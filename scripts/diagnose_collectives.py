import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Print the largest collectives of a one-layer unrolled train-step lowering
— the §Perf hypothesis generator."""
import argparse
import dataclasses
import re
import sys

import jax

sys.path.insert(0, "src")
import repro.configs as configs_lib  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding import compat  # noqa: E402
from repro.roofline.hlo import _OP_RE, _shape_bytes, _group_size, parse_collectives  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--shape", default="train_4k")
ap.add_argument("--layers", type=int, default=1)
ap.add_argument("--top", type=int, default=25)
ap.add_argument("--microbatches", type=int, default=1)
args = ap.parse_args()

cfg = configs_lib.get(args.arch)
kw = dict(num_layers=args.layers, scan_layers=False, unroll_inner=True)
if cfg.family == "encdec":
    kw["encoder_layers"] = args.layers
cfg1 = dataclasses.replace(cfg, **kw)

mesh = make_production_mesh(multi_pod=False)
with compat.use_mesh(mesh):
    jfn, a = build_cell(args.arch, args.shape, mesh,
                        microbatches=args.microbatches, cfg_override=cfg1)
    compiled = jfn.lower(*a).compile()
    txt = compiled.as_text()

ops = []
for line in txt.splitlines():
    m = _OP_RE.search(line)
    if not m:
        continue
    out_shape, kind = m.group(1), m.group(2)
    b = _shape_bytes(out_shape)
    g = _group_size(line)
    name = line.strip().split(" = ")[0]
    ops.append((b, kind, g, out_shape[:70], name[:60]))

ops.sort(reverse=True)
total = sum(b for b, *_ in ops)
print(f"== {args.arch} {args.shape} L={args.layers}: {len(ops)} collectives, "
      f"sum(out bytes)={total/2**30:.2f} GiB")
st = parse_collectives(txt)
print(f"wire bytes: {st.wire_bytes/2**30:.2f} GiB  by kind: "
      f"{ {k: round(v/2**30,2) for k,v in st.by_kind().items()} }")
for b, kind, g, shape, name in ops[:args.top]:
    print(f"{b/2**20:10.1f} MiB  {kind:18s} g={g:3d}  {shape}  {name}")

ca = compat.cost_analysis(compiled)
print("flops:", f"{ca.get('flops',0):.3e}", "bytes:",
      f"{ca.get('bytes accessed',0):.3e}")
