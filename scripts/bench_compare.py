#!/usr/bin/env python
"""Compare current BENCH_*.json against committed baselines.

``python scripts/bench_compare.py [--baseline-ref HEAD] [--threshold 0.25]``

The BENCH files record a perf trajectory, but until now nothing read it
back — a regression only surfaced when a human eyeballed the JSON.  This
tool diffs the BENCH files in the working tree (freshly produced by the
quick benches) against the committed versions (``git show REF:FILE``, or
``--baseline-dir``) and FAILS on a >``threshold`` regression of any
gated series.

Comparisons are only meaningful between runs of the same shape on the
same machine, so two guards precede every diff:

  * host fingerprint (the PR 6 ``host_meta()`` stamp): cpu_count,
    platform, python, jax/jaxlib, backend must match — CI runners
    cannot be compared against the workstation that committed the
    baseline, so a mismatch SKIPS the file (exit 0) with a note;
  * quick flag: a ``--quick`` run against a full-size baseline would
    compare different workloads — also a skip.

Gated series are wall-time/throughput numbers keyed by workload
parameters (per-point ``us_per_iter`` by (m, n, backend), service
latency percentiles, wire bytes per iteration).  Correctness gates
(parity, zero-lost) stay where they are — in each bench's own
``acceptance`` block, enforced by CI already.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Callable, Dict, List, Optional, Tuple

# fields of host_meta() that must agree for a timing comparison to mean
# anything (git_sha is excluded — that is exactly what differs)
FINGERPRINT_FIELDS = ("cpu_count", "platform", "python", "jax", "jaxlib",
                      "jax_backend")

#: direction of goodness
LOWER, HIGHER = "lower", "higher"

Series = Dict[str, Tuple[float, str]]      # label -> (value, direction)


def _point_key(point: dict, fields: Tuple[str, ...]) -> str:
    return ",".join(f"{f}={point.get(f)}" for f in fields
                    if f in point)


def _from_points(doc: dict, fields: Tuple[str, ...],
                 metrics: Dict[str, str]) -> Series:
    out: Series = {}
    for p in doc.get("points", []):
        key = _point_key(p, fields)
        for metric, direction in metrics.items():
            v = p.get(metric)
            if isinstance(v, (int, float)) and v > 0:
                out[f"{metric}[{key}]"] = (float(v), direction)
    return out


def engine_series(doc: dict) -> Series:
    return _from_points(doc, ("m", "n", "dtype", "backend"),
                        {"us_per_iter": LOWER})


def streaming_series(doc: dict) -> Series:
    return _from_points(doc, ("m", "n", "budget_mb"),
                        {"streaming_us_per_sweep": LOWER,
                         "naive_us_per_sweep": LOWER})


def sparse_series(doc: dict) -> Series:
    return _from_points(doc, ("m", "n", "density"),
                        {"sparse_us_per_iter": LOWER,
                         "dense_us_per_iter": LOWER})


def cluster_series(doc: dict) -> Series:
    return _from_points(doc, ("workers", "compress"),
                        {"us_per_iter": LOWER,
                         "reduction_bytes_per_iter": LOWER,
                         "broadcast_bytes_per_iter": LOWER})


def service_series(doc: dict) -> Series:
    out: Series = {}
    warm = doc.get("warm_latency") or {}
    for k in ("p50_ms", "p99_ms"):
        v = warm.get(k)
        if isinstance(v, (int, float)) and v > 0:
            out[f"warm_latency.{k}"] = (float(v), LOWER)
    v = doc.get("healthy_responses_per_s")
    if isinstance(v, (int, float)) and v > 0:
        out["healthy_responses_per_s"] = (float(v), HIGHER)
    return out


EXTRACTORS: Dict[str, Callable[[dict], Series]] = {
    "BENCH_engine.json": engine_series,
    "BENCH_streaming.json": streaming_series,
    "BENCH_sparse.json": sparse_series,
    "BENCH_cluster.json": cluster_series,
    "BENCH_service.json": service_series,
}


def fingerprint(doc: dict) -> Optional[tuple]:
    meta = doc.get("host_meta")
    if not isinstance(meta, dict):
        return None
    return tuple(meta.get(f) for f in FINGERPRINT_FIELDS)


def compare_docs(name: str, current: dict, baseline: dict,
                 threshold: float) -> dict:
    """Diff one bench file. Returns {skipped, reason, rows, regressions}."""
    fp_cur, fp_base = fingerprint(current), fingerprint(baseline)
    if fp_cur is None or fp_base is None or fp_cur != fp_base:
        return {"file": name, "skipped": True,
                "reason": "host fingerprint mismatch "
                          f"({fp_base} -> {fp_cur})",
                "rows": [], "regressions": 0}
    if bool(current.get("quick")) != bool(baseline.get("quick")):
        return {"file": name, "skipped": True,
                "reason": "quick flag mismatch (different workloads)",
                "rows": [], "regressions": 0}
    extract = EXTRACTORS[name]
    cur, base = extract(current), extract(baseline)
    rows: List[dict] = []
    regressions = 0
    for label in sorted(set(cur) & set(base)):
        new, direction = cur[label]
        old, _ = base[label]
        ratio = new / old
        if direction == LOWER:
            regressed = new > old * (1.0 + threshold)
        else:
            regressed = new < old * (1.0 - threshold)
        regressions += bool(regressed)
        rows.append({"series": label, "old": old, "new": new,
                     "ratio": round(ratio, 4), "direction": direction,
                     "regressed": regressed})
    return {"file": name, "skipped": False, "reason": "",
            "rows": rows, "regressions": regressions}


def _load_baseline(name: str, ref: Optional[str],
                   baseline_dir: Optional[str],
                   repo: str) -> Optional[dict]:
    if baseline_dir is not None:
        path = os.path.join(baseline_dir, name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)
    try:
        out = subprocess.run(
            ["git", "-C", repo, "show", f"{ref}:{name}"],
            capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, OSError):
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def run(current_dir: str = ".", baseline_ref: str = "HEAD",
        baseline_dir: Optional[str] = None,
        threshold: float = 0.25, files: Optional[List[str]] = None) -> dict:
    """Programmatic entry point; returns the full comparison report."""
    names = files or sorted(EXTRACTORS)
    report = {"threshold": threshold, "files": [], "regressions": 0,
              "compared": 0, "skipped": 0}
    for name in names:
        cur_path = os.path.join(current_dir, name)
        if not os.path.exists(cur_path):
            report["files"].append({"file": name, "skipped": True,
                                    "reason": "no current file",
                                    "rows": [], "regressions": 0})
            report["skipped"] += 1
            continue
        with open(cur_path) as f:
            current = json.load(f)
        baseline = _load_baseline(name, baseline_ref, baseline_dir,
                                  repo=current_dir)
        if baseline is None:
            report["files"].append({"file": name, "skipped": True,
                                    "reason": "no baseline",
                                    "rows": [], "regressions": 0})
            report["skipped"] += 1
            continue
        res = compare_docs(name, current, baseline, threshold)
        report["files"].append(res)
        if res["skipped"]:
            report["skipped"] += 1
        else:
            report["compared"] += 1
            report["regressions"] += res["regressions"]
    return report


def _print_report(report: dict):
    thr = report["threshold"]
    for res in report["files"]:
        if res["skipped"]:
            print(f"SKIP {res['file']}: {res['reason']}")
            continue
        print(f"DIFF {res['file']} ({len(res['rows'])} gated series, "
              f"threshold {thr:.0%}):")
        for r in res["rows"]:
            flag = "REGRESSION" if r["regressed"] else "ok"
            arrow = "<=" if r["direction"] == LOWER else ">="
            print(f"  {flag:>10}  {r['series']}: {r['old']:g} -> "
                  f"{r['new']:g}  (x{r['ratio']:.3f}, want {arrow} "
                  f"{'1+' if r['direction'] == LOWER else '1-'}{thr:g})")
    print(f"compared {report['compared']} file(s), skipped "
          f"{report['skipped']}, regressions: {report['regressions']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >threshold regression of gated BENCH series "
                    "vs the committed baselines (same host fingerprint "
                    "required)")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref providing baseline BENCH files")
    ap.add_argument("--baseline-dir", default=None,
                    help="read baselines from a directory instead of git")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression that fails (default 0.25)")
    ap.add_argument("--files", nargs="*", default=None,
                    help="subset of BENCH files to compare")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--no-fail", action="store_true",
                    help="report regressions but exit 0")
    args = ap.parse_args(argv)
    report = run(current_dir=args.current_dir,
                 baseline_ref=args.baseline_ref,
                 baseline_dir=args.baseline_dir,
                 threshold=args.threshold, files=args.files)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        _print_report(report)
    if report["regressions"] and not args.no_fail:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
