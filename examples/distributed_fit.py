"""Distributed end-to-end driver (the paper's kind of production run):
row-shard a large synthetic corpus across 8 (virtual) devices, run
transpose-reduction ADMM under shard_map — one n-vector all-reduce per
iteration — and validate against the single-node oracle.

    python examples/distributed_fit.py        (sets its own XLA device count)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import DistributedUnwrappedADMM, shard_rows
from repro.core.oracles import logistic_objective, newton_logistic
from repro.core.prox import make_logistic
from repro.data.synthetic import classification_problem
from repro.sharding import compat


def main():
    ndev = len(jax.devices())
    mesh = compat.make_mesh((ndev,), ("data",))
    print(f"devices: {ndev} (each is a paper 'node')")

    N, m_per, n = ndev, 25_000, 200
    prob = classification_problem(jax.random.PRNGKey(0), N=N,
                                  m_per_node=m_per, n=n, heterogeneity=1.0)
    Dflat = prob.D.reshape(-1, n)
    lflat = prob.labels.reshape(-1)
    print(f"corpus: {Dflat.shape[0]:,} x {n} "
          f"({Dflat.size * 4 / 2**30:.2f} GiB), heterogeneous nodes")

    solver = DistributedUnwrappedADMM(loss=make_logistic(), tau=0.1,
                                      data_axes=("data",))
    solve = jax.jit(solver.build(mesh, Dflat.shape[0], n, iters=80))
    Dg = shard_rows(mesh, Dflat, ("data",))
    lg = shard_rows(mesh, lflat, ("data",))
    t0 = time.time()
    x, objs, res = solve(Dg, lg)
    jax.block_until_ready(x)
    dt = time.time() - t0

    D2, l2 = np.asarray(Dflat), np.asarray(lflat)
    obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
    obj = float(objs[-1])
    acc = float(np.mean(np.sign(D2 @ np.asarray(x)) == l2))
    print(f"80 ADMM iterations in {dt:.1f}s; objective {obj:.1f} "
          f"(optimum {obj_star:.1f}, gap {obj-obj_star:.2e}); "
          f"train acc {acc:.3f}")
    print("per-iteration network traffic: ONE all-reduce of "
          f"{n} floats per node (the paper's O(n) claim).")


if __name__ == "__main__":
    main()
