"""ADMM x LM-framework composition (DESIGN.md §4): fit a readout head on
FROZEN transformer features with transpose-reduction ADMM.

Trains a small qwen3-family LM for a few steps, extracts residual-stream
features, teaches a sparse logistic probe to recover a feature-linear
labeling — the 'linear probe at 950M-rows scale' workflow, miniaturized.

    PYTHONPATH=src python examples/linear_probe.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs_lib
from repro.core.fit import fit
from repro.models.model import forward, init_params
from repro.optim.optimizers import make_optimizer
from repro.runtime.steps import make_train_step


def main():
    cfg = configs_lib.get_smoke("qwen3-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    # a few LM steps so features are not pure init noise
    opt = make_optimizer("adamw", lr=3e-3, warmup_steps=1, total_steps=30)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    tokens = jax.random.randint(key, (8, 64), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    for i in range(20):
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(i, jnp.int32))
    print(f"warmed up LM ({cfg.d_model}d): loss {float(m['loss']):.3f}")

    # frozen features -> node-stacked D for the ADMM fitter
    h, _ = forward(params, cfg, tokens=tokens)
    feats = np.asarray(h.reshape(-1, cfg.d_model), np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal(cfg.d_model)
    labels = np.sign(feats @ w_true
                     + 0.1 * rng.standard_normal(len(feats)))
    D = jnp.asarray(feats).reshape(4, -1, cfg.d_model)
    aux = jnp.asarray(labels, np.float32).reshape(4, -1)

    t0 = time.time()
    r = fit("sparse_logistic", D, aux, mu=0.5, iters=200)
    acc = float(np.mean(np.sign(feats @ np.asarray(r.x)) == labels))
    nnz = int((np.abs(np.asarray(r.x)) > 1e-5).sum())
    print(f"sparse logistic probe: {time.time()-t0:.1f}s, "
          f"train acc {acc:.3f}, {nnz}/{cfg.d_model} features used")
    assert acc > 0.9


if __name__ == "__main__":
    main()
