"""Quickstart: the paper in 60 seconds.

Fits a lasso by §4 transpose reduction (Gram + single-node FASTA), checks
the KKT certificate, and races unwrapped ADMM against consensus ADMM on a
heterogeneous logistic problem (the paper's headline comparison).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram_and_rhs_chunked, transpose_reduction_lasso
from repro.core.fit import fit
from repro.core.oracles import (
    lasso_kkt_gap,
    logistic_objective,
    newton_logistic,
)
from repro.data.synthetic import classification_problem, lasso_problem


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. Lasso via transpose reduction (paper §4) -----------------------
    prob = lasso_problem(key, N=8, m_per_node=2000, n=100)
    Dflat = prob.D.reshape(-1, 100)
    print(f"lasso: D is {Dflat.shape[0]}x100 over 8 nodes, "
          f"mu = {float(prob.mu):.2f} (10% rule)")
    t0 = time.time()
    G, c = gram_and_rhs_chunked(Dflat, prob.b.reshape(-1))   # ONE data pass
    res = transpose_reduction_lasso(G, c, float(prob.mu), iters=2000)
    dt = time.time() - t0
    viol, sup = lasso_kkt_gap(np.asarray(Dflat),
                              np.asarray(prob.b.reshape(-1)),
                              np.asarray(res.x), float(prob.mu))
    nnz = int((np.abs(np.asarray(res.x)) > 1e-6).sum())
    print(f"  solved in {dt:.2f}s ({int(res.iters)} FASTA iters); "
          f"KKT violation {viol:.1e}; support {nnz} (true 10)")

    # --- 2. Unwrapped ADMM vs consensus on heterogeneous data (§10) --------
    prob = classification_problem(key, N=8, m_per_node=1000, n=100,
                                  heterogeneity=1.0)
    D2 = np.asarray(prob.D.reshape(-1, 100))
    l2 = np.asarray(prob.labels.reshape(-1))
    obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
    for method in ("transpose", "consensus"):
        t0 = time.time()
        r = fit("logistic", prob.D, prob.labels, method=method, iters=150)
        objs = np.asarray(r.objective_history)
        hit = np.nonzero(objs <= obj_star * 1.001)[0]
        it = int(hit[0]) + 1 if len(hit) else f">{len(objs)}"
        print(f"  {method:10s}: {time.time()-t0:5.1f}s wall, "
              f"iterations to 0.1% of optimum: {it}")
    print("transpose reduction wins; the gap grows with heterogeneity "
          "(paper Fig. 2b).")


if __name__ == "__main__":
    main()
