"""End-to-end LM training driver on the full fault-tolerance stack:
deterministic pipeline + atomic checkpoints + resume. Uses a reduced
same-family config by default so it completes on CPU in minutes; pass
--full for the real config (TPU-scale).

    PYTHONPATH=src python examples/train_lm.py --arch rwkv6-1.6b --steps 200
"""
import sys

from repro.launch import train


def main():
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "qwen3-8b"] + argv
    if "--full" in argv:
        argv.remove("--full")
    else:
        argv.append("--smoke")
    if "--steps" not in argv:
        argv += ["--steps", "200"]
    if "--ckpt-dir" not in argv:
        argv += ["--ckpt-dir", "/tmp/repro_train_lm_ckpt", "--ckpt-every",
                 "50"]
    train.main(argv)


if __name__ == "__main__":
    main()
