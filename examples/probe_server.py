"""Serving linear probes on frozen LM features from ONE Gram pass.

The production shape of DESIGN.md §4: an interpretability / evals workload
wants many readout heads on the same frozen transformer features — per-label
probes, a regularization path, robust variants. Per-probe ``fit()`` would
recompute the Gram every time; the serving layer registers the features
ONCE and answers every probe from the cached sufficient statistic.

    PYTHONPATH=src python examples/probe_server.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs_lib
from repro.models.model import forward, init_params
from repro.service import FitRequest, FitServer
from repro.service.batching import lasso_mu_path

N_PROBES = 32


def main():
    cfg = configs_lib.get_smoke("qwen3-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (8, 64), 0, cfg.vocab_size, jnp.int32)

    # frozen features: the dataset every probe shares
    h, _ = forward(params, cfg, tokens=tokens)
    feats = np.asarray(h.reshape(-1, cfg.d_model), np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    m, n = feats.shape
    print(f"frozen features: {m} tokens x {n}d")

    srv = FitServer(window=N_PROBES)
    t0 = time.time()
    fp = srv.register_dataset(jnp.asarray(feats))
    print(f"registered in {time.time()-t0:.2f}s — the only Gram pass")

    # one synthetic ground-truth direction per probe
    rng = np.random.default_rng(0)
    W = rng.standard_normal((N_PROBES, n)).astype(np.float32)
    targets = feats @ W.T + 0.1 * rng.standard_normal(
        (m, N_PROBES)).astype(np.float32)

    reqs = [FitRequest(problem="ridge", fingerprint=fp, b=targets[:, j],
                       mu=1e-3 * m) for j in range(N_PROBES)]
    t0 = time.time()
    resp = srv.serve(reqs)
    dt = time.time() - t0
    X = np.stack([r.x for r in sorted(resp, key=lambda r: r.request_id)])
    cos = np.sum(X * W, axis=1) / (
        np.linalg.norm(X, axis=1) * np.linalg.norm(W, axis=1))
    print(f"{N_PROBES} ridge probes served in {dt:.2f}s "
          f"({dt/N_PROBES*1e3:.1f} ms/probe), batch={resp[0].batch_size}; "
          f"probe/truth cosine: min {cos.min():.3f} mean {cos.mean():.3f}")
    assert cos.min() > 0.9

    # sparse readout: full lasso path for probe 0, same cached Gram
    stats = srv.stats_for(fp)
    c0 = jnp.asarray(feats.T @ targets[:, 0])
    mus = jnp.logspace(-1, 2, 16) * float(jnp.max(jnp.abs(c0))) / 100.0
    t0 = time.time()
    Xp = lasso_mu_path(stats.G, c0, mus, iters=400)
    nnz = (np.abs(np.asarray(Xp)) > 1e-5).sum(axis=1)
    print(f"lasso path (16 mus) in {time.time()-t0:.2f}s; "
          f"support {nnz[0]} -> {nnz[-1]}")

    c = srv.counters.snapshot()
    print("counters:", c)
    assert c["gram_passes"] == 1, "probes must share the single Gram pass"


if __name__ == "__main__":
    main()
