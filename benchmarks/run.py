"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]
[--json [PATH]]``

Prints ``name,us_per_call,derived`` CSV rows (one per measured cell).
``--json`` additionally makes the engine benchmark write its machine-
readable result (default ``BENCH_engine.json``) so CI can diff the perf
trajectory run over run.
"""
from __future__ import annotations

import argparse
import sys
import time


def host_meta() -> dict:
    """Host/provenance block stamped into every BENCH_*.json payload —
    one shared definition so a result can always be traced back to the
    machine, software stack, and commit that produced it. Imports stay
    lazy: bench modules ``from benchmarks.run import host_meta`` without
    pulling jax at import time.
    """
    import os
    import platform
    import subprocess
    meta: dict = {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        import jaxlib
        meta["jax"] = jax.__version__
        meta["jaxlib"] = jaxlib.__version__
        meta["jax_backend"] = jax.default_backend()
    except Exception:
        meta["jax"] = meta["jaxlib"] = meta["jax_backend"] = None
    try:
        p = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=5)
        meta["git_sha"] = p.stdout.strip() if p.returncode == 0 else None
    except Exception:
        meta["git_sha"] = None
    return meta


MODULES = {
    "fig1": "benchmarks.fig1_scaling",        # Fig 1 a/b/c scaling sweeps
    "fig2": "benchmarks.fig2_convergence",    # Fig 2 a/b/c curves
    "table1": "benchmarks.table1_star",       # Table 1 star-catalog sweep
    "appendix": "benchmarks.appendix_tables", # Appendix B sweeps
    "tau": "benchmarks.tau_calibration",      # §9 tuning protocol
    "roofline": "benchmarks.roofline_report", # §Roofline collation
    "engine": "benchmarks.engine_bench",      # iteration-engine backends
    "streaming": "benchmarks.streaming_bench",  # out-of-core block streaming
    "sparse": "benchmarks.sparse_bench",      # block-CSR vs dense chunked
    "cluster": "benchmarks.cluster_bench",    # multi-process runtime
    "service": "benchmarks.service_load",     # multi-tenant front end load
}

# modules that can emit a machine-readable result: module key -> default path
JSON_MODULES = {"engine": "BENCH_engine.json",
                "streaming": "BENCH_streaming.json",
                "sparse": "BENCH_sparse.json",
                "cluster": "BENCH_cluster.json",
                "service": "BENCH_service.json"}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write machine-readable results for the JSON-"
                         "capable modules in the selection (engine -> "
                         "BENCH_engine.json, streaming -> "
                         "BENCH_streaming.json); an explicit PATH names "
                         "the sole selected module's output, or the "
                         "engine result when several are selected "
                         "(legacy behavior)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any module FAILED or reported a "
                         "parity MISMATCH (CI mode)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(MODULES)
    if args.json is not None:
        targets = [k for k in JSON_MODULES if k in only] or ["engine"]
        if args.json and len(targets) > 1:
            # an explicit PATH with several JSON-capable modules in the
            # selection keeps the legacy meaning: PATH names the engine
            # result; the others write their defaults
            targets = ["engine"] + [k for k in targets if k != "engine"]
        only.update(targets)
        for key in targets:
            mod = __import__(MODULES[key], fromlist=["JSON_PATH"])
            mod.JSON_PATH = (args.json
                             if args.json and key == targets[0]
                             else JSON_MODULES[key])

    rows = ["name,us_per_call,derived"]
    for key, modname in MODULES.items():
        if key not in only:
            continue
        mod = __import__(modname, fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(rows, quick=args.quick)
            rows.append(f"{key}_total,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # keep the harness going, report the failure
            rows.append(f"{key}_total,0,FAILED:{type(e).__name__}:{e}")
    print("\n".join(rows))
    if args.strict and any(",FAILED:" in r or r.endswith(",MISMATCH")
                           for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
