"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]
[--json [PATH]]``

Prints ``name,us_per_call,derived`` CSV rows (one per measured cell).
``--json`` additionally makes the engine benchmark write its machine-
readable result (default ``BENCH_engine.json``) so CI can diff the perf
trajectory run over run.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = {
    "fig1": "benchmarks.fig1_scaling",        # Fig 1 a/b/c scaling sweeps
    "fig2": "benchmarks.fig2_convergence",    # Fig 2 a/b/c curves
    "table1": "benchmarks.table1_star",       # Table 1 star-catalog sweep
    "appendix": "benchmarks.appendix_tables", # Appendix B sweeps
    "tau": "benchmarks.tau_calibration",      # §9 tuning protocol
    "roofline": "benchmarks.roofline_report", # §Roofline collation
    "engine": "benchmarks.engine_bench",      # iteration-engine backends
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--json", nargs="?", const="BENCH_engine.json",
                    default=None, metavar="PATH",
                    help="write the engine benchmark's JSON result "
                         "(default %(const)s); implies the engine module")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any module FAILED or reported a "
                         "parity MISMATCH (CI mode)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(MODULES)
    if args.json:
        from benchmarks import engine_bench
        engine_bench.JSON_PATH = args.json
        only.add("engine")

    rows = ["name,us_per_call,derived"]
    for key, modname in MODULES.items():
        if key not in only:
            continue
        mod = __import__(modname, fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(rows, quick=args.quick)
            rows.append(f"{key}_total,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # keep the harness going, report the failure
            rows.append(f"{key}_total,0,FAILED:{type(e).__name__}:{e}")
    print("\n".join(rows))
    if args.strict and any(",FAILED:" in r or r.endswith(",MISMATCH")
                           for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
