"""Paper Table 1: star-catalog logistic regression, wall/compute time at
varying node counts for the SAME total corpus (paper: 2500..4000 cores on
1.8 TB; here: emulated nodes on a scaled corpus with identical structure —
307 interaction features, heterogeneous per-node distributions).

The paper's signature result: transpose wall-time ~1 min vs consensus
~20-30 min; total compute ~12 h vs ~30+ days (x60-80 compute gap). We
report the measured compute-time ratio and iterations at each node count.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.consensus import ConsensusLogistic
from repro.core.oracles import logistic_objective, newton_logistic
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import star_catalog_problem

from benchmarks.common import iters_to_tol, time_fn


def run(out_rows: list, quick: bool = False):
    total_rows = 3200 if quick else 6400
    counts = (4, 8) if quick else (4, 8, 16)
    results = []
    for N in counts:
        m_per = total_rows // N
        prob = star_catalog_problem(jax.random.PRNGKey(0), N=N,
                                    m_per_node=m_per)
        n = prob.D.shape[-1]
        D2 = np.asarray(prob.D.reshape(-1, n))
        l2 = np.asarray(prob.labels.reshape(-1))
        obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))

        tr = UnwrappedADMM(loss=make_logistic(), tau=0.1)
        t_t, res_t = time_fn(lambda: tr.run(prob.D, prob.labels, iters=200),
                             reps=1)
        co = ConsensusLogistic(tau=0.5)
        t_c, res_c = time_fn(lambda: co.run(prob.D, prob.labels, iters=120),
                             reps=1)
        it_t = iters_to_tol(res_t.history.objective, obj_star)
        it_c = iters_to_tol(res_c.history.objective, obj_star)
        comp_t = t_t * it_t / 200
        comp_c = t_c * it_c / 120
        results.append({"N": N, "iters_t": it_t, "iters_c": it_c,
                        "compute_t": comp_t, "compute_c": comp_c})
        out_rows.append(
            f"table1_star_N{N},{comp_t*1e6:.0f},"
            f"consensus_compute={comp_c:.2f}s;"
            f"ratio={comp_c/max(comp_t,1e-12):.1f}x;"
            f"iters={it_t}v{it_c}")
    # Paper's qualitative claim: the ratio is large and roughly
    # insensitive to the node count.
    ratios = [r["compute_c"] / max(r["compute_t"], 1e-12) for r in results]
    out_rows.append(
        f"table1_star_summary,0,ratio_range={min(ratios):.1f}-"
        f"{max(ratios):.1f}x_across_{len(counts)}_node_counts")
    return results
