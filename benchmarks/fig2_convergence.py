"""Paper Figure 2 (a/b/c): objective vs wallclock curves.

  fig2a: logistic, homogeneous nodes
  fig2b: logistic, heterogeneous nodes (the consensus-killer)
  fig2c: star-catalog analogue (empirical-style heterogeneous data)

Writes CSV curves to artifacts/benchmarks/fig2_<x>.csv and returns summary
time-to-tolerance numbers.
"""
from __future__ import annotations

import time
from pathlib import Path

import jax
import numpy as np

from repro.core.consensus import ConsensusLogistic
from repro.core.oracles import logistic_objective, newton_logistic
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem, star_catalog_problem

from benchmarks.common import iters_to_tol, time_fn

OUT = Path("artifacts/benchmarks")


def _curves(D, labels, n, iters_t=200, iters_c=200, mu=0.0):
    D2 = np.asarray(D.reshape(-1, n))
    l2 = np.asarray(labels.reshape(-1))
    obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))

    tr = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    t_tr, res_t = time_fn(lambda: tr.run(D, labels, iters=iters_t), reps=1)
    co = ConsensusLogistic(tau=0.5, mu=mu)
    t_co, res_c = time_fn(lambda: co.run(D, labels, iters=iters_c), reps=1)

    objs_t = np.asarray(res_t.history.objective)
    objs_c = np.asarray(res_c.history.objective)
    # map iteration index -> wallclock (uniform per-iteration cost)
    tt = np.arange(1, len(objs_t) + 1) * (t_tr / len(objs_t))
    tc = np.arange(1, len(objs_c) + 1) * (t_co / len(objs_c))
    it_t = iters_to_tol(objs_t, obj_star)
    it_c = iters_to_tol(objs_c, obj_star)
    return {
        "obj_star": obj_star,
        "transpose": (tt, objs_t), "consensus": (tc, objs_c),
        "time_to_tol_transpose": tt[min(it_t, len(tt)) - 1],
        "time_to_tol_consensus": tc[min(it_c, len(tc)) - 1]
        if it_c < len(objs_c) else float("inf"),
    }


def _write_csv(name, curves):
    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / f"{name}.csv", "w") as f:
        f.write("method,time_s,objective\n")
        for meth in ("transpose", "consensus"):
            t, o = curves[meth]
            for ti, oi in zip(t, o):
                f.write(f"{meth},{ti:.4f},{oi:.6f}\n")


def run(out_rows: list, quick: bool = False):
    N, m_per, n = (4, 500, 50) if quick else (8, 1000, 100)
    results = {}
    for name, het in (("fig2a_homogeneous", 0.0), ("fig2b_heterogeneous", 1.0)):
        prob = classification_problem(jax.random.PRNGKey(0), N=N,
                                      m_per_node=m_per, n=n,
                                      heterogeneity=het)
        c = _curves(prob.D, prob.labels, n)
        _write_csv(name, c)
        results[name] = c
        out_rows.append(
            f"{name},{c['time_to_tol_transpose']*1e6:.0f},"
            f"consensus_time_to_tol={c['time_to_tol_consensus']:.3f}s")
    # fig2c: star catalog analogue
    star = star_catalog_problem(jax.random.PRNGKey(1), N=N,
                                m_per_node=200 if quick else 400)
    c = _curves(star.D, star.labels, star.D.shape[-1],
                iters_t=250, iters_c=150)
    _write_csv("fig2c_star", c)
    results["fig2c_star"] = c
    out_rows.append(
        f"fig2c_star,{c['time_to_tol_transpose']*1e6:.0f},"
        f"consensus_time_to_tol={c['time_to_tol_consensus']:.3f}s")
    return results
