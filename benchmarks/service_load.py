"""Multi-tenant service load benchmark — BENCH_service.json (DESIGN.md §15).

Closed-loop load generator against the networked fit front end
(:mod:`repro.service.frontend`) with seeded chaos, proving the service's
robustness contract rather than raw speed:

  * FIVE concurrent tenants with different behaviour profiles — a warm
    ridge tenant, a lasso mu-grid tenant, a bursty over-quota tenant
    (drives admission rejections), a cold logistic tenant with deadlines
    (drives the degrade path when the seeded chaos stalls the cold
    backend), and a flaky tenant that repeatedly crashes mid-flight
    (client kill); plus two hostile non-tenant connections, a slow-loris
    and a corrupt-frame sender, that must be severed without touching
    anyone else.
  * The seeded :class:`~repro.cluster.chaos.FaultInjector` stalls the
    cold-solve backend (``slow`` process faults) so cold requests blow
    their budget and are answered ``degraded`` from cached Gram stats —
    and enough of them trip the circuit breaker, which is the designed
    cascade, not a failure.
  * ZERO LOST REQUESTS is the acceptance bar, checked from both sides:
    server-side every decoded fit has exactly one terminal response and
    nothing stays in flight; client-side every healthy tenant got back
    exactly as many terminal responses as it submitted, and no response
    arrived later than its request's deadline plus a scheduling grace.

Latency is recorded client-side (wire included) and split warm
(gram-path problems served from cached stats) vs cold (full solves).

Observability gates (DESIGN.md §16): the front end runs with a live
:class:`~repro.obs.Observability` plane and a scrape endpoint, and the
run must additionally demonstrate (a) one MULTI-PROCESS trace — a
spawned client process whose ``client.fit`` span is the ancestor of the
frontend's ``frontend.cold_solve`` span under one trace_id; (b) live
``/metrics.json`` scrape samples taken DURING the load whose counters
are monotone and reconcile with the final snapshot; (c) an SLO
burn-rate evaluation where the zero-lost and availability objectives
pass; (d) at least one flight-recorder incident dumped by the seeded
breaker trip and loadable by ``obs_report``; and (e) observability must
be TRANSPARENT — the same fits through an obs-on and an obs-off front
end produce bit-identical solutions.
"""
from __future__ import annotations

import glob
import json
import os
import socket
import struct
import tempfile
import threading
import time

import numpy as np

JSON_PATH = None          # set by benchmarks.run when --json is given

#: responses later than deadline + this grace count as overruns; the
#: grace covers solver-loop scheduling + the degraded fallback solve on
#: a timeshared CI VM, not algorithmic slack
GRACE_S = 1.5


def _dataset(m, n, seed):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    w = rng.standard_normal(n).astype(np.float32)
    b = np.sign(D @ w + 0.1).astype(np.float32)     # ±1 labels
    return D, b


class _Tenant(threading.Thread):
    """One closed-loop tenant: submit, wait for the terminal response,
    record (status, latency), repeat until the wall deadline."""

    def __init__(self, name, address, body, stop_at):
        super().__init__(name=f"tenant-{name}", daemon=True)
        self.tenant = name
        self.address = address
        self.body = body
        self.stop_at = stop_at
        self.records = []          # dicts: problem/status/latency_s/...
        self.submitted = 0
        self.received = 0
        self.error = None

    def run(self):
        from repro.service.frontend import FitServiceClient
        try:
            with FitServiceClient(self.address, tenant=self.tenant) as c:
                while time.monotonic() < self.stop_at:
                    self.body(self, c)
        except Exception as e:      # noqa: BLE001 — surfaced in acceptance
            self.error = f"{type(e).__name__}: {e}"

    def fit(self, client, problem, fingerprint, deadline_s=None, **kw):
        self.submitted += 1
        t0 = time.monotonic()
        r = client.fit(problem, fingerprint, timeout=60.0,
                       deadline_s=deadline_s, **kw)
        lat = time.monotonic() - t0
        self.received += 1
        self.records.append({"problem": problem, "status": r["status"],
                             "latency_s": lat, "deadline_s": deadline_s})
        return r


def _flaky_tenant(address, fingerprint, stop_at, rounds_done):
    """Client-kill chaos: open a connection, fire requests, slam the
    socket shut without reading. Its responses become undeliverable —
    accounted server-side, never blocking a sibling."""
    from repro.service.frontend import FitServiceClient
    while time.monotonic() < stop_at:
        try:
            c = FitServiceClient(address, tenant="flaky")
            for _ in range(2):
                c.fit_async("ridge", fingerprint, mu=1.0)
            c.conn.close()          # crash with responses in flight
            rounds_done.append(2)
        except Exception:           # noqa: BLE001 — dying IS the job
            pass
        time.sleep(0.15)


def _hostile_connections(address):
    """One slow-loris (partial header, stall) and one corrupt-frame
    sender. Returns the open sockets so the caller controls lifetime."""
    loris = socket.create_connection(address)
    loris.sendall(struct.pack(">Q", 4096)[:3])
    corrupt = socket.create_connection(address)
    corrupt.sendall(struct.pack(">Q", 24) + b"\xa5" * 24)
    return [loris, corrupt]


# -- observability gates (DESIGN.md §16) ------------------------------------

def _traced_client_proc(address, fingerprint, out_path):
    """Spawn target: a SEPARATE process running one traced cold fit, so
    the merged timeline provably crosses a process boundary. Ships its
    trace events back through a JSON file (no shared memory)."""
    from repro.obs.trace import Tracer
    from repro.service.frontend import FitServiceClient
    tracer = Tracer(enabled=True, process_name="client")
    with FitServiceClient(tuple(address), tenant="traced",
                          tracer=tracer) as c:
        r = c.fit("logistic", fingerprint, iters=100, deadline_s=30.0,
                  timeout=120.0)
    with open(out_path, "w") as f:
        json.dump({"pid": os.getpid(), "status": r["status"],
                   "events": tracer.events()}, f)


def _run_traced_client(address, fingerprint, rundir, timeout_s=120.0):
    """Run the traced client in a spawned process; returns its shipped
    {pid, status, events} doc, or None if it failed/hung."""
    import multiprocessing as mp
    out_path = os.path.join(rundir, "traced_client.json")
    p = mp.get_context("spawn").Process(
        target=_traced_client_proc,
        args=(tuple(address), fingerprint, out_path), daemon=True)
    p.start()
    p.join(timeout=timeout_s)
    if p.is_alive():
        p.terminate()
        p.join(timeout=5.0)
        return None
    if p.exitcode != 0 or not os.path.exists(out_path):
        return None
    with open(out_path) as f:
        return json.load(f)


def _scrape_json(url, timeout=5.0):
    import urllib.request
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return json.loads(r.read().decode())


def _scrape_text(url, timeout=5.0):
    import urllib.request
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read().decode()


def _counter_total(snap, name):
    return sum(c.get("value", 0) for c in snap.get("counters", [])
               if c.get("name") == name)


def _trace_connectivity(events):
    """Find a client.fit span whose trace contains a frontend.cold_solve
    DESCENDANT — the client -> frontend -> cold-executor chain of the
    acceptance criterion — and report the trace's shape."""
    from repro.obs.trace import is_ancestor
    for ev in events:
        if ev.get("ph") != "X" or ev.get("name") != "client.fit":
            continue
        args = ev.get("args") or {}
        tid, sid = args.get("trace_id"), args.get("span_id")
        if not tid:
            continue
        in_trace = [e for e in events if e.get("ph") == "X"
                    and (e.get("args") or {}).get("trace_id") == tid]
        for cold in in_trace:
            if (cold.get("name") == "frontend.cold_solve"
                    and is_ancestor(events, sid,
                                    cold["args"]["span_id"])):
                return {"connected": True, "trace_id": tid,
                        "processes": len({e.get("pid")
                                          for e in in_trace}),
                        "spans": sorted({e["name"] for e in in_trace})}
    return {"connected": False, "trace_id": None, "processes": 0,
            "spans": []}


def _obs_transparency(D, b):
    """Run identical fits through an obs-ON and an obs-OFF front end;
    observability must not perturb a single output bit."""
    from repro.obs import Observability
    from repro.service.frontend import FitFrontend, FitServiceClient

    def one(obs):
        fe = FitFrontend(window=4, flush_interval_s=0.01, obs=obs)
        try:
            with FitServiceClient(fe.address, tenant="xcheck") as c:
                fp = c.register(D, b)
                out = {}
                for problem, kw in (("ridge", {"mu": 1.0}),
                                    ("logistic", {"iters": 100})):
                    r = c.fit(problem, fp, timeout=120.0, **kw)
                    out[problem] = (r["status"],
                                    None if r["x"] is None
                                    else np.asarray(r["x"]))
            return out
        finally:
            fe.close()

    with tempfile.TemporaryDirectory(prefix="obs_xcheck_") as d:
        obs = Observability(dir=d, process_name="xcheck")
        try:
            with_obs = one(obs)
        finally:
            obs.finish()
    without = one(None)
    identical = all(
        with_obs[k][0] == without[k][0] == "ok"
        and with_obs[k][1] is not None and without[k][1] is not None
        and with_obs[k][1].tobytes() == without[k][1].tobytes()
        for k in with_obs)
    return {"problems": sorted(with_obs),
            "statuses": {k: with_obs[k][0] for k in with_obs},
            "bit_identical": bool(identical)}


def _pct(vals, q):
    return None if not vals else round(
        float(np.percentile(np.asarray(vals), q)) * 1e3, 3)   # ms


def _latency_summary(records, problems, statuses=("ok",)):
    vals = [r["latency_s"] for r in records
            if r["problem"] in problems and r["status"] in statuses]
    return {"count": len(vals), "p50_ms": _pct(vals, 50),
            "p99_ms": _pct(vals, 99),
            "max_ms": _pct(vals, 100)}


def run(rows, quick: bool = False):
    from repro.cluster.chaos import FaultEvent, FaultInjector
    from repro.launch.obs_report import summarize_incident
    from repro.obs import Observability
    from repro.service.frontend import (
        SERVICE_DATA_PLANE,
        FitFrontend,
        FitServiceClient,
    )

    seed = 0
    if quick:
        m, n, duration_s = 1500, 24, 2.5
    else:
        m, n, duration_s = 8000, 48, 8.0
    D, b = _dataset(m, n, seed)
    mu_grid = [0.05, 0.1, 0.5, 1.0]

    # seeded chaos: slow faults against the cold backend, spread over
    # the run's expected request-sequence range so they fire on distinct
    # cold solves rather than piling onto the first one
    rng = np.random.default_rng(seed)
    slow_points = sorted(int(p) for p in
                         rng.integers(5, 40 * int(duration_s), size=4))
    chaos = FaultInjector(
        [FaultEvent(p, "svc", "slow", 1200.0) for p in slow_points],
        data_plane=SERVICE_DATA_PLANE)

    # live observability plane: run-dir artifacts + flight recorder +
    # an OS-assigned scrape port sampled while the load is running
    rundir = tempfile.mkdtemp(prefix="bench_service_obs_")
    obs = Observability(dir=rundir, process_name="frontend")
    fe = FitFrontend(window=8, flush_interval_s=0.01, max_queue=64,
                     tenant_rate=40.0, tenant_burst=5.0,
                     default_deadline_s=20.0, cold_budget_s=0.4,
                     breaker_threshold=3, breaker_reset_s=1.0,
                     frame_deadline_s=1.0, chaos=chaos,
                     obs=obs, scrape_port=0)
    sampler_stop = threading.Event()
    try:
        with FitServiceClient(fe.address, tenant="setup") as setup:
            fp = setup.register(D, b)
            # untimed warmup: pay jit compilation for every path the
            # tenants exercise before the clock starts
            setup.fit("ridge", fp, mu=1.0, timeout=120.0)
            setup.fit("lasso", fp, mu=0.1, iters=200, timeout=120.0)
            setup.fit("logistic", fp, iters=100, timeout=120.0)

        # multi-process trace: a SPAWNED client runs one cold fit before
        # the chaos window opens (fit_seq 4 < first slow point), ships
        # its client-side spans back, and they merge with the frontend's
        # into one timeline under one trace_id
        traced = _run_traced_client(fe.address, fp, rundir)
        if traced is not None:
            fe.tracer.add_events(traced["events"], process_name="client",
                                 pid=traced["pid"])

        stop_at = time.monotonic() + duration_s

        def warm_body(t, c):
            t.fit(c, "ridge", fp, mu=1.0)
            time.sleep(0.02)

        def grid_body(t, c):
            mu = mu_grid[t.submitted % len(mu_grid)]
            t.fit(c, "lasso", fp, mu=mu, iters=200)
            time.sleep(0.02)

        def greedy_body(t, c):
            # burst past the token bucket on purpose, then drain
            rids = [c.fit_async("ridge", fp, mu=1.0) for _ in range(8)]
            t.submitted += len(rids)
            for rid in rids:
                t0 = time.monotonic()
                r = c.result(rid, timeout=60.0)
                t.received += 1
                t.records.append({"problem": "ridge",
                                  "status": r["status"],
                                  "latency_s": time.monotonic() - t0,
                                  "deadline_s": None})
            time.sleep(0.1)

        def cold_body(t, c):
            # every 4th request carries an unmeetable deadline so the
            # mid-queue expiry path shows up in every run
            if t.submitted % 4 == 3:
                t.fit(c, "ridge", fp, mu=1.0, deadline_s=0.002)
            else:
                t.fit(c, "logistic", fp, iters=100, deadline_s=4.0)
            time.sleep(0.02)

        tenants = [
            _Tenant("warm", fe.address, warm_body, stop_at),
            _Tenant("grid", fe.address, grid_body, stop_at),
            _Tenant("greedy", fe.address, greedy_body, stop_at),
            _Tenant("cold", fe.address, cold_body, stop_at),
        ]
        flaky_rounds = []
        flaky = threading.Thread(
            target=_flaky_tenant, args=(fe.address, fp, stop_at,
                                        flaky_rounds),
            daemon=True, name="tenant-flaky")
        t_start = time.monotonic()

        # live scrape sampling DURING the run (acceptance: the samples
        # must be monotone and reconcile with the final snapshot)
        scrape_samples = []

        def _sample_loop():
            url = fe.scrape.url("/metrics.json")
            while not sampler_stop.is_set():
                try:
                    snap = _scrape_json(url)
                    scrape_samples.append({
                        "t_s": round(time.monotonic() - t_start, 3),
                        "responses": _counter_total(
                            snap, "service.responses"),
                        "fit_seen": _counter_total(
                            snap, "service.fit_seen")})
                except Exception:       # noqa: BLE001 — sampling is
                    pass                # best-effort; gate counts hits
                sampler_stop.wait(0.15)

        sampler = threading.Thread(target=_sample_loop, daemon=True,
                                   name="scrape-sampler")
        sampler.start()
        for t in tenants:
            t.start()
        flaky.start()
        time.sleep(duration_s * 0.3)
        hostile = _hostile_connections(fe.address)
        for t in tenants:
            t.join(timeout=120.0)
        flaky.join(timeout=30.0)
        for s in hostile:
            s.close()
        wall_s = time.monotonic() - t_start

        # let the victim responses / severs finish accounting
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            sc = fe.status_counts()
            if sc["in_flight"] == 0 and sc["severed"] >= 2:
                break
            time.sleep(0.05)
        sampler_stop.set()
        sampler.join(timeout=5.0)

        counts = fe.status_counts()
        zero_lost_server = fe.zero_lost_requests()
        records = [r for t in tenants for r in t.records]
        tenant_errors = {t.tenant: t.error for t in tenants if t.error}
        client_balanced = (not tenant_errors and all(
            t.submitted == t.received for t in tenants))
        overruns = [r for r in records
                    if r["deadline_s"] is not None
                    and r["latency_s"] > r["deadline_s"] + GRACE_S]
        status_mix = {s: sum(1 for r in records if r["status"] == s)
                      for s in ("ok", "degraded", "deadline", "rejected",
                                "error")}
        warm_lat = _latency_summary(records, ("ridge", "lasso"))
        cold_lat = _latency_summary(records, ("logistic",))
        degraded_why = {k: int(v) for k, v in fe.metrics.labeled(
            "service.degraded", "why").items()}
        healthy_rps = round(sum(t.received for t in tenants) / wall_s, 1)

        # -- observability gates (DESIGN.md §16) ------------------------
        # (a) multi-process trace connectivity
        trace_info = _trace_connectivity(fe.tracer.events())
        trace_info["client_status"] = (None if traced is None
                                       else traced["status"])
        trace_connected = bool(trace_info["connected"]
                               and trace_info["processes"] >= 2)

        # (b) live scrape reconciliation: counters sampled mid-run are
        # monotone, and a final quiesced scrape equals the authoritative
        # server-side accounting
        terminal_total = sum(counts.get(s, 0) for s in
                             ("ok", "degraded", "deadline", "rejected",
                              "error"))
        resp_series = [s["responses"] for s in scrape_samples]
        seen_series = [s["fit_seen"] for s in scrape_samples]
        monotone = (all(a <= b for a, b in
                        zip(resp_series, resp_series[1:]))
                    and all(a <= b for a, b in
                            zip(seen_series, seen_series[1:])))
        try:
            final_snap = _scrape_json(fe.scrape.url("/metrics.json"))
            prom_text = _scrape_text(fe.scrape.url("/metrics"))
            healthz = _scrape_json(fe.scrape.url("/healthz"))
            slo_http = _scrape_json(fe.scrape.url("/slo"))
            scrape_error = None
        except Exception as e:          # noqa: BLE001 — gate fails below
            final_snap, prom_text, healthz, slo_http = {}, "", {}, {}
            scrape_error = f"{type(e).__name__}: {e}"
        final_matches = (
            _counter_total(final_snap, "service.responses")
            == terminal_total
            and _counter_total(final_snap, "service.fit_seen")
            == counts["fit_seen"])
        live_scrape = {
            "samples": len(scrape_samples),
            "monotone": bool(monotone),
            "final_matches_server": bool(final_matches),
            "prom_text_served": "service_responses_total" in prom_text,
            "healthz_status": healthz.get("status"),
            "slo_route_served": bool(slo_http.get("objectives")),
            "error": scrape_error,
            "series": scrape_samples,
        }
        scrape_ok = bool(len(scrape_samples) >= 3 and monotone
                         and final_matches
                         and live_scrape["prom_text_served"]
                         and healthz.get("status") == "ok"
                         and live_scrape["slo_route_served"])

        # (c) SLO burn-rate evaluation over the run
        slo_final = fe.slo_snapshot()
        slo_by_name = {o["name"]: o for o in slo_final["objectives"]}
        slo_pass = (slo_by_name.get("zero_lost", {}).get("ok") is True
                    and slo_by_name.get("availability", {}).get("ok")
                    is True)

        # (d) flight-recorder incident from the seeded breaker trip,
        # loaded back through obs_report
        incident_summaries = []
        for path in sorted(glob.glob(
                os.path.join(rundir, "incidents", "incident-*.json"))):
            try:
                incident_summaries.append(summarize_incident(path))
            except Exception as e:      # noqa: BLE001 — gate fails below
                incident_summaries.append({"path": path,
                                           "error": str(e)})
        breaker_incidents = [s for s in incident_summaries
                             if s.get("reason") == "breaker_trip"]

        # (e) obs-on x bit-identical to obs-off
        transparency = _obs_transparency(D, b)

        acceptance = {
            "criterion": (
                "every fit request decoded by the service receives "
                "exactly one terminal response (ok/degraded/deadline/"
                "rejected/error) and none is left in flight; every "
                "healthy tenant's submitted == received; no response "
                f"arrives later than its deadline + {GRACE_S}s grace; "
                "the seeded chaos demonstrably exercised every degrade "
                "path: slow cold backend -> degraded answers, bursty "
                "tenant -> quota rejections, unmeetable deadlines -> "
                "mid-queue expiry, and both hostile connections "
                "(slow-loris, corrupt frame) severed without touching "
                "sibling tenants; PLUS the observability gates: a "
                "multi-process trace connects client -> frontend -> "
                "cold executor under one trace_id, live scrape samples "
                "taken during the run reconcile with the final "
                "snapshot, the zero-lost and availability SLOs pass "
                "their burn-rate evaluation, the seeded breaker trip "
                "dumped a flight-recorder incident loadable by "
                "obs_report, and obs-on is bit-identical to obs-off"),
            "zero_lost_requests": bool(zero_lost_server
                                       and client_balanced),
            "server_accounting_balanced": bool(zero_lost_server),
            "client_accounting_balanced": bool(client_balanced),
            "tenant_errors": tenant_errors,
            "deadline_overruns": len(overruns),
            "degrade_path_exercised": bool(status_mix["degraded"] >= 1),
            "rejection_path_exercised": bool(status_mix["rejected"] >= 1),
            "deadline_path_exercised": bool(status_mix["deadline"] >= 1),
            "hostiles_severed": bool(counts["severed"] >= 2),
            "trace_connected": trace_connected,
            "live_scrape_reconciled": scrape_ok,
            "slo_pass": bool(slo_pass),
            "incident_captured": bool(len(breaker_incidents) >= 1),
            "obs_transparent": bool(transparency["bit_identical"]),
        }
        acceptance["pass"] = bool(
            acceptance["zero_lost_requests"]
            and not overruns
            and acceptance["degrade_path_exercised"]
            and acceptance["rejection_path_exercised"]
            and acceptance["deadline_path_exercised"]
            and acceptance["hostiles_severed"]
            and acceptance["trace_connected"]
            and acceptance["live_scrape_reconciled"]
            and acceptance["slo_pass"]
            and acceptance["incident_captured"]
            and acceptance["obs_transparent"])

        rows.append(f"service_warm_latency,"
                    f"{(warm_lat['p50_ms'] or 0) * 1e3:.0f},"
                    f"p99={warm_lat['p99_ms']}ms_n{warm_lat['count']}")
        rows.append(f"service_cold_latency,"
                    f"{(cold_lat['p50_ms'] or 0) * 1e3:.0f},"
                    f"p99={cold_lat['p99_ms']}ms_n{cold_lat['count']}")
        rows.append(f"service_throughput,0,{healthy_rps}rps_"
                    f"{counts['fit_seen']}seen")
        rows.append(
            "service_mix,0,"
            f"ok{status_mix['ok']}_deg{status_mix['degraded']}_"
            f"rej{status_mix['rejected']}_ddl{status_mix['deadline']}_"
            f"err{status_mix['error']}_sev{counts['severed']}")
        rows.append("service_zero_lost,0,"
                    + ("ok" if acceptance["pass"] else "VIOLATED"))
        rows.append(
            "service_obs,0,"
            f"trace{'_ok' if trace_connected else '_FAIL'}_"
            f"scrape{len(scrape_samples)}"
            f"{'ok' if scrape_ok else 'FAIL'}_"
            f"slo{'ok' if slo_pass else 'FAIL'}_"
            f"inc{len(breaker_incidents)}_"
            f"xparent{'ok' if transparency['bit_identical'] else 'FAIL'}")

        if JSON_PATH:
            from benchmarks.run import host_meta
            payload = {
                "generated_by": "benchmarks/service_load.py",
                "host_meta": host_meta(),
                "quick": quick,
                "seed": seed,
                "problem": {"m": m, "n": n, "duration_s": duration_s},
                "chaos": {
                    "slow_cold_backend_at_seq": slow_points,
                    "slow_ms": 1200.0,
                    "client_kill_rounds": len(flaky_rounds),
                    "hostile_connections": ["slow_loris",
                                            "corrupt_frame"],
                },
                "tenants": [
                    {"tenant": t.tenant, "submitted": t.submitted,
                     "received": t.received, "error": t.error}
                    for t in tenants],
                "warm_latency": warm_lat,
                "cold_latency": cold_lat,
                "healthy_responses_per_s": healthy_rps,
                "status_mix_client": status_mix,
                "status_counts_server": counts,
                "degraded_why": degraded_why,
                "breaker": fe.breaker.snapshot(),
                "admission": fe.admission.snapshot(),
                "observability": {
                    "rundir": rundir,
                    "trace": trace_info,
                    "live_scrape": live_scrape,
                    "slo": slo_final,
                    "incidents": incident_summaries,
                    "transparency": transparency,
                },
                "acceptance": acceptance,
            }
            with open(JSON_PATH, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
    finally:
        sampler_stop.set()
        fe.close()
        obs.finish()
