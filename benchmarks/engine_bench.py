"""Engine backend benchmark — us/iter for the solver hot path (§Perf).

Times one donated engine step (x-solve + fused iteration body) per backend
at several (m, n) points and, when ``JSON_PATH`` is set (``run.py --json``),
writes ``BENCH_engine.json`` so CI can track the perf trajectory:

  * ``reference``       — textbook two-pass jnp body (Dx pass + D^T pass);
  * ``chunked``         — the engine's fused one-pass lax.scan stream;
  * ``chunked+bf16``    — same with bf16 data residency (informational on
                          CPU, where bf16 is emulated; the HBM-bytes win
                          is a TPU property — DESIGN.md §8);
  * ``pallas_interpret``— the fused TPU kernel under the interpreter at
                          the smallest point only (a numerics check with a
                          timing column, NOT a perf claim: the interpreter
                          is orders of magnitude slower than real TPU).

The JSON also records a reference-vs-pallas-interpret parity check so the
kernels cannot silently rot on CPU-only runners.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.core.prox import make_logistic
from repro.engine import IterationEngine

JSON_PATH = None          # set by benchmarks.run when --json is given

TAU = 0.1
WARMUP = 2


def _problem(m, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    D = jax.random.normal(ks[0], (m, n), jnp.float32)
    aux = jnp.sign(jax.random.normal(ks[1], (m,)))
    return D, aux


def _engine(backend, residency=None):
    return IterationEngine(loss=make_logistic(), tau=TAU, backend=backend,
                           residency=residency)


def _time_step(eng, D, aux, L, iters):
    m, n = D.shape
    step = eng.make_step(D, aux, L)
    y = jnp.zeros((m,))
    lam = jnp.zeros((m,))
    d = jnp.zeros((n,))
    for _ in range(WARMUP):
        y, lam, d, _ = step(y, lam, d)
    jax.block_until_ready((y, lam, d))
    t0 = time.perf_counter()
    for _ in range(iters):
        y, lam, d, x = step(y, lam, d)
    jax.block_until_ready((y, lam, d))
    return (time.perf_counter() - t0) / iters * 1e6, x


def _parity_check(m=2048, n=128):
    """reference vs pallas-interpret on one fused step from a random state."""
    D, aux = _problem(m, n, seed=1)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    y = jax.random.normal(ks[0], (m,))
    lam = jax.random.normal(ks[1], (m,))
    x = jax.random.normal(ks[2], (n,)) * 0.1
    ref = _engine("reference").iterate(D, aux, y, lam, x)
    pal = _engine("pallas_interpret").iterate(D, aux, y, lam, x)
    scale = float(jnp.max(jnp.abs(ref.d))) or 1.0
    err = max(
        float(jnp.max(jnp.abs(ref.y - pal.y))),
        float(jnp.max(jnp.abs(ref.lam - pal.lam))),
        float(jnp.max(jnp.abs(ref.d - pal.d))) / scale,
    )
    return {"max_abs_or_rel_err": err, "matches": err < 1e-4}


def run(rows, quick: bool = False):
    points = [(8192, 128), (16384, 256)] if quick else [
        (16384, 256), (1 << 17, 512)]
    iters = 4 if quick else 6
    records = []
    for (m, n) in points:
        D, aux = _problem(m, n)
        G, _ = _engine("chunked").gram(D)
        L = gram_lib.gram_factor(G)
        ref_us = None
        variants = [("reference", None), ("chunked", None),
                    ("chunked", "bf16")]
        if (m, n) == points[0]:
            variants.append(("pallas_interpret", None))
        for backend, residency in variants:
            bench_iters = 1 if backend == "pallas_interpret" else iters
            us, _ = _time_step(_engine(backend, residency), D, aux, L,
                               bench_iters)
            if backend == "reference":
                ref_us = us
            label = backend + ("+bf16" if residency else "")
            speed = ref_us / us if ref_us else float("nan")
            records.append({
                "m": m, "n": n, "dtype": "float32", "backend": backend,
                "residency": residency, "us_per_iter": round(us, 1),
                "speedup_vs_reference": round(speed, 3),
            })
            rows.append(f"engine_m{m}_n{n}_{label},{us:.1f},"
                        f"x{speed:.2f}_vs_reference")

    check = _parity_check()
    rows.append("engine_pallas_interpret_parity,0,"
                + ("ok" if check["matches"] else "MISMATCH"))

    if JSON_PATH:
        target = next((r for r in records
                       if r["m"] == 1 << 17 and r["n"] == 512
                       and r["backend"] == "chunked"
                       and r["residency"] is None), None)
        from benchmarks.run import host_meta
        payload = {
            "generated_by": "benchmarks/engine_bench.py",
            # topology + headline engine backend (per-variant backends
            # live on each point record)
            "executor": "local",
            "backend": "chunked",
            "host_meta": host_meta(),
            "device": jax.devices()[0].device_kind,
            "backend_platform": jax.default_backend(),
            "quick": quick,
            "points": records,
            "pallas_interpret_check": check,
            "acceptance": {
                "criterion": "chunked >= 1.5x reference us/iter at "
                             "(m=2^17, n=512), CPU",
                "measured_speedup": (target or {}).get(
                    "speedup_vs_reference"),
                # null (not false) when the quick sweep skips the big point
                "pass": (target["speedup_vs_reference"] >= 1.5
                         if target else None),
            },
        }
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
