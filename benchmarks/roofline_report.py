"""§Roofline reporting: collate artifacts/dryrun/*.json into the per-cell
three-term table (and the markdown block EXPERIMENTS.md embeds)."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path("artifacts/dryrun")


def load_cells(mesh: str = "16x16", art: Path = None):
    ART_ = Path(art) if art else ART
    cells = []
    if not ART_.exists():
        return cells
    for p in sorted(ART_.glob("*.json")):
        if "FAILED" in p.name or f"__{mesh}" not in p.name:
            continue
        c = json.loads(p.read_text())
        if "roofline" not in c:      # admm fit cells have per-phase terms
            continue
        cells.append(c)
    return cells


def load_admm_cells():
    out = []
    if not ART.exists():
        return out
    for p in sorted(ART.glob("admm_*.json")):
        out.append(json.loads(p.read_text()))
    return out


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | frac_of_bound | useful_ratio | peak_GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        t = c["roofline"]
        peak = c["per_device"].get("peak_memory_bytes")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['bottleneck']} | {t['compute_fraction_of_bound']:.3f} | "
            f"{c['useful_flop_ratio']:.2f} | "
            f"{(peak or 0)/2**30:.1f} |")
    return hdr + "\n".join(rows) + "\n"


def run(out_rows: list, quick: bool = False):
    cells = load_cells()
    for c in cells:
        t = c["roofline"]
        out_rows.append(
            f"roofline_{c['arch']}_{c['shape']},0,"
            f"bottleneck={t['bottleneck']};"
            f"frac={t['compute_fraction_of_bound']:.3f}")
    if cells:
        worst = min(cells,
                    key=lambda c: c["roofline"]["compute_fraction_of_bound"])
        out_rows.append(
            f"roofline_worst_cell,0,{worst['arch']}x{worst['shape']};"
            f"frac={worst['roofline']['compute_fraction_of_bound']:.3f}")
    return cells


if __name__ == "__main__":
    import sys
    art = sys.argv[1] if len(sys.argv) > 1 else None
    cells = load_cells(art=art)
    print(markdown_table(cells))
