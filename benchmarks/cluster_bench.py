"""Multi-process cluster benchmark — BENCH_cluster.json (DESIGN.md §11).

Sweeps worker counts over one logistic solve through the coordinator/
worker runtime and records what the paper's deployment claim is actually
made of:

  * BYTES ON THE WIRE per iteration — measured at the sockets (framing
    included), with and without int8 error-feedback compression, against
    the O(m) an equivalent consensus/data-parallel round would move
    (shipping any m-sized object once per iteration). The transpose
    reduction ships three n-vectors per worker per iteration and one
    n-vector broadcast back; that ratio, not wall clock on one VM, is
    the paper's C5 scaling claim.
  * PARITY — every cluster point must reproduce the single-process
    ``UnwrappedADMM.run`` x at the same iteration count (the runtime is
    an execution substrate, not an approximation — except compressed
    mode, which is held to the established objective-gap bar instead,
    since int8 jitter perturbs x by ~1/127 pointwise while the
    objective is quadratically flat at the optimum).
  * HONEST host gating — multi-process scaling needs at least one core
    per worker PLUS the coordinator; on a 2-core CI VM every worker
    count timeshares the same two cores (and pays per-process jax
    startup), so wall-clock speedup is structurally unavailable and the
    acceptance gates any speedup expectation on
    ``cpu_count >= workers + 1``. Parity and wire-byte accounting are
    host-independent and required everywhere.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

JSON_PATH = None          # set by benchmarks.run when --json is given

TAU = 0.1
TINY = dict(eps_rel=1e-9, eps_abs=1e-12)   # fixed-iteration parity runs


def _problem(m, n, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    aux = np.sign(rng.standard_normal((m,))).astype(np.float32)
    return D, aux


def _reference(D, aux, iters):
    from repro.core.prox import make_logistic
    from repro.core.unwrapped import UnwrappedADMM
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    res = solver.run(D[None], aux[None], iters=iters)
    return np.asarray(res.x)


def _wire_totals(res):
    """Measured frame bytes per iteration, split by role: reductions
    (worker->parent->coordinator 'contrib' hops) and the coordinator's
    x broadcasts ('iter'). Worker counters arrive with the shutdown."""
    t = res.telemetry
    sc = t["shutdown_counters"]
    worker_tx = sc["workers"].get("sent_bytes", {})
    reduction = worker_tx.get("contrib", 0)
    broadcast = t["coordinator_broadcast_tx_bytes"]
    other = sum(v for k, v in worker_tx.items() if k != "contrib") + sum(
        v for k, v in sc["coordinator"].get("sent_bytes", {}).items()
        if k != "iter")
    iters = max(t["iters"], 1)
    return {
        "reduction_bytes_per_iter": round(reduction / iters, 1),
        "broadcast_bytes_per_iter": round(broadcast / iters, 1),
        "control_bytes_total": other,
        "tree_depth": t["tree_depth"],
    }


def _one_point(D, aux, workers, iters, compress, store_path):
    from repro.cluster.coordinator import ClusterConfig, cluster_solve
    cfg = ClusterConfig(n_workers=workers, compress=compress)
    t0 = time.perf_counter()
    res = cluster_solve(store_path, None, {"name": "logistic"}, tau=TAU,
                        max_iters=iters, config=cfg, **TINY)
    total_s = time.perf_counter() - t0
    return res, total_s


def _recovery_point(D, aux, ref_x, workers, iters, store_path):
    """Seeded chaos run (worker SIGKILL + mid-solve join + wire faults):
    the solve must self-heal back to the single-process x, and the
    telemetry's recovery metrics (time-to-recover, iterations retried,
    join-to-contributing latency) are the benchmark's product. Timings
    are recorded honestly — on a timeshared CI VM they measure this VM,
    not the paper's cluster — only parity is gated."""
    from repro.cluster.chaos import ChaosSchedule
    from repro.cluster.coordinator import (
        ClusterConfig,
        DegradePolicy,
        cluster_solve,
    )
    sched = ChaosSchedule.generate(0, n_workers=workers, iters=iters,
                                   kills=1, stops=0, joins=1,
                                   delays=1, drops=1)
    cfg = ClusterConfig(
        n_workers=workers, chaos=sched,
        degrade=DegradePolicy(iter_deadline_s=20.0, deadline_retries=3),
        reconnect={"retries": 4, "backoff_s": 0.25, "backoff_max_s": 2.0})
    res = cluster_solve(store_path, None, {"name": "logistic"}, tau=TAU,
                        max_iters=iters, config=cfg, **TINY)
    rel = float(np.linalg.norm(res.x - ref_x)
                / max(np.linalg.norm(ref_x), 1e-30))
    t = res.telemetry
    rec = t["recovery"]
    return {
        "workers": workers, "iters": res.iters,
        "chaos_seed": t["chaos_seed"], "chaos_spec": t["chaos_spec"],
        "status": t["status"],
        "rel_x_err_vs_single_process": rel,
        "deaths": t["deaths"], "joins": t["joins"],
        "blocks_reassigned": t["blocks_reassigned"],
        "blocks_rebalanced": t["blocks_rebalanced"],
        "time_to_recover_s": rec["time_to_recover_s"],
        "iterations_retried": rec["iterations_retried"],
        "join_to_contributing_s": rec["join_to_contributing_s"],
        "recovery_events": rec["events"],
        "solve_wall_s": t["wall_s"],
    }


def run(rows, quick: bool = False):
    from repro.cluster import compress as compress_lib
    from repro.cluster.coordinator import _ensure_store
    from repro.core.oracles import logistic_objective

    if quick:
        m, n, iters, sweep = 1 << 12, 32, 8, [1, 2]
    else:
        m, n, iters, sweep = 1 << 15, 128, 16, [1, 2, 4]
    D, aux = _problem(m, n)
    ref_x = _reference(D, aux, iters)
    ref_obj = logistic_objective(D, aux, ref_x)
    store_path, store_created = _ensure_store(
        D, aux, None, max(sweep),
        block_rows=max(64, m // (2 * max(sweep))))

    cpus = os.cpu_count() or 1
    consensus_bytes = 4 * m          # ONE m-sized f32 object per round
    points = []
    base_wall = None
    for w in sweep:
        res, total_s = _one_point(D, aux, w, iters, False, store_path)
        rel = float(np.linalg.norm(res.x - ref_x)
                    / max(np.linalg.norm(ref_x), 1e-30))
        wire = _wire_totals(res)
        wall = res.telemetry["wall_s"]
        if w == 1:
            base_wall = wall
        rec = {
            "workers": w, "m": m, "n": n, "iters": res.iters,
            "compress": False,
            "solve_wall_s": wall,
            "total_wall_s_incl_spawn": round(total_s, 3),
            "us_per_iter": round(wall / max(res.iters, 1) * 1e6, 1),
            "speedup_vs_1_worker": (round(base_wall / wall, 3)
                                    if base_wall else None),
            "rel_x_err_vs_single_process": rel,
            # per-worker timing breakdown (iters, wall per iter, replay /
            # retry counts) folded by the coordinator from heartbeat +
            # bye metric snapshots
            "per_worker": res.telemetry.get("per_worker"),
            "payload_bytes_per_nvec": compress_lib.wire_bytes(n, False),
            "consensus_scheme_bytes_per_iter": consensus_bytes,
            **wire,
        }
        rec["reduction_vs_consensus_ratio"] = round(
            rec["reduction_bytes_per_iter"] / consensus_bytes, 6)
        points.append(rec)
        rows.append(f"cluster_w{w}_m{m}_n{n},"
                    f"{rec['us_per_iter']},"
                    f"relx{rel:.1e}_"
                    f"{rec['reduction_bytes_per_iter']:.0f}B/iter")

    # compressed point: int8 EF on every hop, objective-gap parity bar
    wc = sweep[-1] if len(sweep) > 1 else 1
    res_c, _ = _one_point(D, aux, wc, iters, True, store_path)
    obj_c = logistic_objective(D, aux, np.asarray(res_c.x))
    gap_c = float(abs(obj_c - ref_obj) / abs(ref_obj))
    wire_c = _wire_totals(res_c)
    comp_rec = {
        "workers": wc, "m": m, "n": n, "iters": res_c.iters,
        "compress": True,
        "solve_wall_s": res_c.telemetry["wall_s"],
        "rel_obj_gap_vs_single_process": gap_c,
        "per_worker": res_c.telemetry.get("per_worker"),
        "payload_bytes_per_nvec": compress_lib.wire_bytes(n, True),
        "payload_bytes_per_nvec_uncompressed":
            compress_lib.wire_bytes(n, False),
        "consensus_scheme_bytes_per_iter": consensus_bytes,
        **wire_c,
    }
    comp_rec["reduction_vs_consensus_ratio"] = round(
        comp_rec["reduction_bytes_per_iter"] / consensus_bytes, 6)
    rows.append(f"cluster_w{wc}_compressed,"
                f"{comp_rec['solve_wall_s']*1e6/max(res_c.iters,1):.1f},"
                f"objgap{gap_c:.1e}_"
                f"{comp_rec['reduction_bytes_per_iter']:.0f}B/iter")

    # recovery point: seeded kill + join + wire-fault chaos, self-healed
    # back to the same x (DESIGN.md §13)
    rec_point = _recovery_point(D, aux, ref_x, max(sweep), iters,
                                store_path)
    # gate PARITY through the faults only: the kill always lands (EOF
    # detection is instant) but whether the joiner registers before the
    # solve ends is a property of this VM's process-spawn latency, so
    # join metrics are recorded, not gated (test_chaos.py's soak gates
    # them under a schedule sized for it)
    recovery_ok = bool(
        rec_point["rel_x_err_vs_single_process"] < 1e-4
        and rec_point["status"] != "degraded"
        and rec_point["deaths"]
        and rec_point["time_to_recover_s"] is not None)
    rows.append(
        f"cluster_recovery_w{rec_point['workers']},"
        f"{(rec_point['time_to_recover_s'] or 0) * 1e6:.0f},"
        f"relx{rec_point['rel_x_err_vs_single_process']:.1e}_"
        f"{rec_point['iterations_retried']}retries")

    parity_ok = all(p["rel_x_err_vs_single_process"] < 1e-4
                    for p in points) and gap_c < 1e-3
    wire_ok = all(p["reduction_bytes_per_iter"]
                  < 0.5 * consensus_bytes for p in points + [comp_rec])
    # at quick's n=32 the per-message framing (~300 B of dict keys and
    # scalars) rivals 4n = 128 B of payload, so the compression ratio is
    # only meaningful at the full-size point — measured always, gated
    # only there (null on --quick, the other benches' convention)
    compression_wins = (None if quick else bool(
        comp_rec["reduction_bytes_per_iter"]
        < 0.7 * max(p["reduction_bytes_per_iter"] for p in points
                    if p["workers"] == wc)))
    # scaling is only claimable with a core per worker + coordinator;
    # workers also default to single-threaded compute, so a big host is
    # required before wall-clock means anything
    scaling_gate = cpus >= max(sweep) + 1
    best_speedup = max((p["speedup_vs_1_worker"] or 0.0) for p in points)
    rows.append(f"cluster_host_gate,0,cpus{cpus}_scaling_"
                + ("applies" if scaling_gate else "not_claimable"))
    if store_created:
        import shutil
        shutil.rmtree(store_path, ignore_errors=True)

    if JSON_PATH:
        from benchmarks.run import host_meta
        payload = {
            "generated_by": "benchmarks/cluster_bench.py",
            "executor": "cluster",
            "backend": "chunked",
            "host_meta": host_meta(),
            "host_cpus": cpus,
            "quick": quick,
            "problem": {"kind": "logistic", "m": m, "n": n,
                        "iters": iters, "tau": TAU},
            "points": points,
            "compressed_point": comp_rec,
            "recovery_point": rec_point,
            "acceptance": {
                "criterion": (
                    "every worker count reproduces the single-process "
                    "solve (x rel err < 1e-4 uncompressed; objective "
                    "gap < 1e-3 compressed); per-iteration reduction "
                    "wire bytes stay O(n-vectors) — under half the "
                    "4m bytes a consensus/data-parallel round would "
                    "move — and int8 compression measurably cuts them; "
                    "wall-clock speedup is only claimed when the host "
                    "has >= workers+1 cores (this VM's 2 cores "
                    "timeshare every process, so the sweep documents "
                    "communication and correctness, not scaling); the "
                    "recovery point must self-heal through a seeded "
                    "kill + mid-solve join + wire faults back to the "
                    "same x — its recovery TIMINGS are recorded but "
                    "not gated (they measure this VM's process spawn "
                    "and detection latencies, not the algorithm)"),
                "parity_ok": parity_ok,
                "wire_bytes_ok": wire_ok,
                "recovery_parity_ok": recovery_ok,
                "compression_cuts_wire_bytes": compression_wins,
                "scaling_gate_applies": scaling_gate,
                "best_speedup_vs_1_worker": best_speedup,
                "speedup_ok": (best_speedup >= 1.3 if scaling_gate
                               else None),
                "pass": bool(parity_ok and wire_ok and recovery_ok
                             and compression_wins is not False
                             and (best_speedup >= 1.3
                                  if scaling_gate else True)),
            },
        }
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
