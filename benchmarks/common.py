"""Shared benchmark utilities: timing, iterations-to-tolerance, and the
paper-style 'total compute time' model.

Methodology note (CPU container): absolute wall-times here are CPU numbers;
what reproduces the paper is the STRUCTURE — iterations-to-convergence of
each method, per-iteration cost, and their scaling in (N, m, n). We report
measured per-iteration wall time x iterations (compute time), plus the
analytic per-iteration FLOP model (repro.core.fit._flops_per_iter) evaluated
at the paper's core counts.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np


def time_fn(fn: Callable, *args, reps: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) after jit warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def iters_to_tol(objs, obj_star: float, rel: float = 1e-3) -> int:
    objs = np.asarray(objs)
    thr = obj_star + rel * abs(obj_star)
    hits = np.nonzero(objs <= thr)[0]
    return int(hits[0]) + 1 if len(hits) else len(objs)


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
