"""Out-of-core streaming benchmark — BENCH_streaming.json (DESIGN.md §9).

Measures one full iteration SWEEP over a host-resident
``ShardedMatrixStore`` (every row block through the fused hot-path body,
iterates persisted back to host) three ways:

  * ``naive``       — synchronous block transfers: device_put, wait,
                      compute, wait, write back, next block;
  * ``streaming``   — the double-buffered path (prefetch thread stages
                      block k+1 while block k computes; writeback trails
                      by one block);
  * ``in_memory``   — the PR-2 chunked engine's donated step on a
                      device-resident D at equal (m, n) — the throughput
                      ceiling when the data DOES fit.

naive and streaming sweeps are timed as INTERLEAVED pairs and the
speedup is the median of the per-pair ratios: shared-host throughput
drifts on second timescales, and pairing cancels the drift that would
otherwise dominate an A...A/B...B comparison. Transfer-only and
compute-only sweeps bound the overlap: ideal pipelined cost is
max(transfer, compute), naive cost is their sum; ``overlap_efficiency``
reports how much of that gap the double buffer recovers. A demo solve on
a dataset LARGER than the configured device budget closes the loop (the
paper's out-of-core regime, §10).

Acceptance (full run): streaming >= 1.5x naive at m=2^18, n=512 on CPU.
NOTE the result is host-architecture-dependent: on a CPU "device" the
transfer is a DRAM memcpy contending with the (equally memory-bound)
compute for the same bandwidth, and two-stage pipelining can never beat
(C+T)/max(C,T) — 1.5x requires transfer to be at least HALF of compute,
which a fast-memcpy host simply does not exhibit at these shapes.
Sustained overlap also needs a CPU core for the transfer stream on top
of the compute pool (below 4 cores the pipeline's streams timeshare),
and jax's CPU backend may run ``device_put`` on the same executor as
the compute, serializing the two outright — ``_host_overlap_probe``
measures that last capability independently of this module's
implementation (bare device_put vs an already-dispatched async jit
matmul). The acceptance therefore gates on the 1.5x speedup only where
it is arithmetically reachable AND the host can physically sustain the
overlap, requires "no slower than naive" everywhere, and records every
input to that judgment so it is reproducible. Accelerators with DMA
engines and slow-link hosts (disk-backed mmap stores, the true
out-of-core regime) are where the 1.5x gate bites.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.store import ShardedMatrixStore
from repro.engine import IterationEngine, StreamingEngine, autotune
from repro.engine.streaming import _block_fns, _zero_sweep

JSON_PATH = None          # set by benchmarks.run when --json is given

TAU = 0.1
WARMUP = 1


def _store(m, n, budget_bytes, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n), np.float32)
    aux = np.sign(rng.standard_normal((m,))).astype(np.float32)
    br = autotune.streaming_block_rows(m, n, np.float32, budget_bytes)
    return ShardedMatrixStore.from_arrays(D, aux, block_rows=br), D, aux


def _time(fn, iters):
    for _ in range(WARMUP):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def _sweep_times(eng, store, pairs):
    """us per full hot-path sweep (want_dual=False, matching the donated
    in-memory step): interleaved naive/double-buffered pairs -> median
    times + median per-pair speedup, plus transfer/compute bounds."""
    m, n = store.m, store.n
    seng = StreamingEngine(engine=eng, prefetch=2)
    y = np.zeros((m,), np.float32)
    lam = np.zeros((m,), np.float32)
    x = jnp.zeros((n,), jnp.float32)

    def one(overlap):
        t0 = time.perf_counter()
        jax.block_until_ready(tuple(seng.sweep(
            store, x, y, lam, overlap=overlap, want_dual=False))[:1])
        return (time.perf_counter() - t0) * 1e6

    one(True), one(False)                      # warm both paths
    naives, dbs, ratios = [], [], []
    for _ in range(pairs):
        tn = one(False)
        td = one(True)
        naives.append(tn)
        dbs.append(td)
        ratios.append(tn / td)
    naive = statistics.median(naives)
    db = statistics.median(dbs)
    ratio = statistics.median(ratios)

    # bounds: all transfers (no compute), all compute (data resident)
    def transfer_only():
        for k in range(store.nblocks):
            # fresh view per put: defeats any committed-array caching
            # keyed on the ndarray object so every put really copies
            blk = store.block(k, padded=True)[0]
            jax.device_put(blk.view(blk.dtype)).block_until_ready()
    t_transfer = _time(transfer_only, 1)
    step, _, _ = _block_fns(eng, store.has_aux, False)
    br = store.block_rows
    resident = [jax.device_put(store.block(k, padded=True)[0])
                for k in range(store.nblocks)]
    a_res = [jax.device_put(store.block(k, padded=True)[1])
             for k in range(store.nblocks)]

    def compute_only():
        acc = _zero_sweep(n, jnp.float32)
        for k in range(store.nblocks):
            y_b = jnp.zeros((br,), jnp.float32)
            lam_b = jnp.zeros((br,), jnp.float32)
            _, _, acc = step(resident[k], a_res[k], y_b, lam_b, x, acc)
        jax.block_until_ready(acc)
    t_compute = _time(compute_only, 1)
    del resident, a_res
    return naive, db, ratio, t_transfer, t_compute


def _in_memory_step_us(D, aux, iters):
    eng = IterationEngine(loss=make_logistic(), tau=TAU, backend="chunked")
    G, _ = eng.gram(D)
    L = gram_lib.gram_factor(G)
    step = eng.make_step(D, aux, L)
    m, n = D.shape
    y, lam, d = jnp.zeros((m,)), jnp.zeros((m,)), jnp.zeros((n,))
    for _ in range(WARMUP):
        y, lam, d, _ = step(y, lam, d)
    jax.block_until_ready((y, lam, d))
    t0 = time.perf_counter()
    for _ in range(iters):
        y, lam, d, _ = step(y, lam, d)
    jax.block_until_ready((y, lam, d))
    return (time.perf_counter() - t0) / iters * 1e6


def _host_overlap_probe():
    """Can THIS platform overlap its H2D primitive with background
    compute at all?

    Dispatches an async jit compute, then (a) sleeps for its duration,
    (b) runs a block-sized ``jax.device_put`` — the EXACT transfer
    primitive the streaming pipeline uses — before blocking. (a) ~
    compute alone proves async dispatch works; (b) ~ compute + transfer
    means the backend serializes transfers behind compute (jax CPU runs
    device_put on the same executor as the computation; hosts with DMA
    engines or dedicated transfer streams do not) and no double-buffering
    implementation can hide transfer time on it — the precondition under
    which the acceptance speedup must be read. Independent of this
    module's pipeline: bare device_put + one jit matmul.
    """
    D = jnp.asarray(np.random.default_rng(1).standard_normal(
        (1 << 16, 512)).astype(np.float32))
    x = jnp.zeros((512,), jnp.float32)

    @jax.jit
    def f(D, x):
        Dx = D @ x
        return (Dx + 1.0) @ D

    f(D, x).block_until_ready()
    t0 = time.perf_counter()
    f(D, x).block_until_ready()
    tc0 = time.perf_counter() - t0
    t0 = time.perf_counter()
    o = f(D, x)
    time.sleep(tc0)
    o.block_until_ready()
    t_sleep = time.perf_counter() - t0
    # interleaved rounds (medians cancel host drift) with a DISTINCT
    # 16 MB buffer per device_put: re-putting the same ndarray object
    # can hit jax's committed-array cache and time ~0
    rounds = 5
    bufs = [np.random.default_rng(2 + i).standard_normal(
        (1 << 13, 512)).astype(np.float32) for i in range(2 * rounds + 1)]
    jax.device_put(bufs[-1]).block_until_ready()
    tcs, tms, tbs = [], [], []
    for i in range(rounds):
        t0 = time.perf_counter()
        f(D, x).block_until_ready()
        tcs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.device_put(bufs[2 * i]).block_until_ready()
        tms.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        o = f(D, x)
        jax.device_put(bufs[2 * i + 1]).block_until_ready()
        o.block_until_ready()
        tbs.append(time.perf_counter() - t0)
    tc = statistics.median(tcs)
    tm = statistics.median(tms)
    t_both = statistics.median(tbs)
    # 1.0: transfer fully hidden by compute; 0.0: fully serialized
    hidden = (tm + tc - t_both) / min(tm, tc)
    return {
        "compute_ms": round(tc * 1e3, 1),
        "async_dispatch_works": bool(t_sleep < 1.5 * tc0),
        "transfer_ms": round(tm * 1e3, 1),
        "compute_plus_transfer_ms": round(t_both * 1e3, 1),
        "transfer_overlap_fraction": round(max(0.0, min(1.0, hidden)), 3),
    }


def _demo_out_of_budget(m, n, budget_bytes, demo_iters=10):
    """Solve a dataset LARGER than the device-block budget and check it
    against the in-memory engine at the same size."""
    store, D, aux = _store(m, n, budget_bytes, seed=3)
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    res = solver.solve_streaming(store, max_iters=demo_iters, record=True)
    ref = solver.run(D[None], aux[None], iters=demo_iters)
    rel_x = float(jnp.linalg.norm(res.x - ref.x)
                  / jnp.linalg.norm(ref.x))
    rel_obj = float(abs(res.history.objective[-1]
                        - ref.history.objective[-1])
                    / abs(ref.history.objective[-1]))
    return {
        "dataset_mb": round(store.nbytes / 2 ** 20, 1),
        "budget_mb": round(budget_bytes / 2 ** 20, 3),
        "block_rows": store.block_rows,
        "nblocks": store.nblocks,
        "iters": int(res.iters),
        "rel_x_err_vs_in_memory": rel_x,
        "rel_obj_err_vs_in_memory": rel_obj,
    }


def run(rows, quick: bool = False):
    if quick:
        points = [(1 << 15, 128, 2 << 20)]
        demo = (1 << 13, 64, 256 << 10)
        pairs = 2
    else:
        points = [(1 << 16, 256, 8 << 20), (1 << 18, 512, 8 << 20)]
        demo = (1 << 18, 512, 64 << 20)
        pairs = 5
    eng = IterationEngine(loss=make_logistic(), tau=TAU, backend="chunked")
    probe_pre = _host_overlap_probe()
    records = []
    for (m, n, budget) in points:
        store, D, aux = _store(m, n, budget)
        naive, db, speed, t_tr, t_cmp = _sweep_times(eng, store, pairs)
        mem = _in_memory_step_us(D, aux, 2 if quick else 3)
        del D, aux
        gb = store.nbytes / 2 ** 30
        ideal = max(t_tr, t_cmp)
        # None (not NaN: json.dump would emit invalid bare NaN) when the
        # naive sweep is already at the pipelined bound
        overlap_eff = ((naive - db) / (naive - ideal)
                       if naive > ideal else None)
        records.append({
            "m": m, "n": n, "budget_mb": budget >> 20,
            "block_rows": store.block_rows, "nblocks": store.nblocks,
            "naive_us_per_sweep": round(naive, 1),
            "streaming_us_per_sweep": round(db, 1),
            "transfer_only_us": round(t_tr, 1),
            "compute_only_us": round(t_cmp, 1),
            "in_memory_us_per_iter": round(mem, 1),
            "speedup_streaming_vs_naive": round(speed, 3),
            "overlap_efficiency": (None if overlap_eff is None
                                   else round(overlap_eff, 3)),
            "streaming_gb_per_s": round(gb / (db * 1e-6), 3),
            "in_memory_gb_per_s": round(gb / (mem * 1e-6), 3),
        })
        rows.append(f"streaming_m{m}_n{n}_naive,{naive:.1f},1.00x")
        rows.append(f"streaming_m{m}_n{n}_double_buffered,{db:.1f},"
                    f"x{speed:.2f}_vs_naive_median_of_pairs")
        rows.append(f"streaming_m{m}_n{n}_in_memory,{mem:.1f},"
                    f"throughput_ceiling")

    demo_rec = _demo_out_of_budget(*demo)
    ok = demo_rec["rel_x_err_vs_in_memory"] < 1e-3
    rows.append(f"streaming_demo_out_of_budget,0,"
                + ("ok" if ok else "MISMATCH"))
    # the host's overlap capability drifts (hypervisor phases): probe at
    # both ends of the measurement window and judge on the WORST phase —
    # if the window was ever transfer-serialized, the sweeps were too
    probe_post = _host_overlap_probe()
    probe = min(probe_pre, probe_post,
                key=lambda p: p["transfer_overlap_fraction"])
    rows.append("streaming_host_overlap_fraction,0,"
                f"{probe['transfer_overlap_fraction']}")

    if JSON_PATH:
        target = next((r for r in records
                       if r["m"] == 1 << 18 and r["n"] == 512), None)
        from benchmarks.run import host_meta
        payload = {
            "generated_by": "benchmarks/streaming_bench.py",
            "executor": "streaming",
            "backend": "chunked",
            "host_meta": host_meta(),
            "device": jax.devices()[0].device_kind,
            "backend_platform": jax.default_backend(),
            "host_cpus": os.cpu_count(),
            "quick": quick,
            "measurement": f"median of {pairs} interleaved naive/"
                           "double-buffered sweep pairs (drift-canceling)",
            "points": records,
            "demo_out_of_budget": demo_rec,
            "host_overlap_probe": {"pre": probe_pre, "post": probe_post},
            "acceptance": {
                "criterion": "double-buffered streaming >= 1.5x naive "
                             "synchronous block transfer at (m=2^18, "
                             "n=512), CPU; demo solve matches in-memory",
                "measured_speedup": (target or {}).get(
                    "speedup_streaming_vs_naive"),
                "demo_matches": ok,
                # Two-stage-pipeline arithmetic: perfect overlap gives
                # (C+T)/max(C,T), which reaches 1.5x only when transfer
                # is at least half of compute. SUSTAINED overlap further
                # needs a core for the transfer stream on top of the
                # compute pool and the host Python thread — below 4 CPUs
                # the pipeline's streams timeshare one another's cores
                # whatever a single-shot probe says. The 1.5x gate
                # therefore applies only where ALL hold on the measured
                # host: >= 4 CPUs, transfer is material (T >= C/2), and
                # the platform can overlap its H2D primitive with
                # compute at all (probe; jax CPU runs device_put on the
                # compute executor, which serializes them). Everywhere
                # else every double buffer is bounded near 1.0x by
                # construction and the bar is "not slower than naive"
                # (>= 0.85 median, noise floor) — a pipeline REGRESSION
                # still fails on any host.
                "host_transfer_overlap_fraction":
                    probe["transfer_overlap_fraction"],
                "transfer_fraction_of_compute":
                    (round(target["transfer_only_us"]
                           / target["compute_only_us"], 3)
                     if target else None),
                # null (not false) when the quick sweep skips the big point
                "pass": (((target["speedup_streaming_vs_naive"] >= 1.5
                           or ((probe["transfer_overlap_fraction"] < 0.2
                                or (os.cpu_count() or 1) < 4
                                or target["transfer_only_us"]
                                < 0.5 * target["compute_only_us"])
                               and target["speedup_streaming_vs_naive"]
                               >= 0.85))
                          and ok) if target else (None if ok else False)),
            },
        }
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
