"""Paper §9 stepsize-tuning protocol: tune tau on a reference instance and
check the scaling rule across problem sizes (backs oracles.default_tau)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.oracles import logistic_objective, newton_logistic
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem

from benchmarks.common import iters_to_tol


def run(out_rows: list, quick: bool = False):
    taus = (0.02, 0.05, 0.1, 0.25, 1.0)
    sizes = ((4, 250),) if quick else ((4, 250), (8, 1000))
    table = {}
    for N, m_per in sizes:
        prob = classification_problem(jax.random.PRNGKey(0), N=N,
                                      m_per_node=m_per, n=20)
        D2 = np.asarray(prob.D.reshape(-1, 20))
        l2 = np.asarray(prob.labels.reshape(-1))
        obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
        per_tau = {}
        for tau in taus:
            res = UnwrappedADMM(loss=make_logistic(), tau=tau).run(
                prob.D, prob.labels, iters=300)
            per_tau[tau] = iters_to_tol(res.history.objective, obj_star)
        best = min(per_tau, key=per_tau.get)
        table[(N, m_per)] = (best, per_tau)
        out_rows.append(
            f"tau_calibration_m{N*m_per},0,best_tau={best};"
            f"iters={per_tau[best]}")
    # m-independence of tau* for unwrapped ADMM (DESIGN.md §3 note)
    bests = [v[0] for v in table.values()]
    out_rows.append(
        f"tau_calibration_summary,0,tau_star_stable={len(set(bests)) <= 2}")
    return table
