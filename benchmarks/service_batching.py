"""Serving-layer benchmark: cached-stats amortization + batched multi-RHS.

Measures the two acceptance properties of the serving subsystem:

  1. factor-cache amortization — a warm fit on an already-registered
     fingerprint spends zero Gram passes (counter-verified) and runs in a
     small fraction of the cold register+fit time;
  2. batched multi-RHS — a 64-request batch completes in well under 64x
     the single-request wall time (BLAS-3 multi-RHS solve + one fused
     D^T B pass instead of 64 separate data passes).

    PYTHONPATH=src python benchmarks/service_batching.py [--rows 50000]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import time_fn  # noqa: E402  (benchmarks/ runs as a script dir)
from repro.service import FitRequest, FitServer


def _serve(srv, reqs):
    # responses hold host numpy arrays, so returning == work complete
    return srv.serve(reqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=50_000)
    ap.add_argument("--features", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    m, n, k = args.rows, args.features, args.batch
    D = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    B = rng.standard_normal((m, k)).astype(np.float32)

    print(f"dataset {m:,} x {n}, batch {k}\n")

    # -- 1. cold vs warm single request ------------------------------------
    srv = FitServer(window=1)
    t0 = time.time()
    fp = srv.register_dataset(D)
    _serve(srv, [FitRequest(problem="ridge", fingerprint=fp, b=B[:, 0],
                            mu=1.0)])
    t_cold = time.time() - t0
    g_after_cold = srv.counters.gram_passes

    def warm_once():
        return _serve(srv, [FitRequest(problem="ridge", fingerprint=fp,
                                       b=B[:, 0], mu=1.0)])

    t_warm, _ = time_fn(warm_once, reps=3, warmup=1)
    assert srv.counters.gram_passes == g_after_cold, \
        "warm fits must not re-run the Gram pass"
    print(f"cold register+fit: {t_cold*1e3:8.1f} ms   (1 Gram pass)")
    print(f"warm fit:          {t_warm*1e3:8.1f} ms   (0 Gram passes, "
          f"{t_cold/max(t_warm,1e-9):.0f}x amortization)")

    # -- 2. batched multi-RHS vs per-request ------------------------------
    srv2 = FitServer(window=k)
    fp2 = srv2.register_dataset(D)

    srv_1 = FitServer(window=1)
    srv_1._datasets = srv2._datasets              # share the cached stats

    def one_by_one():
        out = []
        for j in range(k):
            out.extend(_serve(srv_1, [FitRequest(
                problem="ridge", fingerprint=fp2, b=B[:, j], mu=1.0)]))
        return out

    def batched():
        return _serve(srv2, [FitRequest(problem="ridge", fingerprint=fp2,
                                        b=B[:, j], mu=1.0)
                             for j in range(k)])

    t_batch, resp = time_fn(batched, reps=3, warmup=1)
    t_serial, _ = time_fn(one_by_one, reps=1, warmup=1)
    assert len(resp) == k and resp[0].batch_size == k
    print(f"\n{k} requests, one at a time: {t_serial*1e3:8.1f} ms")
    print(f"{k} requests, micro-batched:  {t_batch*1e3:8.1f} ms "
          f"({t_serial/max(t_batch,1e-9):.1f}x, "
          f"{t_batch/k*1e3:.2f} ms/request)")
    assert t_batch < t_serial, "batching must beat per-request serving"
    print("\ncounters (batched server):", srv2.counters.snapshot())


if __name__ == "__main__":
    main()
