"""Paper Figure 1 (a/b/c): total compute time, transpose vs consensus, as a
function of corpus size (= number of nodes x fixed per-node data).

  fig1a: logistic regression, homogeneous data
  fig1b: SVM, homogeneous data
  fig1c: lasso, heterogeneous data

Emulated node counts are scaled to CPU (paper: 48..7200 cores); the reported
'compute' column is per-iteration wall time x iterations-to-tolerance, the
paper's 'total compute time' notion. The 'paper-scale' column extrapolates
the analytic FLOP model to the paper's configuration of Fig. 1.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.consensus import ConsensusLasso, ConsensusLogistic, ConsensusSVM
from repro.core.fasta import transpose_reduction_lasso
from repro.core import gram as gram_lib
from repro.core.fit import _flops_per_iter
from repro.core.oracles import (
    lasso_objective,
    logistic_objective,
    newton_logistic,
    svm_dual_cd,
    svm_objective,
)
from repro.core.prox import make_hinge, make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem, lasso_problem

from benchmarks.common import iters_to_tol, time_fn

NODE_COUNTS = (2, 4, 8)


def _one_cell(problem: str, N: int, m_per: int, n: int, het: float):
    key = jax.random.PRNGKey(N)
    rows = []
    if problem in ("logistic", "svm"):
        prob = classification_problem(key, N=N, m_per_node=m_per, n=n,
                                      heterogeneity=het)
        D2 = np.asarray(prob.D.reshape(-1, n))
        l2 = np.asarray(prob.labels.reshape(-1))
        if problem == "logistic":
            obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
            tr = UnwrappedADMM(loss=make_logistic(), tau=0.1)
            t_tr, res_t = time_fn(
                lambda: tr.run(prob.D, prob.labels, iters=150), reps=1)
            co = ConsensusLogistic(tau=0.5)
            t_co, res_c = time_fn(
                lambda: co.run(prob.D, prob.labels, iters=150), reps=1)
            objf = lambda x: logistic_objective(D2, l2, np.asarray(x))
        else:
            obj_star = svm_objective(
                D2, l2, svm_dual_cd(D2, l2, 1.0, passes=800), 1.0)
            tr = UnwrappedADMM(loss=make_hinge(1.0), tau=0.5, rho=1.0)
            t_tr, res_t = time_fn(
                lambda: tr.run(prob.D, prob.labels, iters=200), reps=1)
            co = ConsensusSVM(C=1.0, tau=1.0, cd_passes=4)
            t_co, res_c = time_fn(
                lambda: co.run(prob.D, prob.labels, iters=100), reps=1)
            objf = lambda x: svm_objective(D2, l2, np.asarray(x), 1.0)
        it_t = iters_to_tol(res_t.history.objective, obj_star)
        it_c = iters_to_tol(res_c.history.objective, obj_star)
        n_iters_t = len(res_t.history.objective)
        n_iters_c = len(res_c.history.objective)
    else:  # lasso (transpose = §4 direct reduction + FASTA on central node)
        prob = lasso_problem(key, N=N, m_per_node=m_per, n=n,
                             heterogeneity=het)
        Dflat = prob.D.reshape(-1, n)
        bflat = prob.b.reshape(-1)
        D2, b2 = np.asarray(Dflat), np.asarray(bflat)
        mu = float(prob.mu)
        G, c = gram_lib.gram_and_rhs_chunked(Dflat, bflat)
        x_star = np.asarray(
            transpose_reduction_lasso(G, c, mu, iters=4000).x)
        obj_star = lasso_objective(D2, b2, x_star, mu)

        def run_transpose():
            G, c = gram_lib.gram_and_rhs_chunked(Dflat, bflat)
            return transpose_reduction_lasso(G, c, mu, iters=400)

        t_tr, res_t = time_fn(run_transpose, reps=1)
        co = ConsensusLasso(mu=mu, tau=1.0)
        t_co, res_c = time_fn(lambda: co.run(prob.D, prob.b, iters=400),
                              reps=1)
        it_t = iters_to_tol(res_t.objective, obj_star)
        it_c = iters_to_tol(res_c.history.objective, obj_star)
        n_iters_t, n_iters_c = len(res_t.objective), 400
        objf = lambda x: lasso_objective(D2, b2, np.asarray(x), mu)

    m = N * m_per
    comp_t = t_tr * it_t / n_iters_t
    comp_c = t_co * it_c / n_iters_c
    # paper-scale analytic total-compute (FLOPs to tolerance), at this cell
    fl_t = _flops_per_iter(problem, "transpose", N, m_per, n) * it_t
    fl_c = _flops_per_iter(problem, "consensus", N, m_per, n) * it_c
    return {
        "N": N, "m": m, "iters_transpose": it_t, "iters_consensus": it_c,
        "compute_s_transpose": comp_t, "compute_s_consensus": comp_c,
        "flops_transpose": fl_t, "flops_consensus": fl_c,
        "speedup_measured": comp_c / max(comp_t, 1e-12),
        "speedup_flops": fl_c / max(fl_t, 1e-12),
    }


def run(out_rows: list, quick: bool = False):
    cells = [
        ("fig1a_logistic_homo", "logistic", 0.0, 1000, 80),
        ("fig1b_svm_homo", "svm", 0.0, 800, 40),
        ("fig1c_lasso_hetero", "lasso", 1.0, 1000, 80),
    ]
    counts = NODE_COUNTS[:2] if quick else NODE_COUNTS
    results = {}
    for name, problem, het, m_per, n in cells:
        per_n = []
        for N in counts:
            r = _one_cell(problem, N, m_per, n, het)
            per_n.append(r)
            out_rows.append(
                f"{name}_N{N},{r['compute_s_transpose']*1e6:.0f},"
                f"speedup_measured={r['speedup_measured']:.1f}x;"
                f"speedup_flops={r['speedup_flops']:.1f}x;"
                f"iters={r['iters_transpose']}v{r['iters_consensus']}")
        results[name] = per_n
    return results
