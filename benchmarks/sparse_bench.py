"""Sparse transpose-reduction benchmark — the block-CSR data path vs the
dense chunked backend (§Perf, DESIGN.md §10).

Sweeps density ∈ {1%, 5%, 20%, dense} at fixed (m, n) and records, per
cell:

  * ``us_per_iter`` for one donated engine step (x-solve + fused
    iteration body), sparse backend vs dense chunked — the first
    optimization that changes the hot path's ASYMPTOTICS (O(nnz) vs
    O(mn)) rather than its constants;
  * Gram(+RHS) setup time, sparse (host CSR matmul, O(nnz kp)) vs the
    dense chunked stream (O(m n^2)) — including the measured CROSSOVER:
    on CPU the dense MXU-style matmul wins back the Gram above a few
    percent density even though the sparse FLOP count stays lower
    (irregular accumulation runs far below matmul throughput; the JSON
    records both sides so the claim can't silently rot);
  * converged-x parity of a fixed-iteration SVM solve, sparse vs dense —
    measured in f64 (``x_rel_err``: the two formats run the same math,
    so only format bugs survive f64) AND in f32 (``x_rel_err_f32``: the
    production dtype, where summation-order roundoff of the two paths
    floors the comparison around 1e-5 — recorded, not gated).

The SVM hinge loss keeps the prox cost negligible so the data-path
asymptotics dominate what is measured. ``JSON_PATH`` (set by
``benchmarks.run --json``) writes ``BENCH_sparse.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

# f64 parity runs need x64; every timed array below pins float32
# explicitly, so timings are unaffected. (benchmarks.run iterates its
# module dict in insertion order, which lists this module last.)
jax.config.update("jax_enable_x64", True)

from repro.core import gram as gram_lib
from repro.core.prox import make_hinge
from repro.core.unwrapped import UnwrappedADMM
from repro.data.sparse import sparse_classification_problem
from repro.engine import IterationEngine, gram_stats

JSON_PATH = None          # set by benchmarks.run when --json is given

TAU, RHO = 0.5, 1.0       # the SVM calibration (launch/fit._admm_params)
WARMUP = 2
PARITY_ITERS = 100          # past convergence: both formats pin to the
PASS_X_TOL = 1e-5           # same fixed point, leaving pure f32 roundoff


def _engine(backend="auto"):
    return IterationEngine(loss=make_hinge(1.0), tau=TAU, backend=backend)


def _time_step(eng, D, aux, L, iters, batches=3):
    """Median over ``batches`` timed bursts of ``iters`` donated steps —
    a single OS hiccup on a small shared host cannot skew the cell."""
    n = L.shape[0]
    m = D.m if hasattr(D, "m") else D.shape[0]
    step = eng.make_step(D, aux, L)
    y = jnp.zeros((m,), jnp.float32)
    lam = jnp.zeros((m,), jnp.float32)
    d = jnp.zeros((n,), jnp.float32)
    for _ in range(WARMUP):
        y, lam, d, _ = step(y, lam, d)
    jax.block_until_ready((y, lam, d))
    times = []
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(iters):
            y, lam, d, x = step(y, lam, d)
        jax.block_until_ready((y, lam, d))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) / iters * 1e6


def _time_gram(fn, reps=3):
    t0 = time.perf_counter()
    G, _ = fn()                               # warm (compile / first pass)
    jax.block_until_ready(G)
    if time.perf_counter() - t0 > 2.0:
        reps = 1                              # slow cell: one timed rep
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        G, _ = fn()
        jax.block_until_ready(G)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e3


def run(rows, quick: bool = False):
    m, n = ((1 << 14, 256) if quick else (1 << 17, 512))
    densities = [0.01, 0.05, None] if quick else [0.01, 0.05, 0.2, None]
    iters = 3 if quick else 6
    parity_iters = 30 if quick else PARITY_ITERS

    solver_kw = dict(loss=make_hinge(1.0), tau=TAU, rho=RHO)
    records = []
    for density in densities:
        seed = int((density or 1.0) * 1000)
        if density is None:
            # dense anchor cell: Gaussian data, dense path only
            ks = jax.random.split(jax.random.PRNGKey(seed), 2)
            D = jax.random.normal(ks[0], (m, n), jnp.float32)
            aux = jnp.sign(jax.random.normal(ks[1], (m,), jnp.float32))
            bcsr = None
        else:
            prob = sparse_classification_problem(seed, m, n, density)
            bcsr, aux = prob.D, prob.labels
            D = bcsr.to_dense()

        dense_eng = _engine("chunked")
        dense_gram_ms = _time_gram(lambda: dense_eng.gram(D))
        G, _ = dense_eng.gram(D)
        L = gram_lib.gram_factor(G, ridge=RHO / TAU)
        dense_us = _time_step(dense_eng, D, aux, L, iters)

        rec = {"m": m, "n": n, "density": density,
               "dense_us_per_iter": round(dense_us, 1),
               "dense_gram_ms": round(dense_gram_ms, 2)}
        label = f"sparse_m{m}_n{n}_d{density if density else 'dense'}"
        if bcsr is not None:
            sparse_gram_ms = _time_gram(lambda: gram_stats(bcsr, aux))
            sparse_us = _time_step(_engine(), bcsr, aux, L, iters)

            # converged-x parity: same fixed-iteration solve through both
            # formats. f64 isolates FORMAT differences (identical math ->
            # ~1e-12); the f32 rerun records the production-dtype
            # summation-order roundoff floor alongside.
            def _parity(bc, Dd, a):
                rs = UnwrappedADMM(**solver_kw).run(
                    bc, a, iters=parity_iters, record=False)
                rd = UnwrappedADMM(backend="chunked", **solver_kw).run(
                    Dd[None], a[None], iters=parity_iters, record=False)
                return float(jnp.linalg.norm(rs.x - rd.x)
                             / jnp.linalg.norm(rd.x))

            x_rel = _parity(bcsr.astype(jnp.float64),
                            D.astype(jnp.float64),
                            aux.astype(jnp.float64))
            x_rel_f32 = _parity(bcsr, D, aux)
            rec.update({
                "nnz": bcsr.nnz, "kp": bcsr.kp, "kc": bcsr.kc,
                "block_m": bcsr.block_m,
                "sparse_us_per_iter": round(sparse_us, 1),
                "us_iter_speedup": round(dense_us / sparse_us, 3),
                "sparse_gram_ms": round(sparse_gram_ms, 2),
                "gram_speedup": round(dense_gram_ms / sparse_gram_ms, 3),
                "x_rel_err": x_rel,
                "x_rel_err_f32": x_rel_f32,
            })
            rows.append(f"{label},{sparse_us:.1f},"
                        f"x{dense_us / sparse_us:.2f}_vs_dense_chunked")
            rows.append(f"{label}_gram,{sparse_gram_ms * 1e3:.0f},"
                        f"x{dense_gram_ms / sparse_gram_ms:.2f}"
                        f"_vs_dense_chunked")
        else:
            rows.append(f"{label},{dense_us:.1f},dense_anchor")
        records.append(rec)

    if JSON_PATH:
        sparse_cells = [r for r in records
                        if r["density"] is not None
                        and r["density"] <= 0.05]
        best_us = max((r["us_iter_speedup"] for r in sparse_cells),
                      default=None)
        best_gram = max((r["gram_speedup"] for r in sparse_cells),
                        default=None)
        worst_x = max((r["x_rel_err"] for r in sparse_cells),
                      default=None)
        full_point = not quick
        from benchmarks.run import host_meta
        payload = {
            "generated_by": "benchmarks/sparse_bench.py",
            # topology + headline engine backend (the dense baseline
            # cells ran "chunked"; see each point record)
            "executor": "local",
            "backend": "sparse",
            "host_meta": host_meta(),
            "device": jax.devices()[0].device_kind,
            "backend_platform": jax.default_backend(),
            "quick": quick,
            "loss": "hinge (svm calibration: tau=0.5, rho=1)",
            "points": records,
            "acceptance": {
                "criterion": "sparse backend >= 3x us/iter and >= 3x "
                             "Gram setup vs dense chunked at some "
                             "density <= 5% (m=2^17, n=512, CPU); "
                             "converged-x rel err <= 1e-5 (f64 parity; "
                             "the f32 roundoff floor rides along as "
                             "x_rel_err_f32)",
                "us_iter_speedup_best": best_us,
                "gram_speedup_best": best_gram,
                "x_rel_err_max": worst_x,
                "x_rel_err_f32_max": max(
                    (r["x_rel_err_f32"] for r in sparse_cells),
                    default=None),
                # null (not false) when the quick sweep skips the
                # full-size point
                "pass": (best_us is not None and best_us >= 3.0
                         and best_gram >= 3.0
                         and worst_x <= PASS_X_TOL)
                if full_point else None,
            },
        }
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
