"""Paper Appendix B tables: the three sweep axes of the logistic-regression
table — (i) nodes N at fixed per-node data, (ii) rows-per-node at fixed N,
(iii) features at fixed rows — transpose vs consensus compute time."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.consensus import ConsensusLogistic
from repro.core.oracles import logistic_objective, newton_logistic
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem

from benchmarks.common import iters_to_tol, time_fn


def _cell(N, m_per, n, het):
    prob = classification_problem(jax.random.PRNGKey(7), N=N,
                                  m_per_node=m_per, n=n, heterogeneity=het)
    D2 = np.asarray(prob.D.reshape(-1, n))
    l2 = np.asarray(prob.labels.reshape(-1))
    obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
    tr = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    t_t, res_t = time_fn(lambda: tr.run(prob.D, prob.labels, iters=150),
                         reps=1)
    co = ConsensusLogistic(tau=0.5)
    t_c, res_c = time_fn(lambda: co.run(prob.D, prob.labels, iters=100),
                         reps=1)
    it_t = iters_to_tol(res_t.history.objective, obj_star)
    it_c = iters_to_tol(res_c.history.objective, obj_star)
    return (t_t * it_t / 150, t_c * it_c / 100, it_t, it_c)


def run(out_rows: list, quick: bool = False):
    base_N, base_m, base_n = 4, 800, 60
    rows = []
    sweeps = {
        "nodes": [(N, base_m, base_n) for N in ((2, 4) if quick
                                                else (2, 4, 8))],
        "rows": [(base_N, m, base_n) for m in ((400, 800) if quick
                                               else (400, 800, 1600))],
        "features": [(base_N, base_m, n) for n in ((30, 60) if quick
                                                   else (30, 60, 120))],
    }
    for het_name, het in (("homo", 0.0), ("hetero", 1.0)):
        axes = ["nodes"] if het_name == "hetero" and quick else sweeps
        for axis in (sweeps if not quick else {"nodes": sweeps["nodes"]}):
            for (N, m, n) in sweeps[axis]:
                ct, cc, it, ic = _cell(N, m, n, het)
                rows.append((het_name, axis, N, m, n, ct, cc))
                out_rows.append(
                    f"appendix_logreg_{het_name}_{axis}_N{N}_m{m}_F{n},"
                    f"{ct*1e6:.0f},consensus={cc:.2f}s;"
                    f"ratio={cc/max(ct,1e-9):.1f}x;iters={it}v{ic}")
        if het_name == "homo" and quick:
            break
    return rows
