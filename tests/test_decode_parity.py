"""Serving correctness: prefill + decode must continue exactly where the
full forward pass would, for every family (f32 caches for exactness; bf16
caches bounded drift)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ModelConfig
from repro.models.decode import decode_step, init_caches, prefill
from repro.models.model import forward, init_params

jax.config.update("jax_platform_name", "cpu")

COMMON = dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
              vocab_size=128, compute_dtype=jnp.float32, rwkv_head_dim=16,
              rwkv_lora_rank=4, wkv_chunk=4, lru_width=64, window_size=8)

CFGS = [
    ModelConfig(name="dense", family="dense", qk_norm=True, **COMMON),
    ModelConfig(name="moe", family="moe", num_experts=4, experts_per_token=2,
                capacity_factor=8.0, **COMMON),
    ModelConfig(name="rwkv", family="rwkv6", **COMMON),
    ModelConfig(name="grif", family="griffin",
                pattern=("rec", "rec", "attn_local"), **COMMON),
    ModelConfig(name="encdec", family="encdec", encoder_layers=2, **COMMON),
    ModelConfig(name="vlm", family="dense", mrope=True,
                mrope_sections=(2, 3, 3), **COMMON),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_prefill_decode_matches_forward(cfg):
    key = jax.random.PRNGKey(0)
    B, S, SMAX = 2, 12, 20
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 4), 0, 128)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(jax.random.PRNGKey(2),
                                             (B, 8, 64))
    fw_kw = dict(kw)
    if cfg.mrope:
        fw_kw["positions"] = jnp.broadcast_to(
            jnp.arange(S + 4, dtype=jnp.int32), (3, B, S + 4))
    h, _ = forward(params, cfg, tokens=tokens, **fw_kw)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    full_logits = h.astype(jnp.float32) @ head.astype(jnp.float32)

    pf_kw = dict(kw)
    if cfg.mrope:
        pf_kw["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    lg, caches = prefill(params, cfg, tokens=tokens[:, :S], s_max=SMAX,
                         cache_dtype=jnp.float32, **pf_kw)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, S - 1])))]
    for t in range(S, S + 4):
        lg, caches = decode_step(params, cfg, caches, tokens=tokens[:, t],
                                 pos=jnp.asarray(t))
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-4, (cfg.name, errs)


def test_bf16_cache_drift_bounded():
    cfg = CFGS[0]
    key = jax.random.PRNGKey(0)
    B, S = 2, 12
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0, 128)
    h, _ = forward(params, cfg, tokens=tokens)
    head = params["lm_head"]
    full_logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
    lg, caches = prefill(params, cfg, tokens=tokens[:, :S], s_max=S + 2)
    lg, caches = decode_step(params, cfg, caches, tokens=tokens[:, S],
                             pos=jnp.asarray(S))
    err = float(jnp.max(jnp.abs(lg - full_logits[:, S])))
    assert err < 5e-2  # bf16 kv quantization, bounded


def test_local_attn_ring_buffer_wraps():
    """Decode past the window must equal a fresh forward (ring reuse)."""
    cfg = ModelConfig(name="g", family="griffin",
                      pattern=("attn_local",), **{
                          **{k: v for k, v in COMMON.items()
                             if k != "window_size"}, "window_size": 6})
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 1, 16  # > 2x window
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, 128)
    h, _ = forward(params, cfg, tokens=tokens)
    head = params["lm_head"]
    full_logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
    caches = init_caches(cfg, B, s_max=S, dtype=jnp.float32)
    errs = []
    for t in range(S):
        lg, caches = decode_step(params, cfg, caches, tokens=tokens[:, t],
                                 pos=jnp.asarray(t))
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-4, errs
