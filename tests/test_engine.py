"""Iteration-engine backend parity (ISSUE 2 acceptance): the same
(x, history) to tolerance across reference / chunked / pallas-interpret for
lasso, logistic and svm, including bf16 residency and the fused Gram+RHS
kernel, plus the engine-adjacent satellites (solve() warm start, history
without per-iteration x stacking, stats ingest through the engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gram as gram_lib
from repro.core.fasta import transpose_reduction_lasso
from repro.core.prox import (
    StackedProx,
    make_hinge,
    make_huber,
    make_l1,
    make_least_squares,
    make_logistic,
)
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem, lasso_problem
from repro.engine import IterationEngine, autotune, gram_stats
from repro.service.stats import SufficientStats

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("reference", "chunked", "pallas_interpret")


@pytest.fixture(scope="module")
def classif():
    return classification_problem(jax.random.PRNGKey(0), N=4,
                                  m_per_node=250, n=20)


def _rand_state(m, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    D = jax.random.normal(ks[0], (m, n))
    aux = jnp.sign(jax.random.normal(ks[1], (m,)))
    y = jax.random.normal(ks[2], (m,))
    lam = jax.random.normal(ks[3], (m,))
    x = jax.random.normal(ks[4], (n,)) * 0.1
    return D, aux, y, lam, x


# ---------------------------------------------------------------------------
# iterate(): single fused step, all backends, all kernel kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["chunked", "pallas_interpret"])
@pytest.mark.parametrize("loss,tau", [
    (make_logistic(), 0.5), (make_hinge(0.7), 1.0),
    (make_l1(0.3), 1.0), (make_least_squares(), 2.0),
])
def test_iterate_backend_parity(backend, loss, tau):
    m, n = 1234, 40
    D, aux, y, lam, x = _rand_state(m, n)
    a = None if loss.name == "l1" else aux
    ref = IterationEngine(loss=loss, tau=tau, backend="reference").iterate(
        D, a, y, lam, x)
    st = IterationEngine(loss=loss, tau=tau, backend=backend).iterate(
        D, a, y, lam, x)
    scale = float(jnp.max(jnp.abs(ref.d)))
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref.y),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(st.lam), np.asarray(ref.lam),
                               atol=3e-5)
    for got, want in [(st.d, ref.d), (st.w, ref.w), (st.v, ref.v)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-3 * max(scale, 1.0))


@pytest.mark.parametrize("backend", ["chunked", "pallas_interpret"])
def test_iterate_bf16_residency_parity(backend):
    m, n = 2048, 64
    D, aux, y, lam, x = _rand_state(m, n, seed=1)
    loss = make_logistic()
    ref = IterationEngine(loss=loss, tau=0.5, backend="reference").iterate(
        D, aux, y, lam, x)
    eng = IterationEngine(loss=loss, tau=0.5, backend=backend,
                          residency="bf16")
    Dres = eng.prepare(D)
    assert Dres.dtype == jnp.bfloat16
    st = eng.iterate(Dres, aux, y, lam, x)
    assert st.d.dtype == jnp.float32          # f32 in-register accumulation
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref.y),
                               atol=5e-2)
    np.testing.assert_allclose(
        np.asarray(st.d), np.asarray(ref.d),
        atol=2e-2 * float(jnp.max(jnp.abs(ref.d))))


def test_backend_capability_fallbacks():
    # huber has no Pallas prox kind -> chunked; StackedProx is not
    # coordinatewise -> reference (DESIGN.md §8 selection rules).
    assert IterationEngine(loss=make_huber(1.0), tau=1.0,
                           backend="pallas").resolve() == "chunked"
    sp = StackedProx(blocks=(make_l1(0.1), make_logistic()), sizes=(4, 8))
    assert IterationEngine(loss=sp.as_loss(), tau=1.0,
                           backend="chunked").resolve() == "reference"
    with pytest.raises(ValueError):
        IterationEngine(loss=make_logistic(), tau=1.0, backend="cuda")


# ---------------------------------------------------------------------------
# Full solver parity: same (x, history) across backends
# ---------------------------------------------------------------------------

def _run_parity(solver_kw, D, aux, iters, x_rtol=2e-4, obj_rtol=1e-4):
    results = {
        be: UnwrappedADMM(backend=be, **solver_kw).run(D, aux, iters=iters)
        for be in BACKENDS
    }
    ref = results["reference"]
    for be in ("chunked", "pallas_interpret"):
        r = results[be]
        nx = float(jnp.linalg.norm(r.x - ref.x) / jnp.linalg.norm(ref.x))
        assert nx < x_rtol, (be, nx)
        rel = np.max(np.abs(np.asarray(r.history.objective)
                            - np.asarray(ref.history.objective))
                     / np.abs(np.asarray(ref.history.objective)))
        assert rel < obj_rtol, (be, rel)
        np.testing.assert_allclose(np.asarray(r.history.primal_res),
                                   np.asarray(ref.history.primal_res),
                                   atol=1e-3)
    return results


def test_run_backend_parity_logistic(classif):
    _run_parity(dict(loss=make_logistic(), tau=0.1),
                classif.D, classif.labels, iters=60)


def test_run_backend_parity_svm(classif):
    _run_parity(dict(loss=make_hinge(1.0), tau=0.5, rho=1.0),
                classif.D, classif.labels, iters=80)


def test_run_backend_parity_bf16_residency(classif):
    ref = UnwrappedADMM(loss=make_logistic(), tau=0.1,
                        backend="reference").run(
        classif.D, classif.labels, iters=60)
    r = UnwrappedADMM(loss=make_logistic(), tau=0.1, backend="chunked",
                      residency="bf16").run(
        classif.D, classif.labels, iters=60)
    nx = float(jnp.linalg.norm(r.x - ref.x) / jnp.linalg.norm(ref.x))
    assert nx < 5e-3, nx


def test_lasso_gram_backend_parity():
    """lasso rides the engine's Gram path: identical stats -> identical
    FASTA solution across backends."""
    prob = lasso_problem(jax.random.PRNGKey(1), N=2, m_per_node=400, n=48)
    Dflat = prob.D.reshape(-1, 48)
    bflat = prob.b.reshape(-1)
    sols = {}
    for be in BACKENDS:
        G, c = gram_stats(Dflat, bflat, backend=be)
        sols[be] = np.asarray(
            transpose_reduction_lasso(G, c, float(prob.mu), iters=1500).x)
    for be in ("chunked", "pallas_interpret"):
        np.testing.assert_allclose(sols[be], sols["reference"],
                                   rtol=1e-3, atol=1e-5)


def test_fused_gram_rhs_kernel_multi_rhs():
    """Fused Gram+RHS Pallas kernel vs gram_and_rhs_chunked, (m,) and
    (m, r) right-hand sides, f32 and bf16 row streams."""
    for (m, n, r, dt) in [(700, 96, 0, jnp.float32), (513, 33, 5,
                                                      jnp.float32),
                          (256, 140, 2, jnp.bfloat16)]:
        D = jax.random.normal(jax.random.PRNGKey(2), (m, n), dt)
        b = jax.random.normal(jax.random.PRNGKey(3),
                              (m, r) if r else (m,))
        G1, c1 = gram_stats(D, b, backend="pallas_interpret")
        G2, c2 = gram_stats(D, b, backend="chunked")
        tol = dict(rtol=2e-2, atol=1e-2) if dt == jnp.bfloat16 else dict(
            rtol=3e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(G1), np.asarray(G2), **tol)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), **tol)
        assert c1.shape == ((n, r) if r else (n,))


# ---------------------------------------------------------------------------
# Padding edges: m not divisible by the block size (chunked + pallas).
# The prox of a padded zero row may be nonzero (e.g. logistic at z=0 has
# curvature) but its D row is zero, so NOTHING may leak into the d/w/v
# reductions.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["chunked", "pallas_interpret"])
@pytest.mark.parametrize("loss,tau", [(make_logistic(), 0.5),
                                      (make_hinge(0.7), 1.0)])
@pytest.mark.parametrize("m", [1000, 1023, 1025])
def test_padding_edges_no_leak(backend, loss, tau, m):
    n = 32
    block = 256                       # never divides any of the m values
    assert m % block != 0
    D, aux, y, lam, x = _rand_state(m, n, seed=m)
    ref = IterationEngine(loss=loss, tau=tau, backend="reference").iterate(
        D, aux, y, lam, x)
    st = IterationEngine(loss=loss, tau=tau, backend=backend,
                         block_m=block).iterate(D, aux, y, lam, x)
    scale = max(float(jnp.max(jnp.abs(ref.d))), 1.0)
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref.y),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(st.lam), np.asarray(ref.lam),
                               atol=3e-5)
    for got, want in [(st.d, ref.d), (st.w, ref.w), (st.v, ref.v)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-3 * scale)
    # iterates keep exactly m rows (padding never escapes the engine)
    assert st.y.shape == (m,) and st.lam.shape == (m,)


def test_transpose_d_streams_without_dense_copy(monkeypatch):
    """transpose_d routes through the backend-dispatched streaming pass:
    the chunked/pallas engines must NOT call the dense gram_rhs (which
    materializes a full accumulation-precision copy of D)."""
    m, n = 700, 24
    D, _, y, lam, _ = _rand_state(m, n, seed=7)
    want = np.asarray(gram_lib.gram_rhs(D, y - lam))
    for backend in ("chunked", "pallas_interpret"):
        eng = IterationEngine(loss=make_logistic(), tau=1.0,
                              backend=backend)
        np.testing.assert_allclose(np.asarray(eng.transpose_d(D, y, lam)),
                                   want, rtol=1e-5, atol=1e-4)
    from repro.engine import engine as engine_mod

    def boom(*a, **k):
        raise AssertionError("dense gram_rhs called from a streaming "
                             "backend")

    monkeypatch.setattr(engine_mod.gram_lib, "gram_rhs", boom)
    eng = IterationEngine(loss=make_logistic(), tau=1.0, backend="chunked")
    eng.transpose_d(D, y, lam)        # streams: must not hit the dense path
    with pytest.raises(AssertionError, match="dense gram_rhs"):
        IterationEngine(loss=make_logistic(), tau=1.0,
                        backend="reference").transpose_d(D, y, lam)


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

def test_solve_honors_warm_start(classif):
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    cold = solver.solve(classif.D, classif.labels, max_iters=300)
    warm = solver.solve(classif.D, classif.labels, max_iters=300, x0=cold.x)
    # the warm start threads through: converged, and to the same optimum
    assert int(warm.iters) < 300
    nx = float(jnp.linalg.norm(warm.x - cold.x)
               / jnp.linalg.norm(cold.x))
    assert nx < 5e-3, nx
    # and x0 actually changes the trajectory (first x-update starts at x0):
    # a one-iteration warm solve must differ from a one-iteration cold one.
    w1 = solver.run(classif.D, classif.labels, iters=1, x0=cold.x)
    c1 = solver.run(classif.D, classif.labels, iters=1)
    assert float(jnp.linalg.norm(w1.x - c1.x)) > 1e-3


def test_history_final_x_from_carry(classif):
    """History carries scalars only — no (iters, n) x stacking — while the
    final x still matches the recorded trajectory's endpoint."""
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    res = solver.run(classif.D, classif.labels, iters=40)
    assert res.x.shape == (20,)
    assert set(res.history._fields) == {
        "objective", "primal_res", "dual_res", "grad_sq", "converged_at"}
    for field in ("objective", "primal_res", "dual_res", "grad_sq"):
        assert getattr(res.history, field).shape == (40,)
    # final objective consistent with the returned x
    obj_from_x = float(solver._objective(
        res.x,
        jnp.einsum("imn,n->im", classif.D, res.x).reshape(-1),
        classif.labels.reshape(-1)))
    assert abs(obj_from_x - float(res.history.objective[-1])) \
        < 1e-3 * abs(obj_from_x)
    assert solver.run(classif.D, classif.labels, iters=5,
                      record=False).history is None


def test_stats_ingest_backend_parity():
    D = jax.random.normal(jax.random.PRNGKey(4), (600, 32))
    b = jax.random.normal(jax.random.PRNGKey(5), (600,))
    s_chunked = SufficientStats.from_data(D, b, backend="chunked")
    s_pallas = SufficientStats.from_data(D, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(s_pallas.G),
                               np.asarray(s_chunked.G),
                               rtol=3e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_pallas.c),
                               np.asarray(s_chunked.c),
                               rtol=3e-5, atol=1e-3)
    assert s_pallas.fingerprint == s_chunked.fingerprint
    # streaming update still goes through the engine path
    s2 = s_chunked.update(D[:100], b[:100])
    ref = np.asarray(s_chunked.G) + np.asarray(D[:100].T @ D[:100])
    np.testing.assert_allclose(np.asarray(s2.G), ref, rtol=1e-5, atol=1e-3)


def test_autotune_blocks_are_sane():
    bm = autotune.iter_block_m(1 << 20, 512, jnp.float32)
    assert 128 <= bm <= 4096 and bm % 8 == 0
    # never taller than the (padded) row count
    assert autotune.iter_block_m(300, 64, jnp.float32) <= 304
    gm, gn = autotune.gram_blocks(1 << 20, 512, jnp.bfloat16)
    assert gn % 128 == 0 and gm % 16 == 0
    assert autotune.chunked_block_rows(1 << 20, 512, jnp.float32) % 8 == 0
    # memoized: same key -> same object
    key = ("iter", 1 << 20, 512, "float32")
    assert key in autotune.CACHE
