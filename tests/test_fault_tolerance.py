"""Fault tolerance end-to-end: kill training mid-run, restart from the
checkpoint, and converge to the same result as an uninterrupted run.
Also: deterministic data pipeline + elastic repartitioning."""
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.data.pipeline import TokenPipeline

ROOT = str(Path(__file__).parent.parent)


def _run_train(args, check=True):
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    p = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/tmp"}, timeout=900)
    if check:
        assert p.returncode == 0, p.stderr[-2000:]
    return p


def _final_loss(stdout):
    m = re.search(r"\[done\] final loss ([0-9.]+)", stdout)
    assert m, stdout[-2000:]
    return float(m.group(1))


@pytest.mark.slow
def test_kill_and_restart_reproduces_run(tmp_path):
    common = ["--arch", "qwen3-8b", "--smoke", "--steps", "24",
              "--batch", "2", "--seq", "32", "--ckpt-every", "8",
              "--lr", "1e-3"]
    # uninterrupted reference
    ref = _run_train(common + ["--ckpt-dir", str(tmp_path / "ref")])
    ref_loss = _final_loss(ref.stdout)
    # killed at step 12 (after the step-8 checkpoint), then resumed
    crash = _run_train(common + ["--ckpt-dir", str(tmp_path / "ft"),
                                 "--die-at-step", "12"], check=False)
    assert crash.returncode != 0  # SIGKILL
    resumed = _run_train(common + ["--ckpt-dir", str(tmp_path / "ft")])
    assert "[resume] restored step" in resumed.stdout
    res_loss = _final_loss(resumed.stdout)
    # bitwise-identical batches + state restore => same trajectory
    np.testing.assert_allclose(res_loss, ref_loss, rtol=1e-5)


def test_pipeline_determinism_and_restart():
    pipe = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    a = pipe.batch_at(5)
    b = pipe.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # a "restarted" pipeline object reproduces the same stream
    pipe2 = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    np.testing.assert_array_equal(pipe2.batch_at(5)["tokens"], a["tokens"])


def test_pipeline_elastic_repartition():
    """The same global batch, split across 2 vs 4 workers, is identical data
    — elastic rescale only changes placement."""
    pipe = TokenPipeline(vocab_size=50, global_batch=8, seq_len=4, seed=1)
    g = pipe.batch_at(0)["tokens"]
    two = np.split(g, 2)
    four = np.split(g, 4)
    np.testing.assert_array_equal(np.concatenate(two),
                                  np.concatenate(four))


def test_pipeline_prefetch_iterator():
    pipe = TokenPipeline(vocab_size=50, global_batch=4, seq_len=8, seed=0)
    it = pipe.shard_iterator(start_step=10)
    step, batch = next(it)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  pipe.batch_at(10)["tokens"])
    step, _ = next(it)
    assert step == 11
