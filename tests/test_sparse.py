"""Sparse block-CSR data path (ISSUE 4): container round trips, engine
backend parity across every kernel prox kind (single step + full solve +
history), edge cases (zero-nnz blocks, duplicate column indices,
m % block_m tails), the nnz-scaled store (RAM + mmap round trip,
fingerprint reuse in SufficientStats.from_store), the streaming sparse
solve, and the engine-adjacent satellites (rmatvec-routed grad_sq
telemetry, residency="auto" resolution)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gram as gram_lib
from repro.core.prox import (
    make_hinge,
    make_l1,
    make_least_squares,
    make_logistic,
)
from repro.core.unwrapped import UnwrappedADMM
from repro.data.sparse import (
    BlockCSR,
    random_block_csr,
    sparse_classification_problem,
    sparse_lasso_problem,
)
from repro.data.store import ShardedMatrixStore, fingerprint_array
from repro.engine import IterationEngine, autotune, gram_stats
from repro.kernels.spgram import ops as spgram_ops
from repro.kernels.spgram import ref as spgram_ref
from repro.service.stats import SufficientStats

jax.config.update("jax_platform_name", "cpu")

LOSSES = [(make_logistic(), 0.5), (make_hinge(0.7), 1.0),
          (make_l1(0.3), 1.0), (make_least_squares(), 2.0)]


@pytest.fixture(scope="module")
def classif():
    # m % block_m != 0 on purpose: every fixture consumer crosses a tail
    return sparse_classification_problem(0, 1100, 24, 0.15, block_m=256)


def _rand_state(m, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    y = jax.random.normal(ks[0], (m,))
    lam = jax.random.normal(ks[1], (m,))
    x = jax.random.normal(ks[2], (n,)) * 0.1
    return y, lam, x


# ---------------------------------------------------------------------------
# container: conversion, padding, duplicates, zero blocks
# ---------------------------------------------------------------------------

def test_dense_round_trip_and_properties():
    rng = np.random.default_rng(0)
    D = rng.standard_normal((137, 23)).astype(np.float32)
    D[rng.random((137, 23)) < 0.8] = 0
    b = BlockCSR.from_dense(D, block_m=48)
    np.testing.assert_array_equal(np.asarray(b.to_dense()), D)
    assert b.shape == (137, 23)
    assert b.nnz == int(np.count_nonzero(D))
    assert b.nblocks == 3 and b.block_m == 48        # 137 -> 3 x 48 tail-padded
    assert abs(b.density - b.nnz / (137 * 23)) < 1e-12
    # pad slots carry value 0 (the exactness contract)
    val = np.asarray(b.values)
    assert val.shape[0] * val.shape[1] == 144        # padded row count


def test_duplicate_column_indices_sum():
    """Duplicates are COO semantics: they SUM, in to_dense and in every
    reduction (gathers sum the slots; scatter-free by construction)."""
    rows = np.array([0, 0, 0, 1, 2, 2])
    cols = np.array([1, 1, 3, 0, 2, 2])
    vals = np.array([1.0, 2.0, 4.0, 5.0, 3.0, -1.0], np.float32)
    b = BlockCSR.from_coo(rows, cols, vals, m=3, n=4, block_m=2)
    want = np.array([[0, 3, 0, 4], [5, 0, 0, 0], [0, 0, 2, 0]], np.float32)
    np.testing.assert_array_equal(np.asarray(b.to_dense()), want)
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    np.testing.assert_allclose(np.asarray(spgram_ops.matvec(b, x)),
                               want @ np.asarray(x), rtol=1e-6)
    u = jnp.asarray([1.0, -2.0, 0.5])
    np.testing.assert_allclose(np.asarray(spgram_ops.rmatvec(b, u)),
                               want.T @ np.asarray(u), rtol=1e-6)
    G, c = gram_stats(b, u)
    np.testing.assert_allclose(np.asarray(G), want.T @ want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), want.T @ np.asarray(u),
                               rtol=1e-5, atol=1e-5)


def test_zero_nnz_blocks_and_empty_matrix():
    """A block of all-zero rows (and a fully empty matrix) must be legal:
    pad slots only, nothing leaks into any reduction."""
    D = np.zeros((300, 8), np.float32)
    D[250:, :2] = 1.0                    # blocks 0 and 1 are zero-nnz
    b = BlockCSR.from_dense(D, block_m=100)
    np.testing.assert_array_equal(np.asarray(b.to_dense()), D)
    empty = BlockCSR.from_dense(np.zeros((64, 8), np.float32), block_m=32)
    assert empty.nnz == 0
    np.testing.assert_array_equal(np.asarray(empty.to_dense()), 0)
    G, _ = gram_stats(empty)
    np.testing.assert_array_equal(np.asarray(G), 0)
    y, lam, x = _rand_state(300, 8)
    eng = IterationEngine(loss=make_l1(0.3), tau=1.0)
    ref = eng.iterate(jnp.asarray(D), None, y, lam, x)
    st = eng.iterate(b, None, y, lam, x)
    for got, want in zip(st, ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# engine parity: fused sparse body vs dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss,tau", LOSSES,
                         ids=[l.name for l, _ in LOSSES])
def test_iterate_sparse_parity(classif, loss, tau):
    bcsr, labels = classif.D, classif.labels
    Dd = bcsr.to_dense()
    m, n = bcsr.shape
    assert m % bcsr.block_m != 0          # tail block in play
    y, lam, x = _rand_state(m, n, seed=7)
    a = None if loss.name == "l1" else labels
    ref = IterationEngine(loss=loss, tau=tau, backend="reference").iterate(
        Dd, a, y, lam, x)
    st = IterationEngine(loss=loss, tau=tau).iterate(bcsr, a, y, lam, x)
    scale = max(float(jnp.max(jnp.abs(ref.d))), 1.0)
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref.y),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(st.lam), np.asarray(ref.lam),
                               atol=3e-5)
    for got, want in [(st.d, ref.d), (st.w, ref.w), (st.v, ref.v)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-3 * scale)
    assert st.y.shape == (m,) and st.lam.shape == (m,)
    # backend="reference" on sparse input densifies (the parity oracle)
    ref2 = IterationEngine(loss=loss, tau=tau,
                           backend="reference").iterate(bcsr, a, y, lam, x)
    np.testing.assert_allclose(np.asarray(ref2.d), np.asarray(ref.d),
                               rtol=1e-6, atol=1e-6)


def test_gram_backend_parity(classif):
    bcsr, labels = classif.D, classif.labels
    G, c = gram_stats(bcsr, labels)
    Gr = spgram_ref.gram_ref(bcsr)
    cr = spgram_ref.gram_rhs_ref(bcsr, labels)
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-5, atol=1e-3)
    # multi-RHS rides the same pass
    B = jax.random.normal(jax.random.PRNGKey(1), (bcsr.m, 3))
    _, C = gram_stats(bcsr, B)
    np.testing.assert_allclose(np.asarray(C),
                               np.asarray(spgram_ref.gram_rhs_ref(bcsr, B)),
                               rtol=1e-5, atol=1e-3)
    # the jit-safe scatter fallback agrees with the host path
    from repro.kernels.spgram import ops as ops_mod
    acc = gram_lib._acc_dtype(bcsr.dtype)
    np.testing.assert_allclose(np.asarray(ops_mod._gram_fallback(bcsr, acc)),
                               np.asarray(G), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("problem", ["logistic", "svm", "least_squares"])
def test_run_parity_solve_and_history(classif, problem):
    """Full fixed-iteration solve: same (x, history) sparse vs dense."""
    bcsr, labels = classif.D, classif.labels
    Dd = bcsr.to_dense()[None]
    kw = {"logistic": dict(loss=make_logistic(), tau=0.1),
          "svm": dict(loss=make_hinge(1.0), tau=0.5, rho=1.0),
          "least_squares": dict(loss=make_least_squares(), tau=1.0),
          }[problem]
    rs = UnwrappedADMM(**kw).run(bcsr, labels, iters=40)
    rd = UnwrappedADMM(backend="chunked", **kw).run(Dd, labels[None],
                                                    iters=40)
    nx = float(jnp.linalg.norm(rs.x - rd.x) / jnp.linalg.norm(rd.x))
    assert nx < 2e-4, nx
    np.testing.assert_allclose(np.asarray(rs.history.objective),
                               np.asarray(rd.history.objective),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(rs.history.primal_res),
                               np.asarray(rd.history.primal_res),
                               atol=1e-3)
    assert rs.y.shape == (1, bcsr.m)      # N=1 stacking convention


def test_l1_lasso_through_stats(classif):
    """Sparse lasso rides the stats path: identical FASTA solution."""
    from repro.core.fasta import transpose_reduction_lasso
    prob = sparse_lasso_problem(2, 800, 32, 0.1)
    stats = SufficientStats.from_data(prob.D, prob.b)
    G, c = gram_stats(prob.D, prob.b, backend="reference")  # densified
    xs = transpose_reduction_lasso(stats.G, stats.c, float(prob.mu),
                                   iters=800).x
    xd = transpose_reduction_lasso(G, c, float(prob.mu), iters=800).x
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xd),
                               rtol=1e-4, atol=1e-6)


def test_solve_sparse_stopping_and_warm_start(classif):
    bcsr, labels = classif.D, classif.labels
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    cold = solver.solve(bcsr, labels, max_iters=300)
    assert int(cold.iters) < 300
    dense = UnwrappedADMM(loss=make_logistic(), tau=0.1,
                          backend="chunked").solve(
        bcsr.to_dense()[None], labels[None], max_iters=300)
    # same stopping rule; a few iterations of slack is the documented
    # backend behavior (DESIGN.md §3: f32 prox noise floors the dual
    # residual, so the dual test crosses on noise dips)
    assert abs(int(cold.iters) - int(dense.iters)) <= 5
    nx = float(jnp.linalg.norm(cold.x - dense.x)
               / jnp.linalg.norm(dense.x))
    assert nx < 1e-4, nx
    # x0 threads through: one warm iteration differs from one cold one
    w1 = solver.run(bcsr, labels, iters=1, x0=cold.x, record=False)
    c1 = solver.run(bcsr, labels, iters=1, record=False)
    assert float(jnp.linalg.norm(w1.x - c1.x)) > 1e-3


def test_residency_bf16_values_only(classif):
    eng = IterationEngine(loss=make_logistic(), tau=0.5,
                          residency="bf16")
    bres = eng.prepare(classif.D)
    assert bres.values.dtype == jnp.bfloat16
    assert bres.col_values.dtype == jnp.bfloat16
    assert bres.indices.dtype == jnp.int32
    y, lam, x = _rand_state(classif.D.m, classif.D.n)
    st = eng.iterate(bres, classif.labels, y, lam, x)
    assert st.d.dtype == jnp.float32      # f32 accumulation contract


# ---------------------------------------------------------------------------
# store: nnz-scaled blocks, mmap round trip, fingerprint reuse
# ---------------------------------------------------------------------------

def test_sparse_store_round_trip(tmp_path, classif):
    bcsr, labels = classif.D, classif.labels
    ram = ShardedMatrixStore.from_sparse(bcsr, labels)
    assert ram.sparse and ram.m == bcsr.m and ram.nblocks == bcsr.nblocks
    # store bytes scale with nnz, not m*n (asserted at a realistic
    # density/width — the tiny fixture is dominated by padding slack)
    low = random_block_csr(3, 4000, 256, 0.02)
    assert ShardedMatrixStore.from_sparse(low).nbytes \
        < 0.25 * low.m * low.n * 4
    disk = ShardedMatrixStore.open(ram.save(str(tmp_path / "s")))
    assert disk.sparse and disk.fingerprints == ram.fingerprints
    assert disk.sparse_meta == ram.sparse_meta
    # blocks reassemble exactly (RAM and mmap alike)
    for store in (ram, disk):
        parts = []
        for k in range(store.nblocks):
            D_b, a_b = store.block(k, padded=False)
            sl = store.block_slice(k)
            assert D_b.m == sl.stop - sl.start == a_b.shape[0]
            parts.append(np.asarray(D_b.to_dense()))
        np.testing.assert_array_equal(np.concatenate(parts),
                                      np.asarray(bcsr.to_dense()))
    # padded read keeps static shapes with the tail's logical m widened
    D_p, a_p = disk.block(disk.nblocks - 1, padded=True)
    assert D_p.m == disk.block_rows and a_p.shape == (disk.block_rows,)


def test_sparse_store_fingerprint_reuse(tmp_path, classif, monkeypatch):
    """from_store folds the store's write-time fingerprints — it must
    never re-hash block content (on a real store that pass costs as much
    as the Gram itself)."""
    bcsr, labels = classif.D, classif.labels
    store = ShardedMatrixStore.open(
        ShardedMatrixStore.from_sparse(bcsr, labels).save(
            str(tmp_path / "s")))
    ref = SufficientStats.from_data(bcsr, labels)     # hashes, by design
    import repro.service.stats as stats_mod

    def boom(*a, **k):
        raise AssertionError("from_store re-hashed a block")

    monkeypatch.setattr(stats_mod, "fingerprint_array", boom)
    s = SufficientStats.from_store(store)
    assert s.fingerprint == store.fingerprint
    assert s.rows == bcsr.m and s.labeled_rows == bcsr.m
    np.testing.assert_allclose(np.asarray(s.G), np.asarray(ref.G),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s.c), np.asarray(ref.c),
                               rtol=1e-5, atol=1e-3)


def test_sparse_store_downdate_cancels_fingerprint(classif):
    """Retiring every store-ingested block must restore the ZERO stats
    exactly, fingerprint included — store write-time hashes and
    update/downdate content hashes are the same canonical (rows, kp)
    form (a shape-dependent hash of the one-block view would leave a
    non-cancelling fingerprint)."""
    bcsr, labels = classif.D, classif.labels
    store = ShardedMatrixStore.from_sparse(bcsr, labels)
    stats = SufficientStats.from_store(store)
    for k in range(store.nblocks):
        D_b, a_b = store.block(k, padded=False)
        stats = stats.downdate(D_b, jnp.asarray(a_b))
    zero = SufficientStats.zero(bcsr.n)
    assert stats.fingerprint == zero.fingerprint
    assert stats.rows == 0 and stats.labeled_rows == 0
    np.testing.assert_allclose(np.asarray(stats.G), 0, atol=1e-2)


def test_sparse_streaming_solve_parity(tmp_path, classif):
    """solve_streaming over a sparse mmap store == in-memory sparse
    solve (same stopping rule, same x), warm start included."""
    bcsr, labels = classif.D, classif.labels
    store = ShardedMatrixStore.open(
        ShardedMatrixStore.from_sparse(bcsr, labels).save(
            str(tmp_path / "s")))
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    mem = solver.solve(bcsr, labels, max_iters=200)
    stream = solver.solve_streaming(store, max_iters=200, record=True)
    # block-ordered summation may cross the noise-floored dual test a
    # step apart (DESIGN.md §3)
    assert abs(int(stream.iters) - int(mem.iters)) <= 2
    nx = float(jnp.linalg.norm(stream.x - mem.x)
               / jnp.linalg.norm(mem.x))
    assert nx < 1e-5, nx
    warm = solver.solve_streaming(store, max_iters=200, x0=mem.x)
    assert int(warm.iters) <= int(mem.iters) + 2


# ---------------------------------------------------------------------------
# satellites: grad_sq telemetry routing, residency="auto", autotune
# ---------------------------------------------------------------------------

def test_grad_sq_streams_without_dense_upcast(classif, monkeypatch):
    """run()'s grad_sq telemetry routes through the engine's rmatvec: on
    streaming-class backends the dense gram_rhs (which materializes a
    full accumulation-precision copy of D) must never be hit; the
    reference backend still uses it."""
    prob = sparse_classification_problem(5, 700, 16, 0.2, block_m=128)
    D3 = prob.D.to_dense()[None]
    from repro.engine import engine as engine_mod

    def boom(*a, **k):
        raise AssertionError("dense gram_rhs called from a streaming "
                             "backend")

    monkeypatch.setattr(engine_mod.gram_lib, "gram_rhs", boom)
    # distinctive tau so no earlier trace of this config is cached
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.07,
                           backend="chunked")
    res = solver.run(D3, prob.labels[None], iters=3, record=True)
    assert np.isfinite(np.asarray(res.history.grad_sq)).all()
    sp = UnwrappedADMM(loss=make_logistic(), tau=0.07)
    res = sp.run(prob.D, prob.labels, iters=3, record=True)
    assert np.isfinite(np.asarray(res.history.grad_sq)).all()
    with pytest.raises(AssertionError, match="dense gram_rhs"):
        UnwrappedADMM(loss=make_logistic(), tau=0.07,
                      backend="reference").run(D3, prob.labels[None],
                                               iters=3, record=True)


def test_residency_auto_resolution():
    """DESIGN.md §8 rule: auto -> None on CPU/chunked backends (bf16 is a
    measured slowdown there), bf16 only on real-TPU pallas; explicit
    bf16 stays honored as-is."""
    auto = IterationEngine(loss=make_logistic(), tau=1.0,
                           residency="auto")
    # on this CPU host auto resolves to chunked -> residency None
    assert auto.resolve() in ("chunked", "pallas")
    if auto.resolve() == "chunked":
        assert auto.resolve_residency() is None
        D = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        assert auto.prepare(D).dtype == jnp.float32
    explicit = IterationEngine(loss=make_logistic(), tau=1.0,
                               residency="bf16")
    assert explicit.resolve_residency() == "bf16"
    D = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    assert explicit.prepare(D).dtype == jnp.bfloat16
    interp = IterationEngine(loss=make_logistic(), tau=1.0,
                             backend="pallas_interpret", residency="auto")
    assert interp.resolve_residency() is None    # interpret mode is CPU
    with pytest.raises(ValueError):
        IterationEngine(loss=make_logistic(), tau=1.0, residency="fp8")


def test_sparse_autotune_blocks():
    bm = autotune.sparse_block_m(1 << 17, 512, 26, jnp.float32)
    assert 1024 <= bm <= 16384 and bm % 8 == 0
    # denser rows -> shorter blocks (nnz-budgeted, not (m x n)-budgeted)
    assert autotune.sparse_block_m(1 << 17, 512, 128, jnp.float32) < bm
    # never taller than the padded row count
    assert autotune.sparse_block_m(300, 64, 4, jnp.float32) <= 304
    assert ("sparse", 1 << 17, 512, 26, "float32") in autotune.CACHE


def test_generators_hit_requested_density():
    b = random_block_csr(0, 4000, 64, 0.05)
    assert abs(b.density - 0.05) < 0.01
    prob = sparse_classification_problem(1, 2000, 32, 0.1)
    assert set(np.unique(np.asarray(prob.labels))) <= {-1.0, 1.0}
    assert abs(prob.D.density - 0.1) < 0.02
