"""End-to-end behaviour tests for the paper's system.

The paper's deliverable is distributed model fitting: these tests run the
whole stack — synthetic corpus -> transpose-reduction ADMM fit -> accuracy —
plus the LM-framework integration (linear probe on frozen transformer
features, the DESIGN.md §4 composition).
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs_lib
from repro.core.fit import fit
from repro.core.oracles import logistic_objective, newton_logistic
from repro.data.synthetic import classification_problem, star_catalog_problem
from repro.models.model import forward, init_params

jax.config.update("jax_platform_name", "cpu")


def test_e2e_fit_all_problems_transpose_vs_consensus():
    """fit() end-to-end on all four problems, both methods."""
    cp = classification_problem(jax.random.PRNGKey(0), N=4, m_per_node=200,
                                n=16)
    from repro.data.synthetic import lasso_problem
    lp = lasso_problem(jax.random.PRNGKey(1), N=4, m_per_node=200, n=16)
    for problem, D, aux, kw in [
        ("logistic", cp.D, cp.labels, {}),
        ("svm", cp.D, cp.labels, {}),
        ("sparse_logistic", cp.D, cp.labels, {"mu": 2.0}),
        ("lasso", lp.D, lp.b, {"mu": float(lp.mu)}),
    ]:
        for method in ("transpose", "consensus"):
            r = fit(problem, D, aux, method=method, iters=150, **kw)
            assert np.isfinite(float(r.objective_history[-1])), \
                (problem, method)


def test_e2e_star_catalog_analogue():
    """§10.2 analogue: 307-feature interaction matrix, sparse logistic fit,
    classifies 'stars' well above chance."""
    prob = star_catalog_problem(jax.random.PRNGKey(2), N=4, m_per_node=400)
    n = prob.D.shape[-1]
    assert n == 307  # 17 + 17*18/2 + bias
    r = fit("sparse_logistic", prob.D, prob.labels, mu=2.0, iters=250)
    D2 = np.asarray(prob.D.reshape(-1, n))
    l2 = np.asarray(prob.labels.reshape(-1))
    acc = float(np.mean(np.sign(D2 @ np.asarray(r.x)) == l2))
    assert acc > 0.75, acc
    # l1 actually sparsifies
    nnz = int((np.abs(np.asarray(r.x)) > 1e-5).sum())
    assert nnz < n


def test_e2e_linear_probe_on_transformer_features():
    """DESIGN.md §4: the ADMM fitter consumes frozen LM features as D —
    the probe must beat chance at predicting a feature-linear label."""
    cfg = configs_lib.get_smoke("qwen3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    B, S = 8, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _ = forward(params, cfg, tokens=tokens)      # (B, S, d) frozen feats
    feats = np.asarray(h.reshape(-1, cfg.d_model), np.float32)
    feats = feats / (np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6)
    w_true = np.random.default_rng(0).standard_normal(cfg.d_model)
    labels = np.sign(feats @ w_true + 0.1 * np.random.default_rng(1)
                     .standard_normal(feats.shape[0])).astype(np.float32)
    D = jnp.asarray(feats).reshape(4, -1, cfg.d_model)   # 4 virtual nodes
    aux = jnp.asarray(labels).reshape(4, -1)
    r = fit("logistic", D, aux, iters=150)
    acc = float(np.mean(np.sign(feats @ np.asarray(r.x)) == labels))
    assert acc > 0.9, acc


def test_e2e_flop_accounting_sanity():
    """The analytic per-iteration FLOP model orders methods correctly:
    consensus logistic (inner Newton) >> transpose per iteration."""
    from repro.core.fit import _flops_per_iter
    ft = _flops_per_iter("logistic", "transpose", N=100, mi=50000, n=2000)
    fc = _flops_per_iter("logistic", "consensus", N=100, mi=50000, n=2000)
    assert fc > 50 * ft
