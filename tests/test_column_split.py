"""Paper §7.1: the column-split dual lasso recovers the row-split solution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.core.column_split import lasso_column_split
from repro.core.fasta import transpose_reduction_lasso
from repro.core.oracles import lasso_kkt_gap, lasso_objective

jax.config.update("jax_platform_name", "cpu")


def _wide_problem(key, m=60, n=160, active=8):
    kD, kx, ke = jax.random.split(key, 3)
    D = jax.random.normal(kD, (m, n)) / jnp.sqrt(m * 1.0)
    x_true = jnp.zeros((n,)).at[
        jax.random.permutation(kx, n)[:active]].set(1.0)
    b = D @ x_true + 0.05 * jax.random.normal(ke, (m,))
    mu = 0.1 * float(jnp.max(jnp.abs(D.T @ b)))
    return D, b, mu


def test_dual_column_split_matches_primal():
    D, b, mu = _wide_problem(jax.random.PRNGKey(0))
    m, n = D.shape
    # row-split / §4 reference on the same problem
    G, c = gram_lib.gram_and_rhs_chunked(D, b, block_rows=32)
    x_ref = np.asarray(transpose_reduction_lasso(G, c, mu, iters=5000).x)
    obj_ref = lasso_objective(np.asarray(D), np.asarray(b), x_ref, mu)
    # column-split dual (4 nodes x 40 columns)
    D_cols = jnp.stack(jnp.split(D, 4, axis=1))
    res = lasso_column_split(D_cols, b, mu, tau=1.0, iters=2000)
    x = np.asarray(res.x)
    obj = lasso_objective(np.asarray(D), np.asarray(b), x, mu)
    assert obj - obj_ref < 5e-3 * abs(obj_ref) + 1e-6, (obj, obj_ref)
    # dual feasibility: ||D^T alpha||_inf <= mu (+tol)
    corr = np.asarray(D).T @ np.asarray(res.alpha)
    assert np.max(np.abs(corr)) <= mu * 1.01
    # alpha* = Dx* - b (negative residual convention)
    np.testing.assert_allclose(np.asarray(res.alpha),
                               np.asarray(D) @ x - np.asarray(b),
                               atol=5e-2)


def test_dual_kkt_certificate():
    D, b, mu = _wide_problem(jax.random.PRNGKey(1), m=40, n=100)
    D_cols = jnp.stack(jnp.split(D, 4, axis=1))
    res = lasso_column_split(D_cols, b, mu, tau=1.0, iters=3000)
    viol, sup_err = lasso_kkt_gap(np.asarray(D), np.asarray(b),
                                  np.asarray(res.x), mu)
    assert viol < 0.02 * mu
    assert sup_err < 0.05 * mu
