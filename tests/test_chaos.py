"""Elastic, self-healing cluster runtime (ISSUE 7 tentpole): seeded
deterministic fault injection, hardened transport (frame cap + frame
deadline + dial retry), membership rebalance plans, degrade policies,
checkpoint walk-back — and the acceptance soaks: a 4-worker solve under
kill/hang/join/delay/drop chaos landing on the single-process answer,
and a coordinator crash + relaunch resuming from its checkpoint."""
import pickle
import socket
import struct
import threading
import time

import numpy as np
import jax
import pytest

from repro.cluster.chaos import (
    NOOP,
    ChaosSchedule,
    FaultEvent,
    FaultInjector,
    make_injector,
)
from repro.cluster.membership import DeadCluster, Membership, WorkerInfo
from repro.cluster.reduction import Contribution, decode, encode
from repro.cluster.transport import (
    Connection,
    ConnectionClosed,
    Listener,
    connect,
)
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.store import ShardedMatrixStore

jax.config.update("jax_platform_name", "cpu")

TAU = 0.1
TINY = dict(eps_rel=1e-9, eps_abs=1e-12)   # fixed-iteration parity runs


def _problem(m=1200, n=20, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    aux = np.sign(rng.standard_normal((m,))).astype(np.float32)
    return D, aux


def _reference(D, aux, iters):
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    return np.asarray(solver.run(D[None], aux[None], iters=iters).x)


def _cluster_cfg(**kw):
    from repro.cluster.coordinator import ClusterConfig
    kw.setdefault("jax_platforms", "cpu")
    kw.setdefault("heartbeat_timeout_s", 30)
    kw.setdefault("register_timeout_s", 300)
    return ClusterConfig(**kw)


# ---------------------------------------------------------------------------
# schedule: tokens, round-trip, seeded determinism
# ---------------------------------------------------------------------------

def test_fault_event_token_roundtrip():
    e = FaultEvent(iteration=13, target="w2", kind="kill")
    assert e.to_token() == "kill@13:w2"
    assert FaultEvent.from_token("kill@13:w2") == e
    d = FaultEvent(iteration=5, target="w0", kind="delay", param=80.0)
    assert FaultEvent.from_token(d.to_token()) == d
    for bad in ("kill@x:w2", "frob@3:w1", "kill@-1:w0", "kill13w2"):
        with pytest.raises(ValueError):
            FaultEvent.from_token(bad)


def test_schedule_spec_roundtrip_and_sorting():
    spec = "kill@13:w2,delay@5:w0:80,join@9:w4"
    s = ChaosSchedule.parse(spec)
    # events come out iteration-sorted regardless of spec order
    assert [e.iteration for e in s.events] == [5, 9, 13]
    assert ChaosSchedule.parse(s.to_spec()).events == s.events


def test_schedule_generate_deterministic_and_roundtrips():
    for seed in range(10):
        a = ChaosSchedule.generate(seed, n_workers=4, iters=40)
        b = ChaosSchedule.generate(seed, n_workers=4, iters=40)
        assert a.events == b.events and a.seed == seed
        assert ChaosSchedule.parse(a.to_spec()).events == a.events
    assert (ChaosSchedule.generate(0, n_workers=4, iters=40).events
            != ChaosSchedule.generate(1, n_workers=4, iters=40).events)


def test_schedule_generate_validation_and_victim_disjointness():
    with pytest.raises(ValueError, match="survivor"):
        ChaosSchedule.generate(0, n_workers=2, iters=40, kills=1, stops=1)
    with pytest.raises(ValueError, match="iterations"):
        ChaosSchedule.generate(0, n_workers=4, iters=4)
    s = ChaosSchedule.generate(3, n_workers=4, iters=40, kills=2, stops=1)
    victims = [e.target for e in s.for_kind("kill", "stop")]
    assert len(victims) == len(set(victims)) == 3
    joins = s.for_kind("join")
    assert all(e.target == "w4" for e in joins)   # fresh wid, above 0..3
    assert s.counts()["kill"] == 2


# ---------------------------------------------------------------------------
# injector: no-op fast path, fire-once semantics, plane filtering
# ---------------------------------------------------------------------------

def test_make_injector_noop_singleton():
    assert make_injector(None, "w0") is NOOP
    assert make_injector("", "w0") is NOOP
    # a spec with no events for this target also costs nothing
    assert make_injector("kill@3:w1", "w0") is NOOP
    assert not NOOP.enabled
    assert NOOP.process_actions(3) == () and NOOP.on_send("contrib") == ()


def test_injector_process_faults_fire_once_at_or_after_iteration():
    inj = FaultInjector(ChaosSchedule.parse("slow@5:w0:30,kill@7:w0")
                        .for_target("w0"))
    assert inj.process_actions(4) == ()
    # iteration 6 skipped straight to 8: both fire (>=), exactly once
    assert inj.process_actions(8) == (("slow", 30.0), ("kill", 0.0))
    assert inj.process_actions(9) == ()
    assert inj.pending() == ()


def test_injector_wire_faults_exact_iteration_data_plane_only():
    inj = FaultInjector(ChaosSchedule.parse("drop@5:w0").for_target("w0"))
    inj.set_iteration(4)
    assert inj.on_send("contrib") == ()      # wrong iteration: no fire
    inj.set_iteration(5)
    assert inj.on_send("heartbeat") == ()    # control plane stays clean
    assert inj.on_send("contrib") == (("drop", 0.0),)
    assert inj.on_send("contrib") == ()      # fired once


def test_injector_corrupt_breaks_pickle_deterministically():
    inj = FaultInjector(())
    frame = pickle.dumps({"type": "contrib", "x": np.arange(4)})
    bad = inj.corrupt(frame)
    assert bad == inj.corrupt(frame) and bad != frame
    with pytest.raises(Exception):
        pickle.loads(bad)


# ---------------------------------------------------------------------------
# transport hardening: frame cap, frame deadline, decode, dial retry
# ---------------------------------------------------------------------------

def _conn_pair(**kw):
    """A real TCP (client, server) Connection pair on localhost."""
    lst = Listener()
    out = {}

    def _accept():
        out["srv"] = lst.accept(timeout=5.0)

    t = threading.Thread(target=_accept)
    t.start()
    cli = connect(lst.address)
    t.join()
    lst.close()
    srv = out["srv"]
    for k, v in kw.items():
        setattr(cli, k, v)
        setattr(srv, k, v)
    return cli, srv


def test_frame_length_cap_kills_connection():
    cli, srv = _conn_pair(max_frame_bytes=1 << 16)
    cli._sock.sendall(struct.pack(">Q", 1 << 40) + b"xx")
    with pytest.raises(ConnectionClosed, match="exceeds cap"):
        srv.recv(timeout=5.0)
    assert srv.closed
    cli.close()


def test_partial_frame_hits_completion_deadline():
    cli, srv = _conn_pair(frame_deadline_s=0.4)
    cli._sock.sendall(b"\x00\x00\x00")        # 3 of 8 header bytes, then hang
    t0 = time.monotonic()
    with pytest.raises(ConnectionClosed, match="stalled mid-receive"):
        srv.recv(timeout=5.0)
    assert time.monotonic() - t0 < 3.0        # deadline, not the idle timeout
    cli.close()


def test_idle_timeout_with_zero_bytes_returns_none():
    cli, srv = _conn_pair()
    assert srv.recv(timeout=0.2) is None      # idle != dead
    cli.send("ping")
    assert srv.recv(timeout=5.0)["type"] == "ping"
    cli.close()
    srv.close()


def test_undecodable_frame_kills_connection():
    cli, srv = _conn_pair()
    junk = b"\xff\xfenot a pickle"
    cli._sock.sendall(struct.pack(">Q", len(junk)) + junk)
    with pytest.raises(ConnectionClosed, match="undecodable"):
        srv.recv(timeout=5.0)
    cli.close()


def test_connect_retry_backoff_then_failure():
    # grab a port with no listener behind it
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    addr = s.getsockname()
    s.close()
    t0 = time.monotonic()
    with pytest.raises(ConnectionClosed, match="3 attempt"):
        connect(addr, timeout=0.5, retries=2, backoff_s=0.05,
                backoff_max_s=0.1)
    assert time.monotonic() - t0 >= 0.1       # it actually backed off


def test_connect_retry_reaches_late_listener():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    addr = s.getsockname()
    s.close()
    lst = {}

    def _bind_late():
        time.sleep(0.3)
        lst["l"] = Listener(host=addr[0], port=addr[1])

    t = threading.Thread(target=_bind_late)
    t.start()
    conn = connect(addr, timeout=0.5, retries=6, backoff_s=0.1,
                   backoff_max_s=0.5)
    t.join()
    conn.close()
    lst["l"].close()


# ---------------------------------------------------------------------------
# chaos-driven wire faults on a live connection
# ---------------------------------------------------------------------------

def _wire_injector(spec, it):
    inj = make_injector(spec, "w0")
    inj.set_iteration(it)
    return inj


def test_chaos_drop_and_dup_on_send():
    cli, srv = _conn_pair()
    cli.chaos = _wire_injector("drop@3:w0,dup@4:w0", 3)
    cli.send("contrib", k=1)                  # dropped: never arrives
    assert srv.recv(timeout=0.3) is None
    # dropped frames still count as tx (the bytes "left" the app)
    assert cli.counter.snapshot()["sent_bytes"]["contrib"] > 0
    cli.chaos.set_iteration(4)
    cli.send("contrib", k=2)                  # duplicated: arrives twice
    assert srv.recv(timeout=5.0)["k"] == 2
    assert srv.recv(timeout=5.0)["k"] == 2
    cli.close()
    srv.close()


def test_chaos_corrupt_surfaces_as_dead_link():
    cli, srv = _conn_pair()
    cli.chaos = _wire_injector("corrupt@2:w0", 2)
    cli.send("contrib", k=1)
    with pytest.raises(ConnectionClosed, match="undecodable"):
        srv.recv(timeout=5.0)
    cli.close()


def test_chaos_reset_raises_at_sender():
    cli, srv = _conn_pair()
    cli.chaos = _wire_injector("reset@2:w0", 2)
    with pytest.raises(ConnectionClosed, match="chaos"):
        cli.send("contrib", k=1)
    assert cli.closed
    with pytest.raises(ConnectionClosed):
        srv.recv(timeout=5.0)
    srv.close()


def test_chaos_delay_sleeps_but_delivers():
    cli, srv = _conn_pair()
    cli.chaos = _wire_injector("delay@2:w0:150", 2)
    t0 = time.monotonic()
    cli.send("contrib", k=1)
    assert time.monotonic() - t0 >= 0.14
    assert srv.recv(timeout=5.0)["k"] == 1
    cli.close()
    srv.close()


def test_control_plane_immune_to_wire_faults():
    cli, srv = _conn_pair()
    cli.chaos = _wire_injector("drop@2:w0", 2)
    cli.send("heartbeat", t=1.0)              # not data plane: untouched
    assert srv.recv(timeout=5.0)["type"] == "heartbeat"
    cli.close()
    srv.close()


# ---------------------------------------------------------------------------
# membership: liveness interleavings + rebalance plans
# ---------------------------------------------------------------------------

def test_membership_stale_beat_interleaving():
    mem = Membership()
    for wid in range(3):
        mem.add(WorkerInfo(wid=wid))
    assert mem.stale(timeout=0.2) == []
    time.sleep(0.25)
    mem.beat(1)                               # only 1 stays fresh
    assert mem.stale(timeout=0.2) == [0, 2]
    mem.beat(0)
    mem.beat(2)
    assert mem.stale(timeout=0.2) == []
    mem.beat(99)                              # unknown wid: ignored
    mem.mark_dead(2)
    time.sleep(0.25)
    assert mem.stale(timeout=0.2) == [0, 1]   # the dead never re-stale


def test_mark_dead_idempotent():
    mem = Membership()
    mem.add(WorkerInfo(wid=0, blocks={1, 2}))
    assert mem.mark_dead(0) == {1, 2}
    assert mem.mark_dead(0) == set()          # already dead: no orphans
    assert mem.mark_dead(7) == set()          # never registered
    assert mem.deaths == [0]                  # recorded exactly once


def test_rebalance_plan_levels_loads():
    mem = Membership()
    mem.add(WorkerInfo(wid=0, blocks=set(range(8))))
    mem.add(WorkerInfo(wid=1, blocks={8, 9}))
    mem.add(WorkerInfo(wid=2, blocks=set()))  # the joiner
    gains, losses = mem.rebalance_plan()
    loads = [len(mem.get(w).blocks) for w in (0, 1, 2)]
    assert max(loads) - min(loads) <= 1
    assert sum(loads) == 10                   # nothing created or lost
    assert mem.coverage() == set(range(10))
    moved_out = [b for bs in losses.values() for b in bs]
    moved_in = [b for bs in gains.values() for b in bs]
    assert sorted(moved_out) == sorted(moved_in)
    assert mem.rebalances == len(moved_in)
    # already level: a second pass is a no-op
    g2, l2 = mem.rebalance_plan()
    assert not g2 and not l2


def test_rebalance_plan_deterministic():
    def build():
        mem = Membership()
        mem.add(WorkerInfo(wid=0, blocks={0, 1, 2, 3, 4}))
        mem.add(WorkerInfo(wid=1, blocks={5, 6, 7, 8, 9}))
        mem.add(WorkerInfo(wid=2, blocks=set()))
        return mem

    assert build().rebalance_plan() == build().rebalance_plan()


def test_rebalance_plan_dead_cluster():
    mem = Membership()
    mem.add(WorkerInfo(wid=0, blocks={0}))
    mem.mark_dead(0)
    with pytest.raises(DeadCluster):
        mem.rebalance_plan()


# ---------------------------------------------------------------------------
# payload validation, degrade policy, store batch verify, checkpoint
# ---------------------------------------------------------------------------

def test_decode_rejects_malformed_payloads():
    c = Contribution(iteration=3, workers=(0,), rows=10,
                     d=np.ones(4, np.float32), w=np.ones(4, np.float32),
                     v=np.ones(4, np.float32),
                     scalars={"r_sq": 1., "dx_sq": 1., "y_sq": 1.,
                              "obj": 1.})
    good, _ = encode(c, compressed=False)
    assert decode(good).rows == 10
    for mutate in (
        lambda p: p.pop("scalars"),
        lambda p: p.__setitem__("dwv", p["dwv"][:2]),       # (2, n)
        lambda p: p.__setitem__("n", "NaNsense"),
        lambda p: p.__setitem__("rows", -4),
        lambda p: p.__setitem__("workers", [None]),
    ):
        p = {**good, "scalars": dict(good["scalars"])}
        mutate(p)
        with pytest.raises(ValueError):
            decode(p)


def test_degrade_policy_validation():
    from repro.cluster.coordinator import DegradePolicy
    DegradePolicy()                           # defaults are legal
    with pytest.raises(ValueError, match="min_quorum"):
        DegradePolicy(min_quorum=0.0)
    with pytest.raises(ValueError, match="positive"):
        DegradePolicy(iter_deadline_s=0.0)
    with pytest.raises(ValueError):
        DegradePolicy(deadline_retries=-1)


def test_cluster_config_normalizes_chaos_spec():
    cfg = _cluster_cfg(n_workers=4, chaos="kill@13:w2,join@9:w4")
    assert isinstance(cfg.chaos, ChaosSchedule)
    assert cfg.chaos.for_kind("join")[0].target == "w4"
    with pytest.raises(ValueError):
        _cluster_cfg(n_workers=0, spawn=False)


def test_store_verify_blocks_batch():
    D, aux = _problem(400, 8)
    store = ShardedMatrixStore.from_arrays(D, aux, block_rows=128)
    assert store.verify_blocks(range(store.nblocks)) == []
    store._blocks_D[1][0, 0] += 1.0
    store._blocks_D[2][0, 0] += 1.0
    assert store.verify_blocks(range(store.nblocks)) == [1, 2]


def test_checkpoint_restore_walks_back_past_corruption(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    like = {"x": np.zeros(4, np.float32)}
    mgr.save(5, {"x": np.full(4, 5.0, np.float32)})
    mgr.save(10, {"x": np.full(4, 10.0, np.float32)})
    # rot the newest step's leaf on disk
    leaf = tmp_path / "step_00000010" / "leaf_0.npy"
    np.save(leaf, np.full(4, 99.0, np.float32))
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(like)                     # default: newest, loud
    tree, extra = mgr.restore(like, fallback=True)
    np.testing.assert_array_equal(np.asarray(tree["x"]),
                                  np.full(4, 5.0, np.float32))
    # every step rotten -> IOError, not silence
    np.save(tmp_path / "step_00000005" / "leaf_0.npy",
            np.full(4, 99.0, np.float32))
    with pytest.raises(IOError, match="every checkpoint step"):
        mgr.restore(like, fallback=True)


# ---------------------------------------------------------------------------
# end-to-end: fast 2-process chaos run (wire faults + deadline retry)
# ---------------------------------------------------------------------------

def test_two_worker_wire_chaos_exact(tmp_path):
    """delay + dup + drop on real worker links. The dup is deduped by
    the contribution's worker set, the drop is recovered by one
    deadline-retry re-broadcast (cached answers), and the answer is
    EXACT — not merely close."""
    from repro.cluster.coordinator import DegradePolicy, cluster_solve
    D, aux = _problem()
    ref_x = _reference(D, aux, iters=12)
    spec = "delay@4:w0:60,dup@6:w1,drop@8:w1"
    res = cluster_solve(
        D, aux, {"name": "logistic"}, tau=TAU, max_iters=12,
        config=_cluster_cfg(
            n_workers=2, chaos=spec,
            degrade=DegradePolicy(iter_deadline_s=6.0,
                                  deadline_retries=3)),
        store_dir=str(tmp_path / "store"), block_rows=300, **TINY)
    rel = np.linalg.norm(res.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel
    t = res.telemetry
    assert res.status in ("converged", "max_iters") and res.iters == 12
    assert t["status"] == res.status
    assert t["chaos_spec"] == spec
    assert not t["deaths"]                    # wire faults kill nobody
    assert t["iteration_retries"] >= 1        # the drop cost one retry
    retry_kinds = {e["kind"] for e in t["recovery"]["events"]}
    assert "deadline_retry" in retry_kinds


# ---------------------------------------------------------------------------
# acceptance soaks (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_soak_elastic_recovery(tmp_path):
    """THE acceptance soak: 4 workers, 40 iterations, seeded schedule
    with a mid-solve join, a SIGKILL, a SIGSTOP hang, delays and a drop
    — the solve self-heals through all of it and lands within 1e-5 of
    the single-process answer, with full block coverage and recovery
    metrics in the telemetry. Reproducible from the recorded seed."""
    from repro.cluster.coordinator import DegradePolicy, cluster_solve
    SEED = 7
    sched = ChaosSchedule.generate(SEED, n_workers=4, iters=40)
    kinds = sched.counts()
    assert kinds["join"] >= 1 and kinds["kill"] >= 1 \
        and kinds["stop"] >= 1 and kinds["delay"] >= 1 \
        and kinds["drop"] >= 1
    D, aux = _problem()
    ref_x = _reference(D, aux, iters=40)
    res = cluster_solve(
        D, aux, {"name": "logistic"}, tau=TAU, max_iters=40,
        config=_cluster_cfg(
            n_workers=4, chaos=sched,
            heartbeat_timeout_s=10,           # SIGSTOP is only detectable
                                              # by heartbeat age
            degrade=DegradePolicy(iter_deadline_s=40.0,
                                  deadline_retries=4),
            reconnect={"retries": 4, "backoff_s": 0.25,
                       "backoff_max_s": 2.0}),
        store_dir=str(tmp_path / "store"), block_rows=150, **TINY)
    rel = np.linalg.norm(res.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel
    assert res.iters == 40
    assert res.status in ("converged", "max_iters")   # NOT degraded
    t = res.telemetry
    killed = {int(e.target[1:]) for e in sched.for_kind("kill")}
    stopped = {int(e.target[1:]) for e in sched.for_kind("stop")}
    assert killed | stopped <= set(t["deaths"])
    assert t["joins"] >= 1
    assert t["blocks_rebalanced"] >= 1        # the joiner got real work
    assert t["blocks_reassigned"] >= 1        # deaths respread blocks
    rec = t["recovery"]
    assert rec["time_to_recover_s"] is not None \
        and rec["time_to_recover_s"] > 0
    assert rec["join_to_contributing_s"] is not None
    assert any(e["kind"] == "death" for e in rec["events"])
    assert any(e["kind"] == "join" for e in rec["events"])
    # the run is replayable: seed + spec round-trip from the telemetry
    assert t["chaos_seed"] == SEED
    assert ChaosSchedule.generate(SEED, n_workers=4,
                                  iters=40).to_spec() == t["chaos_spec"]


@pytest.mark.slow
def test_coordinator_crash_relaunch_resumes_from_checkpoint(tmp_path):
    """Coordinator recovery: kill the coordinator (no handshake) after
    a checkpoint, relaunch it on the SAME port with spawn=False, and
    the surviving workers re-register (backoff dial); the relaunch
    restores the newest checkpoint and finishes to the single-process
    answer."""
    from repro.cluster.coordinator import ClusterConfig, ClusterCoordinator
    D, aux = _problem()
    ref_x = _reference(D, aux, iters=40)
    store = ShardedMatrixStore.from_arrays(D, aux, block_rows=300)
    store_path = store.save(str(tmp_path / "store"))
    ckpt = str(tmp_path / "ckpt")
    reconnect = {"retries": 10, "backoff_s": 0.5, "backoff_max_s": 2.0}
    common = dict(jax_platforms="cpu", heartbeat_timeout_s=30,
                  register_timeout_s=300, checkpoint_dir=ckpt,
                  checkpoint_every=5, reconnect=reconnect)
    c1 = ClusterCoordinator(store_path, {"name": "logistic"}, tau=TAU,
                            config=ClusterConfig(n_workers=2, **common),
                            **TINY)
    c2 = None
    procs = {}
    try:
        c1.start()
        port = c1.listener.address[1]
        res1 = c1.solve(max_iters=14)         # checkpoints at 5 and 10
        assert res1.iters == 14
        procs = dict(c1._procs)
        c1.crash()                            # no stop handshake: links die
        c2 = ClusterCoordinator(
            store_path, {"name": "logistic"}, tau=TAU,
            config=ClusterConfig(n_workers=2, spawn=False, port=port,
                                 resume=True, **common), **TINY)
        c2.adopt_processes(procs)
        res2 = c2.solve(max_iters=40)         # workers re-register first
    finally:
        if c2 is not None:
            c2.shutdown()
        for p in procs.values():              # belt and braces
            if p.is_alive():
                p.kill()
    assert res2.iters == 40
    assert res2.telemetry["iters"] == 30      # resumed at 10, ran 30 more
    assert sorted(c2.members.workers) == [0, 1]
    rel = np.linalg.norm(res2.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel


@pytest.mark.slow
def test_degraded_status_when_quorum_unrecoverable(tmp_path):
    """Graceful degradation: kill 2 of 3 workers with a min_quorum that
    their deaths violate — the solve returns best-so-far x with
    status='degraded' instead of hanging or raising."""
    from repro.cluster.coordinator import DegradePolicy, cluster_solve
    D, aux = _problem()
    res = cluster_solve(
        D, aux, {"name": "logistic"}, tau=TAU, max_iters=40,
        config=_cluster_cfg(
            n_workers=3, chaos="kill@6:w0,kill@8:w1",
            degrade=DegradePolicy(iter_deadline_s=30.0,
                                  deadline_retries=1,
                                  min_quorum=0.5)),
        store_dir=str(tmp_path / "store"), block_rows=200, **TINY)
    assert res.status == "degraded"
    assert res.telemetry["status"] == "degraded"
    assert res.iters < 40                     # stopped early, not hung
    assert np.all(np.isfinite(res.x))
    assert sorted(res.telemetry["deaths"]) == [0, 1]
