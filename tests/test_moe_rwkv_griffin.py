"""Sequence-mixer unit tests: MoE dispatch, RWKV6 chunk/step parity,
Griffin RG-LRU scan/step parity and state continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import griffin, rwkv6
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dense_ref

jax.config.update("jax_platform_name", "cpu")

MOE_CFG = ModelConfig(
    name="t", family="moe", num_layers=1, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=100, num_experts=8,
    experts_per_token=2, capacity_factor=8.0, compute_dtype=jnp.float32)


def test_moe_matches_dense_reference():
    p = init_moe(jax.random.PRNGKey(0), MOE_CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, aux = moe_ffn(p, MOE_CFG, x)
    ref = moe_ffn_dense_ref(p, MOE_CFG, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    assert float(aux) > 0


def test_moe_capacity_drops_are_silent_zero():
    import dataclasses
    tight = dataclasses.replace(MOE_CFG, capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, _ = moe_ffn(p, tight, x)
    full, _ = moe_ffn(p, MOE_CFG, x)
    assert bool(jnp.isfinite(out).all())
    # dropped tokens -> smaller output norm than uncapped
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(full)) + 1e-3


def test_moe_deterministic():
    p = init_moe(jax.random.PRNGKey(0), MOE_CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    o1, _ = moe_ffn(p, MOE_CFG, x)
    o2, _ = moe_ffn(p, MOE_CFG, x)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_moe_dense_residual_branch():
    import dataclasses
    cfg = dataclasses.replace(MOE_CFG, moe_dense_residual=True)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, _ = moe_ffn(p, cfg, x)
    ref = moe_ffn_dense_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


RWKV_CFG = ModelConfig(
    name="t", family="rwkv6", num_layers=1, d_model=128, num_heads=2,
    num_kv_heads=2, d_ff=256, vocab_size=100, rwkv_head_dim=32,
    rwkv_lora_rank=8, wkv_chunk=8, compute_dtype=jnp.float32)


def test_rwkv_time_mix_chunked_equals_step():
    p = rwkv6.init_time_mix(jax.random.PRNGKey(0), RWKV_CFG)
    B, S, d = 2, 32, 128
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
    out_seq, (last, S_fin) = rwkv6.time_mix(p, RWKV_CFG, x)
    Sst = jnp.zeros((B, 4, 32, 32))
    lastx = jnp.zeros((B, d))
    outs = []
    for t in range(S):
        o, lastx, Sst = rwkv6.time_mix_step(p, RWKV_CFG, x[:, t], lastx, Sst)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_seq),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-3)
    # the returned prefill state matches the step-accumulated state
    np.testing.assert_allclose(np.asarray(S_fin), np.asarray(Sst), atol=1e-3)
    np.testing.assert_allclose(np.asarray(last), np.asarray(lastx), atol=1e-5)


def test_rwkv_wkv_unroll_equals_scan():
    p = rwkv6.init_time_mix(jax.random.PRNGKey(0), RWKV_CFG)
    import dataclasses
    cfg_u = dataclasses.replace(RWKV_CFG, unroll_inner=True)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 128)) * 0.5
    o1, _ = rwkv6.time_mix(p, RWKV_CFG, x)
    o2, _ = rwkv6.time_mix(p, cfg_u, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_rwkv_extreme_decay_stable():
    """Strong data-dependent decay must not overflow the chunked form."""
    p = rwkv6.init_time_mix(jax.random.PRNGKey(0), RWKV_CFG)
    p = dict(p, decay_base=jnp.full((128,), 2.0))  # w ~ exp(-e^2): hard decay
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 128)) * 2.0
    out, _ = rwkv6.time_mix(p, RWKV_CFG, x)
    assert bool(jnp.isfinite(out).all())


GRIF_CFG = ModelConfig(
    name="t", family="griffin", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=1, d_ff=128, vocab_size=100, lru_width=96,
    pattern=("rec", "rec", "attn_local"), compute_dtype=jnp.float32)


def test_griffin_scan_equals_step():
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), GRIF_CFG)
    B, S = 2, 17
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 64)) * 0.5
    out_seq, _ = griffin.recurrent_block(p, GRIF_CFG, x)
    st = (jnp.zeros((B, 96)), jnp.zeros((B, 3, 96)))
    outs = []
    for t in range(S):
        o, st = griffin.recurrent_block_step(p, GRIF_CFG, x[:, t], st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_seq),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-4)


def test_griffin_state_carry_continuity():
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), GRIF_CFG)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, 64)) * 0.5
    full, _ = griffin.recurrent_block(p, GRIF_CFG, x)
    o1, s1 = griffin.recurrent_block(p, GRIF_CFG, x[:, :9])
    o2, _ = griffin.recurrent_block(p, GRIF_CFG, x[:, 9:], s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), atol=1e-4)


def test_rg_lru_is_contraction():
    """|a_t| < 1 by construction: long-run state stays bounded."""
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), GRIF_CFG)
    x = jnp.ones((1, 512, 64))
    out, (h, _) = griffin.recurrent_block(p, GRIF_CFG, x)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.max(jnp.abs(h))) < 1e3
