"""Backend-parity matrix: problem × topology × warm-start × resume.

THE acceptance suite for the SolveExecutor contract (DESIGN.md §14):
every registered problem must produce the same x (rel sup-norm gap
≤ 1e-5) on all four topologies — local row blocks, out-of-core
streaming, shard_map device mesh, and a 2-worker cluster — including
the warm-start and checkpoint-resume legs, with zero per-topology
problem code. Replaces the scattered per-topology parity tests; the
shared problems/tolerances live in exec_fixtures so the per-topology
files stay in sync.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise multi-shard shard_map (single-device it degenerates to a
bitwise copy of local, which still checks the plumbing).
"""
import jax

jax.config.update("jax_platform_name", "cpu")

import numpy as np
import pytest

from exec_fixtures import (
    EXECUTORS,
    NEW_PROBLEMS,
    PARITY_PROBLEMS,
    PARITY_TOL,
    SOLVE_KW,
    N_WORKERS,
    parity_problem,
    rel_gap,
)
from repro.exec import fit_on_executor
from repro.obs import Observability, read_jsonl

WARM_ITERS = 30          # partial solve the warm-start leg seeds from
PARTIAL = dict(max_iters=25, checkpoint_every=10)


@pytest.fixture(scope="module")
def ref_cache():
    """Converged local solutions, one solve per problem for the whole
    matrix (every parametrized case compares against this)."""
    cache = {}

    def get(name):
        if name not in cache:
            prob, D, aux = parity_problem(name)
            r = fit_on_executor(prob, "local", D, aux, **SOLVE_KW)
            cache[name] = np.asarray(r.x)
        return cache[name]

    return get


@pytest.fixture(scope="module")
def warm_x0():
    """Partial-solve iterate every executor's warm leg starts from."""
    cache = {}

    def get(name):
        if name not in cache:
            prob, D, aux = parity_problem(name)
            r = fit_on_executor(prob, "local", D, aux,
                                max_iters=WARM_ITERS,
                                eps_rel=1e-12, eps_abs=1e-15)
            cache[name] = np.asarray(r.x)
        return cache[name]

    return get


@pytest.fixture(scope="module")
def warm_ref(warm_x0, ref_cache):
    """Local warm-started solution — the parity reference for the warm
    leg. Warm and cold follow different trajectories, so at eps_rel=1e-5
    they stop at different approximations of the same optimum; backend
    parity compares like trajectory with like, and a looser sanity bound
    checks the warm path still lands on the cold optimum."""
    cache = {}

    def get(name):
        if name not in cache:
            prob, D, aux = parity_problem(name)
            r = fit_on_executor(prob, "local", D, aux, x0=warm_x0(name),
                                **SOLVE_KW)
            x = np.asarray(r.x)
            assert rel_gap(ref_cache(name), x) <= 100 * PARITY_TOL
            cache[name] = x
        return cache[name]

    return get


@pytest.fixture(scope="module")
def resume_ref(ref_cache, tmp_path_factory):
    """Local checkpoint+resume solution — the parity reference for the
    resume leg (same like-for-like reasoning as ``warm_ref``)."""
    cache = {}

    def get(name):
        if name not in cache:
            base = tmp_path_factory.mktemp(f"resume_ref_{name}")
            ckpt = str(base / "ckpt")
            _fit(name, "local", base, checkpoint_dir=ckpt, **PARTIAL)
            r = _fit(name, "local", base, checkpoint_dir=ckpt,
                     resume=True, **SOLVE_KW)
            x = np.asarray(r.x)
            assert rel_gap(ref_cache(name), x) <= 100 * PARITY_TOL
            cache[name] = x
        return cache[name]

    return get


def _fit(name, executor, tmp_path, **kw):
    prob, D, aux = parity_problem(name)
    if executor == "cluster":
        kw.setdefault("n_workers", N_WORKERS)
        kw.setdefault("store_dir", str(tmp_path / "store"))
    return fit_on_executor(prob, executor, D, aux, **kw)


@pytest.mark.parametrize("executor", [e for e in EXECUTORS if e != "local"])
@pytest.mark.parametrize("problem", PARITY_PROBLEMS)
def test_cold_parity(problem, executor, ref_cache, tmp_path):
    r = _fit(problem, executor, tmp_path, **SOLVE_KW)
    gap = rel_gap(ref_cache(problem), r.x)
    assert gap <= PARITY_TOL, f"{problem} on {executor}: gap {gap:.3e}"


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("problem", NEW_PROBLEMS)
def test_warm_start_parity(problem, executor, warm_x0, warm_ref, tmp_path):
    """Warm-started from the same partial iterate, every executor must
    land on the local warm-started x (local leg: determinism)."""
    r = _fit(problem, executor, tmp_path, x0=warm_x0(problem), **SOLVE_KW)
    gap = rel_gap(warm_ref(problem), r.x)
    assert gap <= PARITY_TOL, f"{problem} warm on {executor}: gap {gap:.3e}"


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("problem", NEW_PROBLEMS)
def test_checkpoint_resume_parity(problem, executor, resume_ref, tmp_path):
    """Kill after 25 iters, resume from the checkpoint, converge: every
    executor must reach the local resumed x (local leg: determinism)."""
    ckpt = str(tmp_path / "ckpt")
    _fit(problem, executor, tmp_path, checkpoint_dir=ckpt, **PARTIAL)
    r = _fit(problem, executor, tmp_path, checkpoint_dir=ckpt,
             resume=True, **SOLVE_KW)
    gap = rel_gap(resume_ref(problem), r.x)
    assert gap <= PARITY_TOL, f"{problem} resume on {executor}: gap {gap:.3e}"


@pytest.mark.parametrize("executor", ["local", "streaming", "shard_map"])
def test_telemetry_stamps_executor(executor, tmp_path):
    """Every telemetry record carries the executor name + resolved
    engine backend, so mixed-topology runs stay attributable."""
    prob, D, aux = parity_problem("logistic")
    obs = Observability.create(str(tmp_path / "obs"))
    fit_on_executor(prob, executor, D, aux, max_iters=5,
                    eps_rel=1e-12, eps_abs=1e-15, obs=obs)
    obs.finish()
    recs = read_jsonl(str(tmp_path / "obs" / "telemetry.jsonl"))
    assert recs, "no telemetry written"
    for rec in recs:
        assert rec["executor"] == executor
        assert rec["backend"]       # resolved engine backend, non-empty
