"""Multi-device semantics (8 virtual CPU devices via subprocess):
distributed transpose-reduction ADMM == single-device reference; the
compressed reduction converges; the mini production-mesh dry-run compiles.

Run in subprocesses because XLA_FLAGS must be set before jax init and the
main pytest process must keep seeing 1 device."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

# real 8-device subprocess solves + production-mesh compiles: tens of
# minutes when healthy. Deselect with -m "not slow" (CI does).
pytestmark = pytest.mark.slow

ROOT = str(Path(__file__).parent.parent)


def _run(script, timeout=900):
    p = subprocess.run(
        [sys.executable, "-c", script], cwd=ROOT, capture_output=True,
        text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/tmp",
             # pin to CPU: without it jax probes for TPU metadata and each
             # subprocess wastes ~60s timing out before falling back
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


HEADER = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.data.synthetic import classification_problem
from repro.core.unwrapped import UnwrappedADMM
from repro.core.prox import make_logistic, make_hinge
from repro.core.distributed import DistributedUnwrappedADMM, shard_rows
from repro.sharding import compat
mesh = compat.make_mesh((8,), ("data",))
prob = classification_problem(jax.random.PRNGKey(0), N=8, m_per_node=125, n=20)
Dflat = prob.D.reshape(-1, 20); lflat = prob.labels.reshape(-1)
Dg = shard_rows(mesh, Dflat, ("data",)); lg = shard_rows(mesh, lflat, ("data",))
"""


def test_distributed_equals_single_device():
    out = _run(HEADER + """
ref = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(prob.D, prob.labels, iters=80)
solver = DistributedUnwrappedADMM(loss=make_logistic(), tau=0.1, data_axes=("data",))
x, objs, rs = solver.build(mesh, Dflat.shape[0], 20, iters=80)(Dg, lg)
err = float(jnp.linalg.norm(x - ref.x) / jnp.linalg.norm(ref.x))
# history parity: the distributed objective telemetry evaluates f(Dx),
# the same quantity as the reference solver's _objective, EVERY iteration
hist_gap = float(jnp.max(jnp.abs(objs - ref.history.objective)
                         / jnp.abs(ref.history.objective)))
res_gap = float(jnp.max(jnp.abs(rs - ref.history.primal_res)))
print(json.dumps({"err": err, "ndev": len(jax.devices()),
                  "hist_gap": hist_gap, "res_gap": res_gap}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["ndev"] == 8
    assert r["err"] < 1e-5
    # mid-run history matches the reference solver, not just the endpoint
    assert r["hist_gap"] < 1e-4, r
    assert r["res_gap"] < 1e-3, r


def test_distributed_uneven_rows_zero_padded():
    """m_global % nshards != 0: build() zero-pads to a shard multiple
    (exact under the transpose reduction) instead of crashing, and the
    objective telemetry subtracts the pad rows' constant f(0) term."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from repro.data.synthetic import classification_problem
from repro.core.unwrapped import UnwrappedADMM
from repro.core.prox import make_logistic
from repro.core.distributed import DistributedUnwrappedADMM
from repro.sharding import compat
mesh = compat.make_mesh((8,), ("data",))
prob = classification_problem(jax.random.PRNGKey(1), N=1, m_per_node=997, n=20)
Dflat = prob.D.reshape(-1, 20); lflat = prob.labels.reshape(-1)
ref = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(prob.D, prob.labels, iters=60)
solver = DistributedUnwrappedADMM(loss=make_logistic(), tau=0.1, data_axes=("data",))
solve = solver.build(mesh, 997, 20, iters=60)   # 997 % 8 != 0
x, objs, rs = solve(Dflat, lflat)               # host arrays: padded inside
err = float(jnp.linalg.norm(x - ref.x) / jnp.linalg.norm(ref.x))
hist_gap = float(jnp.max(jnp.abs(objs - ref.history.objective)
                         / jnp.abs(ref.history.objective)))
print(json.dumps({"err": err, "hist_gap": hist_gap}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 1e-5, r
    assert r["hist_gap"] < 1e-4, r


def test_compressed_reduction_converges():
    out = _run(HEADER + """
ref = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(prob.D, prob.labels, iters=100)
solver = DistributedUnwrappedADMM(loss=make_logistic(), tau=0.1,
                                  data_axes=("data",), compress=True)
x, objs, rs = solver.build(mesh, Dflat.shape[0], 20, iters=100)(Dg, lg)
ref_obj = float(ref.history.objective[-1]); obj = float(objs[-1])
print(json.dumps({"rel_gap": abs(obj - ref_obj) / abs(ref_obj)}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    # int8 + error feedback: same objective to ~1e-4 relative
    assert r["rel_gap"] < 1e-3


def test_composite_l1_xupdate_matches_stacked():
    out = _run(HEADER + """
from repro.core.prox import make_l1, StackedProx
from repro.core.oracles import logistic_objective
mu = 5.0
solver = DistributedUnwrappedADMM(loss=make_logistic(), tau=0.1, l1_mu=mu,
                                  data_axes=("data",))
x, objs, _ = solver.build(mesh, Dflat.shape[0], 20, iters=300)(Dg, lg)
D_hat = jnp.concatenate([jnp.eye(20), Dflat], axis=0)[None]
sp = StackedProx(blocks=(make_l1(mu), make_logistic()), sizes=(20, Dflat.shape[0]))
aux = jnp.concatenate([jnp.zeros(20), lflat])[None]
res = UnwrappedADMM(loss=sp.as_loss(), tau=0.1).run(D_hat, aux, iters=1500)
o1 = logistic_objective(np.asarray(Dflat), np.asarray(lflat), np.asarray(x)) + mu*float(np.abs(np.asarray(x)).sum())
o2 = logistic_objective(np.asarray(Dflat), np.asarray(lflat), np.asarray(res.x)) + mu*float(np.abs(np.asarray(res.x)).sum())
print(json.dumps({"gap": abs(o1-o2)/abs(o2)}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["gap"] < 2e-3


def test_moe_a2a_matches_dense_reference():
    """Explicit all-to-all EP (§Perf) == the no-capacity dense reference on
    a real (4 data x 2 model) mesh."""
    out = _run("""
import jax, jax.numpy as jnp, json, dataclasses
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_ffn_dense_ref
from repro.models.moe_a2a import moe_ffn_a2a
from repro.sharding import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=100, num_experts=8,
                  experts_per_token=2, capacity_factor=8.0,
                  compute_dtype=jnp.float32, moe_impl="a2a")
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
ref = moe_ffn_dense_ref(p, cfg, x)
with compat.use_mesh(mesh):
    xg = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    out, aux = jax.jit(lambda p, x: moe_ffn_a2a(p, cfg, x))(p, xg)
print(json.dumps({"err": float(jnp.max(jnp.abs(out - ref)))}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 1e-4


def test_mini_production_mesh_dryrun():
    """The dry-run machinery on a (4,2) mesh with smoke configs: lower +
    compile + roofline extraction end-to-end (fast stand-in for the 512-dev
    sweep, which runs as a deliverable outside the test suite)."""
    out = _run("""
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.configs as C
from repro.launch.mesh import make_mesh
from repro.launch.input_specs import abstract_params
from repro.sharding import specs as spec_lib
from repro.sharding.util import filter_spec
from repro.runtime.steps import make_train_step
from repro.optim.optimizers import make_optimizer
from repro.roofline.hlo import parse_collectives
from repro.sharding import compat

import dataclasses
mesh = make_mesh((4, 2), ("data", "model"))
results = {}
ALL = [a.replace("_", "-").replace("1p6b", "1.6b") for a in C.ARCH_IDS]
for arch in ALL:
    cfg = C.get_smoke(arch)
    with compat.use_mesh(mesh):
        params_abs = abstract_params(cfg)
        ns = lambda s: NamedSharding(mesh, filter_spec(s, mesh.axis_names))
        params_in = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns(sp)),
            params_abs, spec_lib.param_spec(params_abs, cfg.parallelism),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        opt = make_optimizer("adamw")
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospec = {k: spec_lib.zero1_spec(
                     spec_lib.param_spec(v, cfg.parallelism), v, mesh,
                     axes=cfg.dp_axes)
                 for k, v in opt_abs.items()}
        opt_in = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns(sp)),
            opt_abs, ospec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        B, S = 8, 64
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=ns(P("data", None))),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=ns(P("data", None)))}
        if cfg.frontend == "vision":
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16, sharding=ns(P("data", None, None))),
                     "positions": jax.ShapeDtypeStruct((3, B, S), jnp.int32, sharding=ns(P(None, "data", None))),
                     "labels": batch["labels"]}
        elif cfg.family == "encdec":
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16, sharding=ns(P("data", None, None)))
        step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=ns(P()))
        compiled = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1)).lower(
            params_in, opt_in, batch, step_in).compile()
        coll = parse_collectives(compiled.as_text())
        results[arch] = {"flops": compat.cost_analysis(compiled).get("flops", 0),
                         "n_coll": len(coll.ops)}
print(json.dumps(results))
""")
    r = json.loads(out.strip().splitlines()[-1])
    for arch, v in r.items():
        assert v["flops"] > 0, arch
        assert v["n_coll"] > 0, arch
