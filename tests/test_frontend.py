"""Networked fit-service robustness tests (DESIGN.md §15).

Covers the admission → deadline → degrade → shed state machine, the
exactly-one-terminal-response invariant, failure containment between
tenants (crash / slow-loris / corrupt frame), the cold-solve circuit
breaker, and the transport plumb-through the front end relies on
(per-accept chaos / frame caps / frame deadlines)."""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.cluster.chaos import FaultEvent, FaultInjector
from repro.cluster.transport import ConnectionClosed, Listener, connect
from repro.service.admission import (
    AdmissionController,
    CircuitBreaker,
    TokenBucket,
)
from repro.service.frontend import (
    SERVICE_DATA_PLANE,
    FitFrontend,
    FitServiceClient,
)


def _data(m=300, n=16, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    return D, b


def _labels(D):
    return np.sign(D @ np.ones(D.shape[1], D.dtype) + 0.1).astype(D.dtype)


# ---------------------------------------------------------------------------
# admission units
# ---------------------------------------------------------------------------

def test_token_bucket_rate_and_retry_hint():
    tb = TokenBucket(rate=10.0, burst=2.0)
    now = time.monotonic()
    assert tb.try_take(now).ok
    assert tb.try_take(now).ok
    adm = tb.try_take(now)
    assert not adm.ok and adm.reason == "quota"
    assert 0.0 < adm.retry_after_s <= 0.11
    # a tenth of a second refills one token at rate 10
    assert tb.try_take(now + 0.11).ok


def test_admission_queue_bound_beats_quota():
    ac = AdmissionController(max_queue=4, tenant_rate=1000.0)
    assert ac.admit("t", in_flight=3).ok
    adm = ac.admit("t", in_flight=4)
    assert not adm.ok and adm.reason == "queue_full"
    assert adm.retry_after_s >= 0.05
    snap = ac.snapshot()
    assert snap["admitted"] == 1 and snap["rejected"] == 1


def test_circuit_breaker_state_machine():
    cb = CircuitBreaker(failure_threshold=2, reset_after_s=0.05)
    assert cb.state == "closed" and cb.allow()
    cb.record_failure()
    assert cb.state == "closed"
    cb.record_failure()
    assert cb.state == "open" and not cb.allow() and cb.trips == 1
    time.sleep(0.06)
    assert cb.state == "half_open"
    assert cb.allow()            # one probe
    assert not cb.allow()        # only one
    cb.record_failure()          # probe failed -> re-open
    assert cb.state == "open" and cb.trips == 2
    time.sleep(0.06)
    assert cb.allow()
    cb.record_success()
    assert cb.state == "closed" and cb.allow()


# ---------------------------------------------------------------------------
# transport plumb-through (satellite: Listener.accept knobs)
# ---------------------------------------------------------------------------

def test_listener_threads_knobs_into_accepted_connections():
    chaos = FaultInjector([FaultEvent(0, "x", "delay", 1.0)])
    lst = Listener(chaos=chaos, max_frame_bytes=1234, frame_deadline_s=0.5)
    try:
        client = threading.Thread(target=lambda: connect(lst.address))
        client.start()
        conn = lst.accept(timeout=2.0)
        client.join()
        assert conn is not None
        assert conn.chaos is chaos
        assert conn.max_frame_bytes == 1234
        assert conn.frame_deadline_s == 0.5
        # explicit per-accept override, including chaos=None
        c2 = threading.Thread(target=lambda: connect(lst.address))
        c2.start()
        conn2 = lst.accept(timeout=2.0, chaos=None, max_frame_bytes=99,
                           frame_deadline_s=9.0)
        c2.join()
        assert conn2.chaos is None and conn2.max_frame_bytes == 99
        assert conn2.frame_deadline_s == 9.0
        conn.close()
        conn2.close()
    finally:
        lst.close()


def test_slow_loris_client_is_severed():
    """Partial frame then stall: the receiver must raise within the
    frame deadline instead of pinning the handler thread."""
    lst = Listener(frame_deadline_s=0.3)
    try:
        raw = socket.create_connection(lst.address)
        conn = lst.accept(timeout=2.0)
        raw.sendall(struct.pack(">Q", 1000)[:4])     # half a header, stall
        t0 = time.monotonic()
        with pytest.raises(ConnectionClosed, match="stalled"):
            conn.recv(timeout=5.0)
        assert time.monotonic() - t0 < 2.0
        assert conn.closed
        raw.close()
    finally:
        lst.close()


def test_oversized_frame_client_is_severed_others_unaffected():
    lst = Listener(max_frame_bytes=1 << 10)
    try:
        bad_raw = socket.create_connection(lst.address)
        bad = lst.accept(timeout=2.0)
        good = None
        t = threading.Thread(target=lambda: connect(lst.address).send(
            "ping", tenant="good"))
        t.start()
        good = lst.accept(timeout=2.0)
        t.join()
        bad_raw.sendall(struct.pack(">Q", 1 << 20))  # absurd length
        with pytest.raises(ConnectionClosed, match="exceeds cap"):
            bad.recv(timeout=2.0)
        # the sibling connection still delivers
        msg = good.recv(timeout=2.0)
        assert msg["type"] == "ping" and msg["tenant"] == "good"
        bad_raw.close()
        good.close()
    finally:
        lst.close()


# ---------------------------------------------------------------------------
# front end: happy path + taxonomy
# ---------------------------------------------------------------------------

def test_frontend_multi_tenant_round_trip_and_coalescing():
    D, b = _data()
    with FitFrontend(window=4, flush_interval_s=0.05) as fe:
        with FitServiceClient(fe.address, tenant="alice") as alice, \
             FitServiceClient(fe.address, tenant="bob") as bob:
            fp = alice.register(D, b)
            rids_a = [alice.fit_async("ridge", fp, mu=1.0)
                      for _ in range(2)]
            rids_b = [bob.fit_async("ridge", fp, mu=1.0)
                      for _ in range(2)]
            res = ([alice.result(r, timeout=20.0) for r in rids_a]
                   + [bob.result(r, timeout=20.0) for r in rids_b])
            assert all(r["status"] == "ok" for r in res)
            x_ref = np.linalg.solve(D.T @ D + np.eye(16), D.T @ b)
            for r in res:
                np.testing.assert_allclose(r["x"], x_ref, rtol=1e-3,
                                           atol=1e-3)
            # tenants' requests coalesced into shared micro-batches
            assert any(r["batch_size"] >= 2 for r in res)
        assert fe.zero_lost_requests()


def test_frontend_rejects_over_quota_with_retry_hint():
    D, b = _data()
    with FitFrontend(window=4, tenant_rate=2.0, tenant_burst=2.0) as fe:
        with FitServiceClient(fe.address, tenant="greedy") as c:
            fp = c.register(D, b)
            rids = [c.fit_async("ridge", fp, mu=1.0) for _ in range(5)]
            res = [c.result(r, timeout=20.0) for r in rids]
            statuses = [r["status"] for r in res]
            assert statuses.count("ok") == 2
            assert statuses.count("rejected") == 3
            rej = [r for r in res if r["status"] == "rejected"]
            assert all(r["retry_after_s"] > 0 for r in rej)
        assert fe.zero_lost_requests()


def test_frontend_queue_bound_sheds_instead_of_growing():
    D, b = _data()
    # a solver that never flushes (huge window + interval) so the queue
    # genuinely fills; max_queue=3 must shed the rest immediately
    with FitFrontend(window=1024, flush_interval_s=30.0, max_queue=3,
                     default_deadline_s=1.0) as fe:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)
            rids = [c.fit_async("ridge", fp, mu=1.0) for _ in range(8)]
            res = [c.result(r, timeout=20.0) for r in rids]
            statuses = [r["status"] for r in res]
            assert statuses.count("rejected") == 5
            # the 3 admitted ones expire their deadline mid-queue —
            # still a terminal answer, never a hang
            assert statuses.count("deadline") == 3
        assert fe.zero_lost_requests()


def test_frontend_deadline_expires_mid_queue():
    D, b = _data()
    with FitFrontend(window=1024, flush_interval_s=30.0) as fe:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)
            t0 = time.monotonic()
            r = c.fit("ridge", fp, mu=1.0, deadline_s=0.25, timeout=20.0)
            dt = time.monotonic() - t0
            assert r["status"] == "deadline"
            assert dt < 5.0              # answered promptly, not hung
        assert fe.zero_lost_requests()


def test_frontend_bad_requests_get_error_and_siblings_survive():
    """Flush-poisoning end to end: a bad group in the same micro-batch
    must not cost any sibling its response."""
    D, b = _data()
    with FitFrontend(window=4, flush_interval_s=0.5) as fe:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)
            rids = [
                c.fit_async("ridge", fp, mu=1.0),
                c.fit_async("ridge", "0" * 64, mu=1.0),   # unknown fp
                c.fit_async("lasso", fp),                 # missing mu
                c.fit_async("ridge", fp, mu=2.0),
            ]
            res = [c.result(r, timeout=20.0) for r in rids]
            assert [r["status"] for r in res] == [
                "ok", "error", "error", "ok"]
            assert "unknown dataset fingerprint" in res[1]["error"]
            assert "no mu" in res[2]["error"]
        assert fe.zero_lost_requests()


# ---------------------------------------------------------------------------
# degradation: budgets, breaker, chaos
# ---------------------------------------------------------------------------

def test_cold_budget_blown_returns_degraded_cached_answer():
    D, _ = _data()
    labels = _labels(D)
    chaos = FaultInjector([FaultEvent(1, "svc", "slow", 1500.0)],
                          data_plane=SERVICE_DATA_PLANE)
    with FitFrontend(window=4, flush_interval_s=0.005, chaos=chaos,
                     cold_budget_s=0.2, breaker_threshold=10) as fe:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, labels)
            t0 = time.monotonic()
            r = c.fit("logistic", fp, iters=50, timeout=20.0)
            dt = time.monotonic() - t0
            assert r["status"] == "degraded"
            assert "budget" in r["error"]
            assert r["from_cache"] is True
            assert dt < 5.0
            # the degraded answer is the warm ridge probe — a usable
            # linear classifier, not garbage
            acc = np.mean(np.sign(D @ r["x"]) == labels)
            assert acc > 0.8
        assert fe.zero_lost_requests()


def test_breaker_trips_and_sheds_to_degraded():
    D, _ = _data()
    labels = _labels(D)
    # every cold solve stalls 1.5s against a 0.15s budget -> failures
    events = [FaultEvent(i, "svc", "slow", 1500.0) for i in range(1, 4)]
    chaos = FaultInjector(events, data_plane=SERVICE_DATA_PLANE)
    with FitFrontend(window=2, flush_interval_s=0.005, chaos=chaos,
                     cold_budget_s=0.15, breaker_threshold=2,
                     breaker_reset_s=60.0, cold_workers=4) as fe:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, labels)
            statuses = []
            for _ in range(4):
                r = c.fit("logistic", fp, iters=50, timeout=20.0)
                statuses.append(r["status"])
            assert all(s == "degraded" for s in statuses)
            assert fe.breaker.state == "open"
            # once open, sheds happen without touching the backend
            assert fe.metrics.counter_value("service.breaker_shed") >= 1
        assert fe.zero_lost_requests()


def test_breaker_trips_on_backend_exceptions(monkeypatch):
    D, b = _data()
    fe = FitFrontend(window=2, flush_interval_s=0.005,
                     breaker_threshold=2, breaker_reset_s=60.0)
    try:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)

            def boom(req):
                raise RuntimeError("backend down")

            monkeypatch.setattr(fe.server, "solve_one", boom)
            r1 = c.fit("logistic", fp, b=_labels(D), timeout=20.0)
            r2 = c.fit("logistic", fp, b=_labels(D), timeout=20.0)
            assert r1["status"] == "error" and "backend down" in r1["error"]
            assert r2["status"] == "error"
            assert fe.breaker.state == "open"
            # breaker open: next cold request degrades; the fallback
            # path (solve_one) is also broken, so it lands on "error" —
            # still terminal, still accounted
            r3 = c.fit("logistic", fp, b=_labels(D), timeout=20.0)
            assert r3["status"] == "error"
            assert fe.metrics.counter_value("service.breaker_shed") >= 1
        assert fe.zero_lost_requests()
    finally:
        fe.close()


def test_crashed_client_does_not_stall_siblings():
    D, b = _data()
    # flush well after the victim's EOF is noticed, so its responses
    # deterministically hit a dead connection
    with FitFrontend(window=8, flush_interval_s=0.2) as fe:
        with FitServiceClient(fe.address, tenant="alice") as alice:
            fp = alice.register(D, b)
            victim = FitServiceClient(fe.address, tenant="victim")
            for _ in range(3):
                victim.fit_async("ridge", fp, mu=1.0)
            victim.conn.close()          # crash with requests in flight
            rids = [alice.fit_async("ridge", fp, mu=1.0)
                    for _ in range(4)]
            res = [alice.result(r, timeout=20.0) for r in rids]
            assert all(r["status"] == "ok" for r in res)
            # the victim's responses were produced and accounted, just
            # undeliverable — not lost, not blocking
            deadline = time.monotonic() + 10.0
            while (fe.metrics.counter_value("service.undeliverable") < 3
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            assert fe.metrics.counter_value("service.undeliverable") == 3
        assert fe.zero_lost_requests()


def test_corrupting_and_loris_clients_are_severed_siblings_fine():
    D, b = _data()
    with FitFrontend(window=8, flush_interval_s=0.02,
                     frame_deadline_s=0.3) as fe:
        with FitServiceClient(fe.address, tenant="alice") as alice:
            fp = alice.register(D, b)
            # corrupt-frame client: garbage body of a plausible length
            bad = socket.create_connection(fe.address)
            bad.sendall(struct.pack(">Q", 16) + b"\xff" * 16)
            # slow-loris client: half a header, then silence
            loris = socket.create_connection(fe.address)
            loris.sendall(struct.pack(">Q", 100)[:3])
            res = [alice.fit("ridge", fp, mu=1.0, timeout=20.0)
                   for _ in range(3)]
            assert all(r["status"] == "ok" for r in res)
            deadline = time.monotonic() + 10.0
            while (fe.metrics.counter_value("service.severed") < 2
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert fe.metrics.counter_value("service.severed") == 2
            bad.close()
            loris.close()
        assert fe.zero_lost_requests()


def test_frontend_shutdown_answers_stranded_requests():
    D, b = _data()
    fe = FitFrontend(window=1024, flush_interval_s=30.0,
                     default_deadline_s=30.0)
    c = FitServiceClient(fe.address, tenant="t")
    fp = c.register(D, b)
    rid = c.fit_async("ridge", fp, mu=1.0)
    # wait until the request is queued server-side, then stop the service
    deadline = time.monotonic() + 5.0
    while (fe.status_counts()["in_flight"] < 1
           and time.monotonic() < deadline):
        time.sleep(0.01)
    fe.close()
    r = c.result(rid, timeout=10.0)
    assert r["status"] == "error" and "shutting down" in r["error"]
    c.close()
    assert fe.zero_lost_requests()
