"""Consensus ADMM baseline correctness + claim C4 (heterogeneity gap)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import ConsensusLasso, ConsensusLogistic, ConsensusSVM
from repro.core.oracles import (
    logistic_objective,
    newton_logistic,
    svm_dual_cd,
    svm_objective,
    lasso_objective,
)
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.core import gram as gram_lib
from repro.core.fasta import transpose_reduction_lasso
from repro.data.synthetic import classification_problem, lasso_problem

jax.config.update("jax_platform_name", "cpu")


def test_consensus_lasso_reaches_optimum():
    prob = lasso_problem(jax.random.PRNGKey(0), N=4, m_per_node=300, n=40)
    Dflat = prob.D.reshape(-1, 40)
    G, c = gram_lib.gram_and_rhs_chunked(Dflat, prob.b.reshape(-1))
    x_star = np.asarray(
        transpose_reduction_lasso(G, c, float(prob.mu), iters=3000).x)
    obj_star = lasso_objective(np.asarray(Dflat),
                               np.asarray(prob.b.reshape(-1)), x_star,
                               float(prob.mu))
    res = ConsensusLasso(mu=float(prob.mu), tau=1.0).run(
        prob.D, prob.b, iters=600)
    obj = lasso_objective(np.asarray(Dflat), np.asarray(prob.b.reshape(-1)),
                          np.asarray(res.z), float(prob.mu))
    assert obj - obj_star < 1e-2 * abs(obj_star)


def test_consensus_logistic_reaches_optimum():
    prob = classification_problem(jax.random.PRNGKey(1), N=4,
                                  m_per_node=150, n=15)
    D2 = np.asarray(prob.D.reshape(-1, 15))
    l2 = np.asarray(prob.labels.reshape(-1))
    obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
    res = ConsensusLogistic(tau=0.5).run(prob.D, prob.labels, iters=150)
    obj = logistic_objective(D2, l2, np.asarray(res.z))
    assert obj - obj_star < 2e-2 * abs(obj_star)


def test_consensus_svm_reaches_optimum():
    prob = classification_problem(jax.random.PRNGKey(2), N=4,
                                  m_per_node=100, n=12)
    D2 = np.asarray(prob.D.reshape(-1, 12))
    l2 = np.asarray(prob.labels.reshape(-1))
    obj_star = svm_objective(D2, l2, svm_dual_cd(D2, l2, 1.0, passes=1500),
                             1.0)
    res = ConsensusSVM(C=1.0, tau=1.0, cd_passes=6).run(
        prob.D, prob.labels, iters=150)
    obj = svm_objective(D2, l2, np.asarray(res.z), 1.0)
    assert obj - obj_star < 5e-2 * abs(obj_star) + 0.1


def _iters_to_tol(objs, obj_star, rel=1e-3):
    objs = np.asarray(objs)
    thresh = obj_star + rel * abs(obj_star)
    hits = np.nonzero(objs <= thresh)[0]
    return int(hits[0]) + 1 if len(hits) else len(objs)


def test_heterogeneity_hurts_consensus_not_transpose():
    """C4 (Fig. 2a vs 2b): per-node distribution shift slows consensus ADMM
    markedly while unwrapped/transpose ADMM is insensitive."""
    iters = {}
    for het in (0.0, 1.0):
        prob = classification_problem(jax.random.PRNGKey(3), N=8,
                                      m_per_node=120, n=15,
                                      heterogeneity=het)
        D2 = np.asarray(prob.D.reshape(-1, 15))
        l2 = np.asarray(prob.labels.reshape(-1))
        obj_star = logistic_objective(D2, l2, newton_logistic(D2, l2))
        rt = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(
            prob.D, prob.labels, iters=400)
        rc = ConsensusLogistic(tau=0.5).run(prob.D, prob.labels, iters=400)
        iters[("transpose", het)] = _iters_to_tol(
            rt.history.objective, obj_star)
        iters[("consensus", het)] = _iters_to_tol(
            rc.history.objective, obj_star)
    # consensus degrades under heterogeneity...
    assert iters[("consensus", 1.0)] > 1.5 * iters[("consensus", 0.0)]
    # ...transpose is (relatively) insensitive
    assert iters[("transpose", 1.0)] < 2.0 * iters[("transpose", 0.0)] + 10
    # and transpose beats consensus outright on heterogeneous data
    assert iters[("transpose", 1.0)] < iters[("consensus", 1.0)]
