"""Live observability plane tests (DESIGN.md §16).

Covers the request-scoped trace context (in-process and across the TCP
wire, old-format frames included), the scrape endpoint, declarative
SLOs with burn rates, the flight recorder and its breaker-trip trigger,
crash-safe artifacts (atexit / SIGTERM / SIGKILL), bounded-cardinality
per-tenant admission metrics, the bench regression comparator, and the
obs_report service mode."""
import importlib.util
import json
import os
import pickle
import signal
import socket
import struct
import subprocess
import sys
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.cluster.chaos import FaultEvent, FaultInjector
from repro.launch.obs_report import build_report, summarize_incident
from repro.obs import Observability, load_incident, read_jsonl
from repro.obs.context import (
    TraceContext,
    current_context,
    new_trace,
    use_context,
)
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry
from repro.obs.scrape import ScrapeServer, render_prometheus
from repro.obs.slo import BURN_CAP, Objective, SLOTracker
from repro.obs.telemetry import jsonable
from repro.obs.trace import Tracer, is_ancestor, load_trace, span_tree
from repro.service.admission import AdmissionController
from repro.service.frontend import (
    SERVICE_DATA_PLANE,
    FitFrontend,
    FitServiceClient,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _data(m=300, n=16, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    b = np.sign(D @ np.ones(n, np.float32) + 0.1).astype(np.float32)
    return D, b


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read().decode()


# ---------------------------------------------------------------------------
# trace context units
# ---------------------------------------------------------------------------

def test_context_child_and_wire_roundtrip():
    root = new_trace()
    child = root.child()
    assert child.trace_id == root.trace_id
    assert child.parent_id == root.span_id
    assert TraceContext.from_wire(root.to_wire()) == root
    # malformed wire forms decode to None, never raise
    for bad in (None, 17, "x", {}, {"trace_id": 1, "span_id": "a"},
                {"trace_id": "t"}):
        assert TraceContext.from_wire(bad) is None
    # non-string parent_id is dropped, context still usable
    ctx = TraceContext.from_wire({"trace_id": "t", "span_id": "s",
                                  "parent_id": 9})
    assert ctx is not None and ctx.parent_id is None


def test_use_context_is_scoped_and_none_is_noop():
    assert current_context() is None
    with use_context(None):
        assert current_context() is None
    ctx = new_trace()
    with use_context(ctx):
        assert current_context() is ctx
        with use_context(ctx.child()) as inner:
            assert current_context() is inner
        assert current_context() is ctx
    assert current_context() is None


def test_spans_chain_under_active_context_and_stamp_args():
    tr = Tracer(enabled=True)
    with use_context(new_trace()):
        root_ctx = current_context()
        with tr.span("outer"):
            with tr.span("inner"):
                pass
    evs = [e for e in tr.events() if e.get("ph") == "X"]
    by_name = {e["name"]: e["args"] for e in evs}
    assert by_name["outer"]["parent_id"] == root_ctx.span_id
    assert by_name["inner"]["parent_id"] == by_name["outer"]["span_id"]
    assert (by_name["inner"]["trace_id"] == by_name["outer"]["trace_id"]
            == root_ctx.trace_id)
    assert is_ancestor(evs, by_name["outer"]["span_id"],
                       by_name["inner"]["span_id"])
    assert not is_ancestor(evs, by_name["inner"]["span_id"],
                           by_name["outer"]["span_id"])


def test_complete_at_records_retroactive_child_span():
    tr = Tracer(enabled=True)
    ctx = new_trace()
    t0_us = time.time_ns() // 1000 - 50_000
    tr.complete_at("queue_wait", t0_us, 0.05, ctx=ctx, tenant="t")
    (ev,) = [e for e in tr.events() if e.get("ph") == "X"]
    assert ev["ts"] == t0_us and ev["dur"] == pytest.approx(50_000)
    assert ev["args"]["parent_id"] == ctx.span_id
    assert ev["args"]["tenant"] == "t"


# ---------------------------------------------------------------------------
# cross-process propagation over TCP (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_trace_propagates_through_chaos_slowed_cold_solve(tmp_path):
    """One traced fit against a frontend whose cold backend is slowed by
    seeded chaos: every span lands in ONE trace, the client span is the
    ancestor of the cold-executor span, and the cold span's duration
    SHOWS the injected stall."""
    D, b = _data()
    obs = Observability(dir=str(tmp_path / "run"), process_name="frontend",
                        crash_flush=False)
    # the traced logistic fit is fit_seq 2 (register is not a fit;
    # the warm ridge below is 1) — stall exactly that cold solve
    chaos = FaultInjector([FaultEvent(2, "svc", "slow", 300.0)],
                          data_plane=SERVICE_DATA_PLANE)
    client_tr = Tracer(enabled=True, process_name="client")
    fe = FitFrontend(window=2, flush_interval_s=0.005, chaos=chaos,
                     obs=obs, cold_budget_s=30.0)
    try:
        with FitServiceClient(fe.address, tenant="traced",
                              tracer=client_tr) as c:
            fp = c.register(D, b)
            assert c.fit("ridge", fp, mu=1.0, timeout=60.0)["status"] == "ok"
            r = c.fit("logistic", fp, iters=50, timeout=60.0)
            assert r["status"] == "ok"
    finally:
        fe.close()
        obs.finish()
    fe.tracer.add_events(client_tr.events())
    evs = [e for e in fe.tracer.events() if e.get("ph") == "X"]
    fits = [e for e in evs if e["name"] == "client.fit"
            and e["args"].get("problem") == "logistic"]
    assert len(fits) == 1
    tid = fits[0]["args"]["trace_id"]
    in_trace = [e for e in evs if (e.get("args") or {}).get("trace_id") == tid]
    names = {e["name"] for e in in_trace}
    assert {"client.fit", "client.submit", "frontend.admit",
            "frontend.queue_wait", "frontend.cold_solve"} <= names
    (cold,) = [e for e in in_trace if e["name"] == "frontend.cold_solve"]
    assert is_ancestor(evs, fits[0]["args"]["span_id"],
                       cold["args"]["span_id"])
    assert cold["dur"] >= 300e3          # µs: the chaos stall is visible
    # every span of the request resolves to a single tree (no orphans
    # besides the root client span)
    tree = span_tree(in_trace)
    for e in in_trace:
        pid = e["args"].get("parent_id")
        if e["name"] != "client.fit":
            assert pid is not None
    assert fits[0]["args"]["span_id"] in tree


def test_queue_wait_span_reconciles_with_dispatch_histogram():
    D, b = _data()
    obs = Observability(dir=None, enabled=True, crash_flush=False)
    fe = FitFrontend(window=4, flush_interval_s=0.005, obs=obs)
    tr = Tracer(enabled=True)
    try:
        with FitServiceClient(fe.address, tenant="t", tracer=tr) as c:
            fp = c.register(D, b)
            for _ in range(5):
                assert c.fit("ridge", fp, mu=1.0,
                             timeout=60.0)["status"] == "ok"
    finally:
        fe.close()
    waits = [e for e in fe.tracer.events()
             if e.get("ph") == "X" and e["name"] == "frontend.queue_wait"]
    (hist,) = [h for h in fe.metrics.snapshot()["histograms"]
               if h["name"] == "service.dispatch_wait_s"]
    assert hist["count"] == len(waits) == 5
    span_sum_s = sum(e["dur"] for e in waits) / 1e6
    assert span_sum_s == pytest.approx(hist["sum"], rel=0.05, abs=0.05)
    # each queue-wait span is parented under its request's context
    for e in waits:
        assert e["args"].get("parent_id") is not None


def test_old_format_frames_still_decode(tmp_path):
    """Peers that predate the _ctx field must interoperate both ways:
    an untraced client sends no _ctx, and a hand-built PR 9-format frame
    (raw length-prefixed pickle, no _ctx key) gets served."""
    D, b = _data()
    obs = Observability(dir=str(tmp_path / "run"), process_name="frontend",
                        crash_flush=False)
    fe = FitFrontend(window=2, flush_interval_s=0.005, obs=obs)
    try:
        with FitServiceClient(fe.address, tenant="legacy") as c:
            fp = c.register(D, b)
            r = c.fit("ridge", fp, mu=1.0, timeout=60.0)
            assert r["status"] == "ok" and "_ctx" not in r
        # admit span exists but starts its own (context-less) lineage
        admits = [e for e in fe.tracer.events()
                  if e.get("ph") == "X" and e["name"] == "frontend.admit"]
        assert admits and all("trace_id" not in (e.get("args") or {})
                              for e in admits)
        # raw PR 9 frame bytes, no transport helper involved
        raw = pickle.dumps({"type": "ping", "rid": 7, "tenant": "old"},
                           protocol=pickle.HIGHEST_PROTOCOL)
        s = socket.create_connection(fe.address, timeout=5.0)
        try:
            s.sendall(struct.pack(">Q", len(raw)) + raw)
            hdr = b""
            while len(hdr) < 8:
                hdr += s.recv(8 - len(hdr))
            (ln,) = struct.unpack(">Q", hdr)
            body = b""
            while len(body) < ln:
                body += s.recv(ln - len(body))
            reply = pickle.loads(body)
            assert reply["type"] == "pong" and reply["rid"] == 7
        finally:
            s.close()
    finally:
        fe.close()
        obs.finish()


def test_traced_frames_are_ignored_gracefully_by_raw_reader():
    """The _ctx field is additive: a frame sent from inside an active
    context carries it, and a reader that only looks at the keys it
    knows still gets everything it asked for."""
    from repro.cluster.transport import Listener, connect
    lst = Listener("127.0.0.1", 0)
    try:
        got = {}

        def _serve():
            conn = lst.accept(timeout=5.0)
            got.update(conn.recv(timeout=5.0))
            conn.close()

        import threading
        th = threading.Thread(target=_serve, daemon=True)
        th.start()
        conn = connect(lst.address, timeout=5.0)
        ctx = new_trace()
        with use_context(ctx):
            conn.send("ping", rid=1)
        th.join(timeout=5.0)
        conn.close()
        assert got["type"] == "ping" and got["rid"] == 1
        assert got["_ctx"] == ctx.to_wire()
        assert TraceContext.from_wire(got["_ctx"]) == ctx
    finally:
        lst.close()


# ---------------------------------------------------------------------------
# scrape endpoint
# ---------------------------------------------------------------------------

def test_render_prometheus_groups_and_types():
    snap = {
        "counters": [
            {"name": "svc.b", "labels": {}, "value": 1},
            {"name": "svc.a", "labels": {"k": "1"}, "value": 2},
            {"name": "svc.b", "labels": {"k": "2"}, "value": 3},
        ],
        "gauges": [{"name": "g.x", "labels": {}, "value": 1.5}],
        "histograms": [],
    }
    text = render_prometheus(snap)
    lines = [ln for ln in text.splitlines() if ln]
    # one TYPE line per metric, all samples of a metric contiguous
    assert lines.count("# TYPE svc_b_total counter") == 1
    bi = [i for i, ln in enumerate(lines) if ln.startswith("svc_b_total")]
    assert bi == list(range(bi[0], bi[0] + 2))
    assert 'svc_a_total{k="1"} 2' in lines
    assert "# TYPE g_x gauge" in lines and "g_x 1.5" in lines


def test_render_prometheus_histogram_summary():
    reg = MetricsRegistry()
    for v in [0.01, 0.02, 0.03, 0.5]:
        reg.observe("lat_s", v, kind="warm")
    text = render_prometheus(reg.snapshot())
    assert "# TYPE lat_s summary" in text
    assert 'lat_s{kind="warm",quantile="0.5"}' in text
    assert 'lat_s_count{kind="warm"} 4' in text


def test_scrape_server_routes_live_registry():
    reg = MetricsRegistry()
    reg.inc("hits", route="a")
    srv = ScrapeServer(lambda: reg.snapshot(),
                       health_fn=lambda: {"status": "ok", "n": 1},
                       slo_fn=lambda: {"objectives": [], "ok": True})
    try:
        st, text = _get(srv.url("/metrics"))
        assert st == 200 and 'hits_total{route="a"} 1' in text
        # the snapshot callable runs per scrape: counters move live
        reg.inc("hits", route="a")
        _, js = _get(srv.url("/metrics.json"))
        snap = json.loads(js)
        assert [c["value"] for c in snap["counters"]
                if c["name"] == "hits"] == [2]
        st, hz = _get(srv.url("/healthz"))
        assert st == 200 and json.loads(hz)["status"] == "ok"
        st, slo = _get(srv.url("/slo"))
        assert st == 200 and json.loads(slo)["ok"] is True
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url("/nope"))
        assert ei.value.code == 404
    finally:
        srv.close()


def test_scrape_callback_error_is_500_not_thread_death():
    boom = {"on": True}

    def snap():
        if boom["on"]:
            raise RuntimeError("kaboom")
        return {"counters": [], "gauges": [], "histograms": []}

    srv = ScrapeServer(snap)
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url("/metrics"))
        assert ei.value.code == 500
        boom["on"] = False
        st, _ = _get(srv.url("/metrics"))   # thread survived the error
        assert st == 200
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# SLOs
# ---------------------------------------------------------------------------

def _objs():
    return (
        Objective(name="avail", kind="availability", target=0.8),
        Objective(name="warm_lat", kind="latency", target=0.9,
                  threshold_s=1.0, scope="warm"),
        Objective(name="zero_lost", kind="external", target=1.0),
    )


def test_slo_availability_and_burn_rate():
    tr = SLOTracker(window_s=60.0)
    for _ in range(9):
        tr.record("ok", latency_s=0.1, warm=True)
    tr.record("error", latency_s=0.1, warm=True)
    ev = tr.evaluate(_objs(), external={"zero_lost": True})
    by = {o["name"]: o for o in ev["objectives"]}
    assert by["avail"]["sli"] == pytest.approx(0.9)
    # 10% bad against a 20% budget burns at half the sustainable rate
    assert by["avail"]["burn_rate"] == pytest.approx(0.5)
    assert by["avail"]["ok"] is True and ev["ok"] is True


def test_slo_latency_scope_and_threshold():
    tr = SLOTracker(window_s=60.0)
    for _ in range(8):
        tr.record("ok", latency_s=0.2, warm=True)
    tr.record("ok", latency_s=3.0, warm=True)      # warm, slow
    tr.record("ok", latency_s=9.0, warm=False)     # cold: out of scope
    by = {o["name"]: o
          for o in tr.evaluate(_objs(),
                               external={"zero_lost": True})["objectives"]}
    assert by["warm_lat"]["events"] == 9
    assert by["warm_lat"]["sli"] == pytest.approx(8 / 9)
    assert by["warm_lat"]["ok"] is False


def test_slo_external_zero_tolerance_and_unknown():
    tr = SLOTracker(window_s=60.0)
    tr.record("ok")
    by = {o["name"]: o
          for o in tr.evaluate(_objs(),
                               external={"zero_lost": False})["objectives"]}
    assert by["zero_lost"]["ok"] is False
    assert by["zero_lost"]["burn_rate"] == BURN_CAP
    ev = tr.evaluate(_objs())              # no external supplied
    by = {o["name"]: o for o in ev["objectives"]}
    assert by["zero_lost"]["ok"] is None
    assert ev["ok"] is True                # unknown is not a failure


def test_slo_window_expiry_and_empty_pool():
    tr = SLOTracker(window_s=10.0)
    now = time.monotonic()
    tr.record("error", t=now - 60.0)       # long expired
    ev = tr.evaluate(_objs(), external={"zero_lost": True}, now=now)
    by = {o["name"]: o for o in ev["objectives"]}
    assert by["avail"]["ok"] is None and by["avail"]["events"] == 0


def test_slo_export_gauges():
    tr = SLOTracker(window_s=60.0)
    tr.record("ok", latency_s=0.1, warm=True)
    reg = MetricsRegistry()
    tr.export_gauges(reg, objectives=_objs(),
                     external={"zero_lost": True})
    snap = reg.snapshot()
    gauges = {(g["name"], g["labels"].get("objective")): g["value"]
              for g in snap["gauges"]}
    assert gauges[("slo.sli", "avail")] == 1.0
    assert gauges[("slo.ok", "zero_lost")] == 1.0


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_ring_is_bounded_and_ordered(tmp_path):
    fr = FlightRecorder(dir=str(tmp_path), capacity=8, window_s=60.0)
    for i in range(20):
        fr.note("tick", i=i)
    snap = fr.snapshot()
    assert snap["events_recorded"] == 20 and snap["ring_size"] == 8
    path = fr.incident("probe")
    doc = load_incident(path)
    assert [e["i"] for e in doc["events"]] == list(range(12, 20))


def test_flight_incident_stamps_trace_context(tmp_path):
    fr = FlightRecorder(dir=str(tmp_path))
    ctx = new_trace()
    with use_context(ctx):
        fr.note("respond", status="error")
    doc = load_incident(fr.incident("status_error", rid=3))
    assert doc["trigger"]["rid"] == 3
    assert doc["events"][-1]["trace_id"] == ctx.trace_id


def test_flight_incident_cap_counts_drops(tmp_path):
    fr = FlightRecorder(dir=str(tmp_path), max_incidents=2)
    fr.note("x")
    assert fr.incident("a") and fr.incident("b")
    assert fr.incident("c") is None
    snap = fr.snapshot()
    assert snap["incidents"] == 2 and snap["incidents_dropped"] == 1
    assert len(fr.incidents()) == 2


def test_disabled_flight_recorder_is_noop():
    fr = FlightRecorder(dir=None, enabled=False)
    fr.note("x")
    assert fr.incident("y") is None
    assert fr.snapshot()["events_recorded"] == 0


def test_breaker_trip_dumps_incident(tmp_path, monkeypatch):
    """The designed cascade: cold-backend exceptions trip the breaker,
    and the closed→open transition dumps a flight incident that
    obs_report can read back."""
    D, b = _data()
    obs = Observability(dir=str(tmp_path / "run"), process_name="frontend",
                        crash_flush=False)
    fe = FitFrontend(window=2, flush_interval_s=0.005, obs=obs,
                     breaker_threshold=2, breaker_reset_s=30.0)
    monkeypatch.setattr(
        fe.server, "solve_one",
        lambda req: (_ for _ in ()).throw(RuntimeError("backend down")))
    try:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)
            for _ in range(2):
                r = c.fit("logistic", fp, iters=10, timeout=60.0)
                assert r["status"] in ("error", "degraded")
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and not fe.flight.incidents():
            time.sleep(0.02)
        summaries = [summarize_incident(p)
                     for p in fe.flight.incidents()]
        trips = [s for s in summaries if s["reason"] == "breaker_trip"]
        assert trips
        summary = trips[0]
        assert summary["events_by_kind"].get("admit", 0) >= 1
        assert fe.metrics.counter_value("service.breaker_trips") >= 1
    finally:
        fe.close()
        obs.finish()
    # the incident file lives under RUNDIR/incidents/ where the report
    # generator scans for it
    rd = str(tmp_path / "run")
    report = build_report(rd)
    assert any(i.get("reason") == "breaker_trip"
               for i in report.get("incidents", []))


# ---------------------------------------------------------------------------
# crash-safe artifacts
# ---------------------------------------------------------------------------

_CHILD = r"""
import os, sys, time
from repro.obs import Observability
obs = Observability(dir=sys.argv[1], process_name="victim")
obs.inc("child.counter", 3)
with obs.span("child.work"):
    pass
for i in range(20):
    obs.record(iter=i, objective=float(i))
mode = sys.argv[2]
if mode == "atexit":
    sys.exit(0)                      # no finish(): atexit must flush
obs.flush()
print("READY", flush=True)
while True:                          # parent kills us here
    obs.record(iter=999, objective=0.0)
    time.sleep(0.01)
"""


def _spawn_victim(tmp_path, mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, "-c", _CHILD, str(tmp_path), mode],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, env=env,
        text=True)


def _wait_ready(proc, timeout=60.0):
    line = proc.stdout.readline()
    assert "READY" in line


def test_atexit_flushes_artifacts_without_finish(tmp_path):
    proc = _spawn_victim(tmp_path, "atexit")
    assert proc.wait(timeout=120.0) == 0
    snap = json.load(open(tmp_path / "metrics.json"))
    assert [c["value"] for c in snap["counters"]
            if c["name"] == "child.counter"] == [3]
    evs = load_trace(str(tmp_path / "trace.json"))
    assert any(e.get("name") == "child.work" for e in evs)
    recs = read_jsonl(str(tmp_path / "telemetry.jsonl"))
    assert len(recs) == 20


def test_sigterm_flushes_then_dies_with_conventional_status(tmp_path):
    proc = _spawn_victim(tmp_path, "loop")
    _wait_ready(proc)
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=120.0)
    assert rc == -signal.SIGTERM
    assert any(e.get("name") == "child.work"
               for e in load_trace(str(tmp_path / "trace.json")))
    assert len(read_jsonl(str(tmp_path / "telemetry.jsonl"))) >= 20


def test_sigkill_leaves_loadable_artifacts(tmp_path):
    """SIGKILL mid-write: everything written before the kill loads
    cleanly through the tolerant readers."""
    proc = _spawn_victim(tmp_path, "loop")
    _wait_ready(proc)
    time.sleep(0.1)                    # let it write mid-loop records
    proc.kill()
    proc.wait(timeout=60.0)
    recs = read_jsonl(str(tmp_path / "telemetry.jsonl"))
    assert len(recs) >= 20             # pre-kill records all present
    assert [r["iter"] for r in recs[:20]] == list(range(20))
    evs = load_trace(str(tmp_path / "trace.json"))
    assert any(e.get("name") == "child.work" for e in evs)


def test_truncated_artifacts_salvage(tmp_path):
    obs = Observability(dir=str(tmp_path), process_name="t",
                        crash_flush=False)
    for i in range(5):
        obs.record(iter=i)
    with obs.span("kept"):
        pass
    obs.finish()
    # tear both files the way a dying writer would
    tpath = tmp_path / "telemetry.jsonl"
    tpath.write_text(tpath.read_text() + '{"iter": 99, "obj')
    trpath = tmp_path / "trace.json"
    raw = trpath.read_text()
    trpath.write_text(raw[:int(len(raw) * 0.7)])
    recs = read_jsonl(str(tpath))
    assert [r["iter"] for r in recs] == list(range(5))
    evs = load_trace(str(trpath))      # salvages complete event objects
    assert isinstance(evs, list)


# ---------------------------------------------------------------------------
# per-tenant admission metrics (bounded cardinality)
# ---------------------------------------------------------------------------

def test_admission_emits_bounded_tenant_labels():
    reg = MetricsRegistry()
    ac = AdmissionController(max_queue=100, tenant_rate=1000.0,
                             registry=reg, max_labeled_tenants=4)
    for i in range(10):
        assert ac.admit(f"tenant-{i}", in_flight=0).ok
    admitted = reg.labeled("admission.admitted", "tenant")
    assert sum(admitted.values()) == 10
    assert len(admitted) == 5          # 4 real labels + _other
    assert admitted["_other"] == 6
    # token gauges use the same capped names
    assert set(ac.bucket_levels()) <= set(admitted)


def test_admission_reject_reason_labeled():
    reg = MetricsRegistry()
    ac = AdmissionController(max_queue=2, tenant_rate=1.0, tenant_burst=1.0,
                             registry=reg)
    assert ac.admit("t", in_flight=0).ok
    assert not ac.admit("t", in_flight=0).ok       # quota
    assert not ac.admit("t", in_flight=2).ok       # queue_full
    rej = reg.labeled("admission.rejected", "reason")
    assert rej == {"quota": 1, "queue_full": 1}


def test_frontend_scrape_reconciles_with_status_counts(tmp_path):
    D, b = _data()
    obs = Observability(dir=str(tmp_path / "run"), process_name="frontend",
                        crash_flush=False)
    fe = FitFrontend(window=2, flush_interval_s=0.005, obs=obs,
                     scrape_port=0)
    try:
        with FitServiceClient(fe.address, tenant="t") as c:
            fp = c.register(D, b)
            for _ in range(3):
                assert c.fit("ridge", fp, mu=1.0,
                             timeout=60.0)["status"] == "ok"
        _, js = _get(fe.scrape.url("/metrics.json"))
        snap = json.loads(js)
        responded = sum(c0["value"] for c0 in snap["counters"]
                        if c0["name"] == "service.responses")
        assert responded == fe.status_counts()["ok"] == 3
        # live gauges and SLO gauges ride the same scrape
        names = {g["name"] for g in snap["gauges"]}
        assert {"service.queue_depth", "service.uptime_s",
                "breaker.open", "slo.sli"} <= names
        _, slo = _get(fe.scrape.url("/slo"))
        doc = json.loads(slo)
        by = {o["name"]: o for o in doc["objectives"]}
        assert by["zero_lost"]["ok"] is True
        assert by["availability"]["sli"] == 1.0
    finally:
        fe.close()
        obs.finish()


# ---------------------------------------------------------------------------
# bench_compare
# ---------------------------------------------------------------------------

def _bench_compare():
    spec = importlib.util.spec_from_file_location(
        "bench_compare", os.path.join(REPO, "scripts", "bench_compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _service_doc(p99=20.0, rps=100.0, cpu=8, quick=True):
    bc = _bench_compare()
    meta = {f: "x" for f in bc.FINGERPRINT_FIELDS}
    meta["cpu_count"] = cpu
    return {"host_meta": meta, "quick": quick,
            "warm_latency": {"p50_ms": 10.0, "p99_ms": p99},
            "healthy_responses_per_s": rps}


def test_bench_compare_flags_regressions_both_directions():
    bc = _bench_compare()
    base = _service_doc()
    res = bc.compare_docs("BENCH_service.json", _service_doc(p99=50.0),
                          base, threshold=0.25)
    assert not res["skipped"] and res["regressions"] == 1
    (bad,) = [r for r in res["rows"] if r["regressed"]]
    assert bad["series"] == "warm_latency.p99_ms"
    # throughput is higher-is-better: a drop regresses, a gain does not
    res = bc.compare_docs("BENCH_service.json", _service_doc(rps=50.0),
                          base, threshold=0.25)
    assert res["regressions"] == 1
    res = bc.compare_docs("BENCH_service.json",
                          _service_doc(p99=10.0, rps=200.0), base, 0.25)
    assert res["regressions"] == 0


def test_bench_compare_skips_on_fingerprint_or_quick_mismatch():
    bc = _bench_compare()
    base = _service_doc()
    res = bc.compare_docs("BENCH_service.json", _service_doc(cpu=64),
                          base, 0.25)
    assert res["skipped"] and "fingerprint" in res["reason"]
    res = bc.compare_docs("BENCH_service.json", _service_doc(quick=False),
                          base, 0.25)
    assert res["skipped"] and "quick" in res["reason"]


def test_bench_compare_run_and_exit_codes(tmp_path):
    bc = _bench_compare()
    cur, basedir = tmp_path / "cur", tmp_path / "base"
    cur.mkdir(), basedir.mkdir()
    (cur / "BENCH_service.json").write_text(
        json.dumps(_service_doc(p99=100.0)))
    (basedir / "BENCH_service.json").write_text(json.dumps(_service_doc()))
    report = bc.run(current_dir=str(cur), baseline_dir=str(basedir),
                    files=["BENCH_service.json"])
    assert report["compared"] == 1 and report["regressions"] == 1
    assert bc.main(["--current-dir", str(cur),
                    "--baseline-dir", str(basedir),
                    "--files", "BENCH_service.json"]) == 1
    assert bc.main(["--current-dir", str(cur),
                    "--baseline-dir", str(basedir),
                    "--files", "BENCH_service.json", "--no-fail"]) == 0
    # a missing baseline is a skip, not a failure
    report = bc.run(current_dir=str(cur),
                    baseline_dir=str(tmp_path / "empty"),
                    files=["BENCH_service.json"])
    assert report["skipped"] == 1 and report["compared"] == 0


# ---------------------------------------------------------------------------
# obs_report service mode
# ---------------------------------------------------------------------------

def test_obs_report_renders_service_section(tmp_path):
    reg = MetricsRegistry()
    for status, n in (("ok", 5), ("degraded", 1), ("rejected", 2)):
        for _ in range(n):
            reg.inc("service.responses", status=status)
    reg.inc("service.fit_seen", 8, tenant="t0")
    reg.inc("service.degraded", why="cold solve blew its budget")
    reg.inc("admission.admitted", 6, tenant="t0")
    reg.inc("admission.rejected", 2, tenant="t0", reason="quota")
    for v in (0.01, 0.02):
        reg.observe("server.fit_latency_s", v, kind="warm")
    rundir = tmp_path / "run"
    rundir.mkdir()
    (rundir / "metrics.json").write_text(
        json.dumps(jsonable(reg.snapshot())))
    report = build_report(str(rundir))
    svc = report["service"]
    assert svc["status_mix"] == {"ok": 5, "degraded": 1, "rejected": 2}
    (tenant_row,) = svc["per_tenant"]
    assert tenant_row["tenant"] == "t0"
    assert tenant_row["admitted"] == 6 and tenant_row["rejected"] == 2
    assert svc["degrade_why"] == {"cold solve blew its budget": 1}
