"""WKV Pallas kernel (VMEM-resident state) vs the exact per-step oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv.ops import wkv
from repro.kernels.wkv.ref import wkv_ref

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("B,H,T,hd,chunk", [
    (1, 2, 32, 16, 8),
    (2, 4, 64, 32, 16),
    (1, 1, 48, 64, 16),
])
def test_wkv_kernel_matches_step_oracle(B, H, T, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, H, T, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, H, T, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, hd)) * 0.5
    # realistic data-dependent decay: log w = -exp(N(-2,1)), clamped in ops
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, H, T, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    y = wkv(r, k, v, w_log, u, chunk=chunk, interpret=True)
    w_clamped = jnp.maximum(w_log, -5.0)
    for b in range(B):
        for h in range(H):
            y_ref, _ = wkv_ref(r[b, h], k[b, h], v[b, h],
                               w_clamped[b, h], u[h])
            np.testing.assert_allclose(np.asarray(y[b, h]),
                                       np.asarray(y_ref),
                                       atol=2e-4, rtol=1e-3)


def test_wkv_kernel_matches_model_chunked_form():
    """The kernel and the model's XLA matmul form are the same math."""
    from repro.models.rwkv6 import _wkv_chunked_matmul
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    T, hd = 64, 32
    r = jax.random.normal(ks[0], (T, hd)) * 0.5
    k = jax.random.normal(ks[1], (T, hd)) * 0.5
    v = jax.random.normal(ks[2], (T, hd)) * 0.5
    w_log = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (T, hd)) - 2.0),
                        -5.0)
    u = jax.random.normal(ks[4], (hd,)) * 0.3
    y_xla, _ = _wkv_chunked_matmul(r, k, v, w_log, u, chunk=16)
    y_krn = wkv(r[None, None], k[None, None], v[None, None],
                w_log[None, None], u[None], chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_krn[0, 0]), np.asarray(y_xla),
                               atol=2e-5)


def test_wkv_kernel_hard_decay_stable():
    B, H, T, hd = 1, 1, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    r = jax.random.normal(ks[0], (B, H, T, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd))
    v = jax.random.normal(ks[2], (B, H, T, hd))
    w_log = jnp.full((B, H, T, hd), -50.0)   # instant forgetting (clamped)
    u = jnp.ones((H, hd))
    y = wkv(r, k, v, w_log, u, chunk=8, interpret=True)
    assert bool(jnp.isfinite(y).all())
