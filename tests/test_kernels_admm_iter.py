"""Fused ADMM-iteration Pallas kernel vs jnp oracle (§Perf Iter C3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.admm_iter.ops import admm_iter
from repro.kernels.admm_iter.ref import admm_iter_ref

jax.config.update("jax_platform_name", "cpu")

CASES = [
    (2048, 128, jnp.float32, "logistic"),
    (3000, 307, jnp.float32, "logistic"),   # star-cell feature count, ragged m
    (2048, 256, jnp.bfloat16, "logistic"),
    (1500, 64, jnp.float32, "hinge"),
    (777, 33, jnp.float32, "l1"),
]


@pytest.mark.parametrize("m,n,dt,kind", CASES)
def test_fused_iter_matches_ref(m, n, dt, kind):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    D = jax.random.normal(ks[0], (m, n), dt)
    aux = jnp.sign(jax.random.normal(ks[1], (m,)))
    y = jax.random.normal(ks[2], (m,))
    lam = jax.random.normal(ks[3], (m,))
    x = jax.random.normal(ks[4], (n,)) * 0.1
    y1, l1, d1 = admm_iter(D, aux, y, lam, x, kind=kind, delta=2.0,
                           block_m=512, interpret=True)
    y2, l2, d2 = admm_iter_ref(D, aux, y, lam, x, kind=kind, delta=2.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-5, atol=2e-3 * float(jnp.max(jnp.abs(d2))))


def test_fused_iter_advances_admm_exactly():
    """One kernel call must equal one UnwrappedADMM.step (same y/lam/d)."""
    from repro.core import gram as gram_lib
    from repro.core.prox import make_logistic
    from repro.core.unwrapped import UnwrappedADMM
    key = jax.random.PRNGKey(1)
    m, n = 1024, 32
    D = jax.random.normal(key, (m, n))
    labels = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (m,)))
    tau = 0.1
    solver = UnwrappedADMM(loss=make_logistic(), tau=tau)
    L = solver.setup(D[None])
    y = jnp.zeros((1, m))
    lam = jnp.zeros((1, m))
    # reference step
    x_ref, Dx, y_ref, lam_ref = solver.step(L, D[None], labels[None], y, lam)
    # kernel path: x from the same solve, then the fused body
    d0 = jnp.einsum("mn,m->n", D, (y - lam)[0])
    x_k = gram_lib.gram_solve(L, d0)
    yk, lk, dk = admm_iter(D, labels, y[0], lam[0], x_k,
                           kind="logistic", delta=1.0 / tau, interpret=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y_ref[0]),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lam_ref[0]),
                               atol=3e-5)
    # and d feeds the NEXT x-update identically
    d_ref = jnp.einsum("mn,m->n", D, (y_ref - lam_ref)[0])
    np.testing.assert_allclose(np.asarray(dk), np.asarray(d_ref), rtol=1e-4,
                               atol=1e-3)
