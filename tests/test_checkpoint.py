"""Checkpoint manager: atomicity, integrity, GC, elastic restore."""
import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager

jax.config.update("jax_platform_name", "cpu")


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": scale * jax.random.normal(k1, (16, 8)),
            "b": {"c": scale * jax.random.normal(k2, (4,)),
                  "d": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(0))
    m.save(7, tree, extra={"step": 7, "note": "x"})
    restored, extra = m.restore(tree)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 5, 9, 12):
        m.save(s, tree, extra={"step": s})
    assert m.latest_step() == 12
    assert m.all_steps() == [9, 12]  # gc kept last 2


def test_background_save(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    m.save(3, tree, extra={"step": 3}, background=True)
    m.wait()
    restored, extra = m.restore(tree)
    assert extra["step"] == 3


def test_corruption_detected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(3))
    m.save(1, tree, extra={"step": 1})
    # flip bytes in a leaf
    leaf = tmp_path / "step_00000001" / "leaf_0.npy"
    data = bytearray(leaf.read_bytes())
    data[-5] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError, match="corruption"):
        m.restore(tree)


def test_uncommitted_tmp_ignored(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(4))
    m.save(1, tree, extra={"step": 1})
    # simulate a crash mid-write: a stale .tmp directory
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "leaf_0.npy").write_bytes(b"garbage")
    assert m.latest_step() == 1
    restored, extra = m.restore(tree)
    assert extra["step"] == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Values survive re-placement on a different topology (here: a simple
    device_put with a new sharding spec — the mesh-size-change path)."""
    m = CheckpointManager(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(5))
    m.save(1, tree, extra={"step": 1})
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    restored, _ = m.restore(tree, shardings=shardings)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_killed_writer_never_corrupts(tmp_path):
    """SIGKILL a process mid-save: previously committed step must survive
    and restore cleanly (the .tmp of the interrupted save is ignored)."""
    script = f"""
import sys, os, signal
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.checkpoint.manager import CheckpointManager
m = CheckpointManager({str(tmp_path)!r})
tree = {{"w": jnp.ones((2048, 512)), "b": jnp.zeros((4096,))}}
m.save(1, tree, extra={{"step": 1}})
# start a big save then die immediately
import threading
t = threading.Thread(target=m.save, args=(2, tree), kwargs={{"extra": {{"step": 2}}}})
t.start()
os.kill(os.getpid(), signal.SIGKILL)
"""
    p = subprocess.run([sys.executable, "-c", script],
                       cwd=str(Path(__file__).parent.parent),
                       capture_output=True)
    assert p.returncode != 0  # killed
    m = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((2048, 512)), "b": jnp.zeros((4096,))}
    step = m.latest_step()
    assert step in (1, 2)  # either committed fully or not at all
    restored, extra = m.restore(tree, step=step)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.ones((2048, 512)))
