"""Multi-process cluster runtime (ISSUE 5 tentpole): shared int8-EF
compression, tree-reduce contributions, membership/reassignment plans,
store content verification, streaming + cluster checkpoint/resume, and
real-process solves — including worker SIGKILL mid-solve with block
reassignment — against the single-process reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.cluster import compress
from repro.cluster.membership import DeadCluster, Membership, WorkerInfo
from repro.cluster.reduction import (
    Contribution,
    TreeTopology,
    decode,
    encode,
)
from repro.core.oracles import logistic_objective
from repro.core.prox import make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.store import ShardedMatrixStore

from exec_fixtures import cluster_problem as _problem

jax.config.update("jax_platform_name", "cpu")

TAU = 0.1
TINY = dict(eps_rel=1e-9, eps_abs=1e-12)   # fixed-iteration parity runs


@pytest.fixture(scope="module")
def ref40():
    """Single-process reference: 40 fixed iterations on the module
    problem (the cluster runs must land on the same x)."""
    D, aux = _problem()
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    res = solver.run(D[None], aux[None], iters=40)
    return D, aux, np.asarray(res.x)


# ---------------------------------------------------------------------------
# compression (shared with core/distributed.py)
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    for n in (7, 32, 256, 700):
        v = jnp.asarray(rng.standard_normal(n), jnp.float32)
        q, s = compress.quantize_int8(v)
        r = compress.dequantize_int8(q, s, n)
        # symmetric int8: error <= half a quantization step per group
        assert float(jnp.max(jnp.abs(v - r))) <= float(jnp.max(s)) * 0.5001
        assert q.dtype == jnp.int8


def test_adaptive_group_never_inflates_payload():
    # an n=32 vector must not be padded out to a 256-byte group
    assert compress.wire_bytes(32, True) < compress.wire_bytes(32, False)
    assert compress.wire_bytes(512, True) < 0.3 * compress.wire_bytes(
        512, False)


def test_error_feedback_unbiased_over_stream():
    """Summing an EF-compressed stream tracks the true running sum to
    one quantization step — the property that lets ADMM tolerate the
    compressed reduction."""
    rng = np.random.default_rng(1)
    err = jnp.zeros((64,), jnp.float32)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for _ in range(50):
        v = jnp.asarray(rng.standard_normal(64), jnp.float32)
        q, s, err = compress.ef_compress(v, err)
        true_sum += np.asarray(v)
        deq_sum += np.asarray(compress.dequantize_int8(q, s, 64))
    # without EF the bias would grow ~ sqrt(iters) * step; with EF the
    # gap stays bounded by the single residual still held in err
    np.testing.assert_allclose(deq_sum, true_sum - np.asarray(err),
                               rtol=0, atol=1e-4)


def test_shard_map_path_uses_shared_impl():
    # repro.cluster.compress is the ONE canonical int8 EF module: the
    # shard_map psum imports ef_compress from it directly, and the old
    # underscored re-exports are gone (callers import the real names)
    from repro.core import distributed
    assert distributed.ef_compress is compress.ef_compress
    assert not hasattr(distributed, "_quantize_int8")
    assert not hasattr(distributed, "_dequantize_int8")


# ---------------------------------------------------------------------------
# reduction container + tree topology
# ---------------------------------------------------------------------------

def test_contribution_encode_decode_merge():
    rng = np.random.default_rng(2)

    def mk(wid, it=3):
        return Contribution(
            iteration=it, workers=(wid,), rows=100 + wid,
            d=rng.standard_normal(24).astype(np.float32),
            w=rng.standard_normal(24).astype(np.float32),
            v=rng.standard_normal(24).astype(np.float32),
            scalars={"r_sq": 1.0 * wid, "dx_sq": 2.0, "y_sq": 3.0,
                     "obj": 4.0})

    a, b = mk(0), mk(1)
    m = a.merge(b)
    assert m.workers == (0, 1) and m.rows == 201
    np.testing.assert_allclose(m.d, a.d + b.d)
    assert m.scalars["r_sq"] == 1.0

    raw, _ = encode(a, compressed=False)
    np.testing.assert_array_equal(decode(raw).d, a.d)
    comp, err = encode(a, compressed=True)
    got = decode(comp)
    step = float(np.max(np.abs(a.d))) / 127
    np.testing.assert_allclose(got.d, a.d, atol=0.51 * step + 1e-7)
    assert err is not None                   # EF residual handed back
    with pytest.raises(AssertionError):
        a.merge(mk(2, it=4))                 # cross-iteration merge


@pytest.mark.parametrize("nw,fanout", [(1, 2), (2, 2), (5, 2), (9, 3)])
def test_tree_topology_structure(nw, fanout):
    topo = TreeTopology.build(range(nw), fanout=fanout)
    assert topo.parent(topo.root) is None
    seen = set()
    for wid in topo.order:
        for c in topo.children(wid):
            assert topo.parent(c) == wid
            seen.add(c)
        # every non-root reaches the root
        hops, node = 0, wid
        while topo.parent(node) is not None:
            node = topo.parent(node)
            hops += 1
            assert hops <= nw
        assert node == topo.root
    assert seen == set(topo.order) - {topo.root}
    assert topo.depth() >= 1


def test_membership_assignment_and_reassignment():
    mem = Membership()
    for wid in range(3):
        mem.add(WorkerInfo(wid=wid))
    plan = mem.initial_assignment(10)
    assert sorted(b for bs in plan.values() for b in bs) == list(range(10))
    assert mem.coverage() == set(range(10))
    orphans = mem.mark_dead(1)
    assert orphans and mem.coverage() == set(range(10)) - orphans
    plan2 = mem.reassignment_plan(sorted(orphans))
    assert mem.coverage() == set(range(10))
    assert set(plan2) <= {0, 2}
    # balanced: nobody ends >1 block above the other survivor
    loads = [len(mem.get(w).blocks) for w in (0, 2)]
    assert abs(loads[0] - loads[1]) <= 1
    mem.mark_dead(0)
    orphans = mem.mark_dead(2)
    with pytest.raises(DeadCluster):
        mem.reassignment_plan(sorted(orphans))


def test_store_verify_block_detects_tamper():
    D, aux = _problem(400, 8)
    store = ShardedMatrixStore.from_arrays(D, aux, block_rows=128)
    assert all(store.verify_block(k) for k in range(store.nblocks))
    store._blocks_D[1][0, 0] += 1.0          # corrupt one value
    assert not store.verify_block(1)
    assert store.verify_block(0)


def test_stats_payload_roundtrip():
    from repro.service.stats import SufficientStats
    D, aux = _problem(300, 10)
    st = SufficientStats.from_data(jnp.asarray(D), jnp.asarray(aux))
    rt = SufficientStats.from_payload(st.to_payload())
    np.testing.assert_array_equal(np.asarray(rt.G), np.asarray(st.G))
    assert (rt.rows, rt.fingerprint, rt.labeled_rows) == (
        st.rows, st.fingerprint, st.labeled_rows)
    merged = st.merge(rt)
    assert merged.rows == 2 * st.rows


# ---------------------------------------------------------------------------
# streaming checkpoint/resume (satellite): bitwise after a kill
# ---------------------------------------------------------------------------

def test_streaming_checkpoint_resume_bitwise(tmp_path):
    D, aux = _problem(1500, 20, seed=1)
    store = ShardedMatrixStore.from_arrays(D, aux, block_rows=400)
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    ref = solver.solve_streaming(store, max_iters=30)
    # "killed" at iteration 17 (last committed checkpoint: 15), resumed
    solver.solve_streaming(store, max_iters=17,
                           checkpoint_dir=str(tmp_path),
                           checkpoint_every=5)
    res = solver.solve_streaming(store, max_iters=30,
                                 checkpoint_dir=str(tmp_path),
                                 checkpoint_every=5, resume=True)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert int(res.iters) == int(ref.iters)
    # resuming a COMPLETED solve must return the checkpointed x, not
    # the zero init of a loop that never runs
    res2 = solver.solve_streaming(store, max_iters=30,
                                  checkpoint_dir=str(tmp_path),
                                  checkpoint_every=5, resume=True)
    np.testing.assert_array_equal(np.asarray(res2.x), np.asarray(ref.x))


def test_streaming_checkpoint_refuses_foreign_store(tmp_path):
    D, aux = _problem(600, 12, seed=2)
    store = ShardedMatrixStore.from_arrays(D, aux, block_rows=200)
    solver = UnwrappedADMM(loss=make_logistic(), tau=TAU)
    solver.solve_streaming(store, max_iters=6,
                           checkpoint_dir=str(tmp_path),
                           checkpoint_every=3)
    other = ShardedMatrixStore.from_arrays(D + 1.0, aux, block_rows=200)
    with pytest.raises(ValueError, match="different store"):
        solver.solve_streaming(other, max_iters=6,
                               checkpoint_dir=str(tmp_path), resume=True)


# ---------------------------------------------------------------------------
# real multi-process solves
# ---------------------------------------------------------------------------

def _cluster_cfg(**kw):
    from repro.cluster.coordinator import ClusterConfig
    kw.setdefault("jax_platforms", "cpu")
    kw.setdefault("heartbeat_timeout_s", 30)
    kw.setdefault("register_timeout_s", 300)
    return ClusterConfig(**kw)


def test_config_rejects_staleness_plus_checkpointing():
    from repro.cluster.coordinator import ClusterConfig
    with pytest.raises(ValueError, match="strict synchronous"):
        ClusterConfig(staleness=2, checkpoint_every=5)
    with pytest.raises(ValueError, match="quorum"):
        ClusterConfig(quorum=0.0)
    ClusterConfig(staleness=2)           # staleness alone is fine


def test_two_process_compressed_reduction_parity(ref40, tmp_path):
    """Fast end-to-end gate: 2 REAL worker processes, int8-EF tree
    reduction, must land on the single-process objective (the
    established compressed-mode bar: x jitters ~1/127 pointwise, the
    objective is quadratically flat at the optimum)."""
    from repro.cluster.coordinator import cluster_solve
    D, aux, ref_x = ref40
    res = cluster_solve(D, aux, {"name": "logistic"}, tau=TAU,
                        max_iters=40, config=_cluster_cfg(
                            n_workers=2, compress=True),
                        store_dir=str(tmp_path / "store"),
                        block_rows=300, **TINY)
    assert res.iters == 40
    ref_obj = logistic_objective(D, aux, ref_x)
    obj = logistic_objective(D, aux, np.asarray(res.x))
    assert abs(obj - ref_obj) / abs(ref_obj) < 1e-3
    # and the wire really carried int8: fewer reduction bytes per iter
    # than the uncompressed 3 f32 n-vectors would need
    per_iter = res.telemetry["reduction_rx_bytes_per_iter"]
    assert per_iter < 2 * 3 * 4 * D.shape[1]


@pytest.mark.slow
def test_four_worker_solve_matches_single_process(ref40, tmp_path):
    from repro.cluster.coordinator import cluster_solve
    D, aux, ref_x = ref40
    res = cluster_solve(D, aux, {"name": "logistic"}, tau=TAU,
                        max_iters=40, config=_cluster_cfg(n_workers=4),
                        store_dir=str(tmp_path / "store"),
                        block_rows=150, **TINY)
    rel = np.linalg.norm(res.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel
    t = res.telemetry
    assert t["workers_alive"] == 4 and not t["deaths"]


@pytest.mark.slow
def test_worker_sigkill_reassignment_same_answer(ref40, tmp_path):
    """The acceptance fault path: SIGKILL one of 4 workers mid-solve;
    its blocks are reassigned (fingerprint-verified), the new owner
    replays the x-history, and the solve converges to the same x."""
    from repro.cluster.coordinator import cluster_solve
    D, aux, ref_x = ref40
    res = cluster_solve(
        D, aux, {"name": "logistic"}, tau=TAU, max_iters=40,
        config=_cluster_cfg(n_workers=4,
                            worker_overrides={2: {"die_at_iter": 13}}),
        store_dir=str(tmp_path / "store"), block_rows=150, **TINY)
    rel = np.linalg.norm(res.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel
    t = res.telemetry
    assert t["deaths"] == [2]
    assert t["blocks_reassigned"] >= 1
    assert t["iteration_retries"] >= 1
    assert t["workers_alive"] == 3


@pytest.mark.slow
def test_cluster_lasso_stats_path(tmp_path):
    """Lasso over the cluster is the paper-§4 path: one distributed
    stats reduction (fingerprint-complete), then a local FASTA solve
    identical to the single-process cached-Gram solve."""
    from repro.cluster.coordinator import cluster_stats
    from repro.core.fasta import transpose_reduction_lasso
    from repro.service.stats import SufficientStats
    rng = np.random.default_rng(3)
    m, n = 1600, 24
    D = rng.standard_normal((m, n)).astype(np.float32)
    b = (D @ rng.standard_normal(n).astype(np.float32)
         + 0.1 * rng.standard_normal(m).astype(np.float32))
    store_dir = str(tmp_path / "store")
    st, _ = cluster_stats(D, b, store_dir=store_dir,
                          config=_cluster_cfg(n_workers=4),
                          block_rows=200)
    store = ShardedMatrixStore.open(store_dir)
    ref_st = SufficientStats.from_store(store)
    assert st.fingerprint == store.fingerprint == ref_st.fingerprint
    assert st.rows == m and st.fully_labeled
    fr = transpose_reduction_lasso(st.G, st.c, mu=5.0, iters=400)
    fr_ref = transpose_reduction_lasso(ref_st.G, ref_st.c, mu=5.0,
                                       iters=400)
    rel = (np.linalg.norm(np.asarray(fr.x) - np.asarray(fr_ref.x))
           / max(float(np.linalg.norm(np.asarray(fr_ref.x))), 1e-30))
    assert rel <= 1e-5, rel


@pytest.mark.slow
def test_cluster_checkpoint_resume(ref40, tmp_path):
    from repro.cluster.coordinator import cluster_solve
    D, aux, ref_x = ref40
    store_dir = str(tmp_path / "store")
    ckpt = str(tmp_path / "ckpt")
    common = dict(tau=TAU, store_dir=store_dir, block_rows=300, **TINY)
    # "killed" after 12 iterations (checkpoints every 5 -> step 10)
    cluster_solve(D, aux, {"name": "logistic"}, max_iters=12,
                  config=_cluster_cfg(n_workers=2, checkpoint_dir=ckpt,
                                      checkpoint_every=5), **common)
    res = cluster_solve(D, aux, {"name": "logistic"}, max_iters=40,
                        config=_cluster_cfg(n_workers=2,
                                            checkpoint_dir=ckpt,
                                            checkpoint_every=5,
                                            resume=True), **common)
    rel = np.linalg.norm(res.x - ref_x) / np.linalg.norm(ref_x)
    assert rel <= 1e-5, rel
    # resuming the COMPLETED solve (latest checkpoint at 40): zero
    # iterations run, the checkpointed x comes back verbatim
    res2 = cluster_solve(D, aux, {"name": "logistic"}, max_iters=40,
                         config=_cluster_cfg(n_workers=2,
                                             checkpoint_dir=ckpt,
                                             checkpoint_every=5,
                                             resume=True), **common)
    np.testing.assert_array_equal(res2.x, res.x)


@pytest.mark.slow
def test_bounded_staleness_straggler(ref40, tmp_path):
    """Quorum mode with a deliberate straggler: the coordinator
    proceeds without it (within the staleness bound) and still reaches
    the single-process objective."""
    from repro.cluster.coordinator import cluster_solve
    D, aux, ref_x = ref40
    res = cluster_solve(
        D, aux, {"name": "logistic"}, tau=TAU, max_iters=60,
        config=_cluster_cfg(n_workers=2, staleness=3, quorum=0.5,
                            worker_overrides={1: {"slow_ms": 40}}),
        store_dir=str(tmp_path / "store"), block_rows=300, **TINY)
    ref_obj = logistic_objective(D, aux, ref_x)
    obj = logistic_objective(D, aux, np.asarray(res.x))
    assert abs(obj - ref_obj) / abs(ref_obj) < 1e-3
