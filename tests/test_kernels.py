"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import chunked_attention_xla, flash_attention
from repro.kernels.flash_attn.ref import mha_ref
from repro.kernels.gram.ops import gram, gram_with_rhs
from repro.kernels.gram.ref import gram_ref, gram_with_rhs_ref
from repro.kernels.prox.ops import prox_update
from repro.kernels.prox.ref import prox_update_ref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Gram kernel (the transpose-reduction hot-spot)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(256, 128), (1000, 130), (512, 64),
                                 (2048, 512), (77, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(m, n, dtype):
    D = jax.random.normal(jax.random.PRNGKey(0), (m, n), dtype)
    G1 = gram(D, block_m=256, block_n=128, interpret=True)
    G2 = gram_ref(D)
    tol = 5e-6 * m if dtype == jnp.bfloat16 else 2e-6 * m
    np.testing.assert_allclose(np.asarray(G1), np.asarray(G2),
                               atol=tol * float(jnp.max(jnp.abs(G2))) / m,
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_gram_symmetric_skip_equals_full():
    D = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    G1 = gram(D, symmetric_skip=True, interpret=True)
    G2 = gram(D, symmetric_skip=False, interpret=True)
    np.testing.assert_allclose(np.asarray(G1), np.asarray(G2), rtol=1e-6)


def test_gram_output_is_psd_and_symmetric():
    D = jax.random.normal(jax.random.PRNGKey(2), (300, 60))
    G = np.asarray(gram(D, interpret=True))
    np.testing.assert_allclose(G, G.T, rtol=1e-6)
    w = np.linalg.eigvalsh(G)
    assert w.min() > -1e-3


@pytest.mark.parametrize("m,n", [(512, 100), (999, 65)])
def test_gram_with_rhs(m, n):
    key = jax.random.PRNGKey(3)
    D = jax.random.normal(key, (m, n))
    b = jax.random.normal(jax.random.PRNGKey(4), (m,))
    G1, c1 = gram_with_rhs(D, b, interpret=True)
    G2, c2 = gram_with_rhs_ref(D, b)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=3e-5,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(G1), np.asarray(G2), rtol=3e-5,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# Fused prox/lambda kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1000, 262144, 300001])
@pytest.mark.parametrize("kind,delta", [("logistic", 10.0), ("hinge", 0.7),
                                        ("l1", 0.3), ("least_squares", 2.0)])
def test_prox_kernel_matches_ref(m, kind, delta):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    Dx = jax.random.normal(k1, (m,)) * 3
    lam = jax.random.normal(k2, (m,))
    aux = jnp.sign(jax.random.normal(k3, (m,))) if kind != "l1" else None
    y1, l1 = prox_update(Dx, lam, aux, kind=kind, delta=delta,
                         interpret=True, block_rows=64)
    aux_ref = aux if aux is not None else jnp.zeros_like(Dx)
    y2, l2 = prox_update_ref(kind, Dx, lam, aux_ref, delta)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-6)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-6)


def test_prox_kernel_fusion_identity():
    """lam' + y == Dx + lam (conservation of the ADMM update)."""
    m = 4096
    Dx = jax.random.normal(jax.random.PRNGKey(5), (m,))
    lam = jax.random.normal(jax.random.PRNGKey(6), (m,))
    labels = jnp.sign(jax.random.normal(jax.random.PRNGKey(7), (m,)))
    y, lam_new = prox_update(Dx, lam, labels, kind="logistic", delta=1.0,
                             interpret=True, block_rows=64)
    np.testing.assert_allclose(np.asarray(y + lam_new),
                               np.asarray(Dx + lam), atol=2e-6)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

CASES = [
    (2, 4, 2, 256, 256, 64, jnp.float32, True),
    (1, 8, 1, 512, 512, 128, jnp.float32, True),
    (2, 4, 4, 256, 256, 64, jnp.bfloat16, True),
    (1, 2, 2, 256, 512, 64, jnp.float32, False),
]


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,dt,causal", CASES)
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Skv, D, dt, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dt)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dt)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dt)
    ref = mha_ref(q, k, v, causal=causal).astype(jnp.float32)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    for impl in ("pallas_interpret", "xla"):
        o = flash_attention(q, k, v, causal=causal, impl=impl,
                            block_q=128, block_k=128).astype(jnp.float32)
        assert float(jnp.max(jnp.abs(o - ref))) < tol, impl


def test_windowed_attention_matches_dense_mask():
    """Local (banded) attention vs explicit dense masking."""
    B, H, S, D, W = 1, 2, 96, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    o = chunked_attention_xla(q, k, v, causal=True, window=W, chunk_q=32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(1.0 * D)
    qi, ki = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (qi >= ki) & (ki > qi - W)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5)


def test_chunked_attention_unroll_matches_scan():
    B, H, S, D = 1, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    o1 = chunked_attention_xla(q, k, v, causal=True, chunk_q=32, unroll=False)
    o2 = chunked_attention_xla(q, k, v, causal=True, chunk_q=32, unroll=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
