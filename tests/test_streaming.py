"""Out-of-core streaming path (ISSUE 3 tentpole): the ShardedMatrixStore
contract (blocks, padding, fingerprints, mmap round-trip), stats ingestion
reusing store fingerprints, and solve_streaming parity with the in-memory
engine across backends on a dataset whose D exceeds the configured
per-block device budget."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.prox import make_hinge, make_logistic
from repro.core.unwrapped import UnwrappedADMM
from repro.data.store import ShardedMatrixStore, fingerprint_array
from repro.engine import IterationEngine, StreamingEngine, autotune
from repro.service.stats import SufficientStats

from exec_fixtures import classification_fixture

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def classif():
    return classification_fixture(N=4, m_per_node=300, n=24)


def _flat(classif):
    D = np.asarray(classif.D.reshape(-1, 24))
    a = np.asarray(classif.labels.reshape(-1))
    return D, a


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

def test_store_blocks_and_padding(classif):
    D, a = _flat(classif)                      # m = 1200
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=512)
    assert (store.m, store.n, store.nblocks) == (1200, 24, 3)
    # tail block is logically short, padded on request
    Dt, at = store.block(2, padded=False)
    assert Dt.shape == (176, 24) and at.shape == (176,)
    Dp, ap = store.block(2, padded=True)
    assert Dp.shape == (512, 24) and ap.shape == (512,)
    assert np.all(Dp[176:] == 0) and np.all(ap[176:] == 0)
    np.testing.assert_array_equal(Dp[:176], Dt)
    # logical slices tile [0, m)
    sls = [store.block_slice(k) for k in range(store.nblocks)]
    assert sls[0] == slice(0, 512) and sls[2] == slice(1024, 1200)
    # reassembly is exact
    np.testing.assert_array_equal(
        np.concatenate([store.block(k)[0] for k in range(3)]), D)


def test_store_fingerprints_match_service_hashing(classif):
    """Store write-time fingerprints == hashing the blocks the service
    way, and the folded store fingerprint == ingest-order-independent."""
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=500)
    for k in range(store.nblocks):
        Db, ab = store.block(k, padded=False)
        assert store.fingerprints[k] == fingerprint_array(Db, ab)
    s = SufficientStats.from_store(store)
    assert s.fingerprint == store.fingerprint
    assert s.rows == store.m and s.labeled_rows == store.m
    # same stats as a direct streaming ingest of the raw arrays
    ref = SufficientStats.from_data(jnp.asarray(D), jnp.asarray(a),
                                    backend="chunked")
    np.testing.assert_allclose(np.asarray(s.G), np.asarray(ref.G),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s.c), np.asarray(ref.c),
                               rtol=1e-5, atol=1e-3)


def test_store_mmap_roundtrip(tmp_path, classif):
    D, a = _flat(classif)
    ram = ShardedMatrixStore.from_arrays(D, a, block_rows=256)
    disk = ShardedMatrixStore.open(ram.save(str(tmp_path / "store")))
    assert disk.path is not None
    assert (disk.m, disk.n, disk.block_rows) == (ram.m, ram.n, 256)
    assert disk.fingerprints == ram.fingerprints
    for k in range(ram.nblocks):
        np.testing.assert_array_equal(disk.block(k)[0], ram.block(k)[0])
        np.testing.assert_array_equal(disk.block(k)[1], ram.block(k)[1])


def test_streaming_block_rows_budget():
    br = autotune.streaming_block_rows(1 << 18, 512, jnp.float32,
                                       budget_bytes=8 << 20)
    # worst-case in-flight set (compute + 2 queued + 1 staging at the
    # default prefetch depth) of (br, 512) f32 blocks fits the budget
    assert 4 * br * 512 * 4 <= 8 << 20
    assert br % 8 == 0 and br >= 128
    # never taller than the dataset
    assert autotune.streaming_block_rows(100, 8, jnp.float32) <= 104


# ---------------------------------------------------------------------------
# solve_streaming parity (all backends), D larger than the device budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "chunked",
                                     "pallas_interpret"])
def test_solve_streaming_matches_in_memory(classif, backend):
    D, a = _flat(classif)
    # per-block device budget far below D's 115 KB: 8 blocks in flight
    br = autotune.streaming_block_rows(D.shape[0], D.shape[1], np.float32,
                                       budget_bytes=16 << 10)
    assert br * D.shape[1] * 4 < D.nbytes          # genuinely out-of-core
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=br)
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1, backend=backend)
    mem = solver.solve(classif.D, classif.labels, max_iters=250)
    stream = solver.solve_streaming(store, max_iters=250, record=True)
    nx = float(jnp.linalg.norm(stream.x - mem.x) / jnp.linalg.norm(mem.x))
    assert nx < 2e-4, (backend, nx)
    # host-resident iterates come back (1, m) and match the in-memory ones
    assert stream.y.shape == (1, D.shape[0])
    np.testing.assert_allclose(np.asarray(stream.y).ravel(),
                               np.asarray(mem.y).ravel(), atol=2e-3)


def test_solve_streaming_overlap_parity(classif):
    """Double-buffered and naive-synchronous sweeps are bit-equivalent in
    results (same blocks, same jitted body, different scheduling)."""
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=301)
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    db = solver.solve_streaming(store, max_iters=40, record=True)
    sync = solver.solve_streaming(store, max_iters=40, record=True,
                                  overlap=False)
    assert int(db.iters) == int(sync.iters)
    np.testing.assert_array_equal(np.asarray(db.x), np.asarray(sync.x))
    np.testing.assert_array_equal(np.asarray(db.history.objective),
                                  np.asarray(sync.history.objective))


def test_solve_streaming_objective_matches_reference_history(classif):
    """Streamed telemetry == the in-memory recorded history, including the
    pad-objective correction (m % block_rows != 0)."""
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=352)  # pad 208
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    iters = 30
    ref = solver.run(classif.D, classif.labels, iters=iters)
    stream = solver.solve_streaming(store, max_iters=iters, record=True)
    k = int(stream.iters)
    np.testing.assert_allclose(
        np.asarray(stream.history.objective)[:k],
        np.asarray(ref.history.objective)[:k], rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(stream.history.primal_res)[:k],
        np.asarray(ref.history.primal_res)[:k], atol=1e-3)


def test_solve_streaming_warm_start(classif):
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=256)
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    cold = solver.solve_streaming(store, max_iters=300)
    warm = solver.solve_streaming(store, max_iters=300, x0=cold.x)
    assert int(warm.iters) < int(cold.iters)
    nx = float(jnp.linalg.norm(warm.x - cold.x) / jnp.linalg.norm(cold.x))
    assert nx < 5e-3, nx


def test_solve_streaming_hinge_ragged_tail(classif):
    """hinge parity holds with a ragged tail block (pad-row value is 1,
    not 0 — exercises the pad-objective correction for a second loss)."""
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=389)
    solver = UnwrappedADMM(loss=make_hinge(1.0), tau=0.5, rho=1.0)
    mem = solver.solve(classif.D, classif.labels, max_iters=200)
    stream = solver.solve_streaming(store, max_iters=200)
    nx = float(jnp.linalg.norm(stream.x - mem.x) / jnp.linalg.norm(mem.x))
    assert nx < 1e-3, nx


def test_solve_streaming_unlabeled_store(classif):
    """A store built WITHOUT aux streams through every has_aux=False
    branch (staging, step, pad objective) — l1 loss needs no labels."""
    from repro.core.prox import make_l1
    D, _ = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, block_rows=389)  # no aux
    assert not store.has_aux
    assert store.block(0)[1] is None
    solver = UnwrappedADMM(loss=make_l1(0.5), tau=1.0)
    mem = solver.solve(D[None], None, max_iters=120)
    stream = solver.solve_streaming(store, max_iters=120, record=True)
    # l1-on-Dx drives x to ~0; compare absolutely, scaled by the data
    tol = 1e-4 * max(float(jnp.linalg.norm(mem.x)), 1.0)
    assert float(jnp.linalg.norm(stream.x - mem.x)) < tol
    assert np.all(np.isfinite(np.asarray(stream.history.objective)))
    # unlabeled ingest works too and folds the same fingerprints
    s = SufficientStats.from_store(store)
    assert s.rows == store.m and s.labeled_rows == 0
    assert s.fingerprint == store.fingerprint


def test_streaming_device_dtype_residency(classif):
    """An f64 host store with f32 device residency: blocks are cast at
    staging time, results match the f32 solve."""
    D, a = _flat(classif)
    store64 = ShardedMatrixStore.from_arrays(D.astype(np.float64),
                                             a.astype(np.float64),
                                             block_rows=256)
    solver = UnwrappedADMM(loss=make_logistic(), tau=0.1)
    eng = StreamingEngine(engine=solver.engine, device_dtype="float32")
    assert eng.residency_dtype(store64) == jnp.float32
    res64 = solver.solve_streaming(store64, max_iters=150,
                                   device_dtype="float32")
    store32 = ShardedMatrixStore.from_arrays(D, a, block_rows=256)
    res32 = solver.solve_streaming(store32, max_iters=150)
    assert res64.x.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(res64.x), np.asarray(res32.x),
                               atol=1e-5)


def test_staged_tuple_payloads_and_abandonment(classif):
    """staged() must (a) pass through 2-tuple payloads whose first element
    is an array (sentinel detection is by identity, not ==, which numpy
    arrays refuse), and (b) unblock its producer thread when the consumer
    abandons the generator mid-stream."""
    import threading
    import time
    from repro.engine.streaming import staged
    D, a = _flat(classif)
    store = ShardedMatrixStore.from_arrays(D, a, block_rows=128)
    items = list(staged(range(store.nblocks),
                        lambda k: store.block(k, padded=True), 2))
    assert len(items) == store.nblocks
    np.testing.assert_array_equal(items[0][0], store.block(0, True)[0])
    before = threading.active_count()
    gen = staged(range(store.nblocks),
                 lambda k: store.block(k, padded=True), 2)
    next(gen)
    gen.close()                       # consumer walks away mid-stream
    time.sleep(0.3)
    assert threading.active_count() <= before


def test_sweep_padded_rows_do_not_leak(classif):
    """Zero pad rows of the tail block contribute nothing to d and the
    stopping-rule scalars (the gram.blocked_rows zero-row argument,
    streaming edition)."""
    D, a = _flat(classif)
    eng = IterationEngine(loss=make_logistic(), tau=0.1,
                          backend="chunked")
    seng = StreamingEngine(engine=eng)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(24),
                    jnp.float32) * 0.1
    results = {}
    for br in (500, 1200):    # ragged tail (pad 300) vs single block
        store = ShardedMatrixStore.from_arrays(D, a, block_rows=br)
        y = np.zeros((store.m,), np.float32)
        lam = np.zeros((store.m,), np.float32)
        sw = seng.sweep(store, x, y, lam)
        results[br] = (np.asarray(sw.d), float(sw.r_sq), float(sw.dx_sq),
                       y.copy(), lam.copy())
    d_r, r_r, dx_r, y_r, lam_r = results[1200]
    d_p, r_p, dx_p, y_p, lam_p = results[500]
    np.testing.assert_allclose(d_p, d_r, rtol=1e-5, atol=1e-4)
    assert abs(r_p - r_r) < 1e-3 * max(abs(r_r), 1.0)
    assert abs(dx_p - dx_r) < 1e-3 * max(abs(dx_r), 1.0)
    np.testing.assert_allclose(y_p, y_r, atol=1e-5)
    np.testing.assert_allclose(lam_p, lam_r, atol=1e-5)
