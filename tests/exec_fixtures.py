"""Shared problem/data fixtures for executor parity.

One place defines the parity problems (loss config + ridge + synthetic
data) and the comparison metric; the matrix suite
(test_executor_parity.py) AND the per-topology test files
(test_streaming / test_cluster) import from here instead of keeping
private copies that drift.

Why every parity problem carries a small ridge: the backend-parity
contract is "same x to 1e-5 on all four topologies", and that only has
a float32 meaning when the optimum is unique and the iteration
contracts — a separable logistic (x diverges) or an unregularized
piecewise-linear loss lets psum-reordering noise random-walk the
trajectories apart. Regularizer problems (group lasso) keep rho=0: the
composite prox-gradient x-update has no ridge term, matching the
legacy composite paths.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.exec import EXECUTORS, make_problem, synth_data

# problem -> (make_problem kwargs, ridge rho override or None)
PARITY_CONFIGS = {
    "logistic": (dict(tau=1.0), 0.1),
    "svm": ({}, None),                      # already carries rho=1.0
    "quantile": (dict(q=0.35), 0.2),
    "group_lasso": ({}, None),              # reg path: no ridge
    "multinomial": (dict(classes=3, tau=1.0), 0.3),
}
PARITY_PROBLEMS = tuple(PARITY_CONFIGS)
# the problems this PR added — must pass parity on ALL four executors,
# including the warm-start and checkpoint-resume legs
NEW_PROBLEMS = ("quantile", "group_lasso", "multinomial")

PARITY_TOL = 1e-5
SOLVE_KW = dict(max_iters=2000, eps_rel=1e-5, eps_abs=1e-7)
DATA_KW = dict(m=64, n=8, seed=2)
N_WORKERS = 2

assert set(("local", "streaming", "shard_map", "cluster")) == set(EXECUTORS)


def parity_problem(name: str):
    """(ExecProblem, D, aux) for one parity matrix row."""
    kw, rho = PARITY_CONFIGS[name]
    prob = make_problem(name, **kw)
    if rho is not None:
        prob = dataclasses.replace(prob, rho=rho)
    D, aux = synth_data(prob, **DATA_KW)
    return prob, D, aux


def rel_gap(x_ref, x) -> float:
    """sup-norm gap scaled by the reference magnitude (floor 1.0)."""
    x_ref = np.asarray(x_ref)
    x = np.asarray(x).reshape(x_ref.shape)
    return float(np.max(np.abs(x - x_ref))
                 / max(1.0, float(np.max(np.abs(x_ref)))))


# ---------------------------------------------------------------------------
# per-topology file fixtures (imported by test_streaming / test_cluster)
# ---------------------------------------------------------------------------

def classification_fixture(N=4, m_per_node=300, n=24, seed=0):
    """The node-stacked classification problem the streaming tests use."""
    import jax

    from repro.data.synthetic import classification_problem
    return classification_problem(jax.random.PRNGKey(seed), N=N,
                                  m_per_node=m_per_node, n=n)


def cluster_problem(m=1200, n=20, seed=0):
    """The flat logistic problem the cluster tests use."""
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((m, n)).astype(np.float32)
    aux = np.sign(rng.standard_normal((m,))).astype(np.float32)
    return D, aux
