"""Per-assigned-architecture smoke tests: REDUCED same-family config, one
forward + one train step on CPU, asserting output shapes and no NaNs
(deliverable f). The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs_lib
from repro.models.model import forward, init_params
from repro.optim.optimizers import make_optimizer
from repro.runtime.steps import make_train_step

jax.config.update("jax_platform_name", "cpu")

ARCHES = [a.replace("_", "-").replace("1p6b", "1.6b") for a in
          configs_lib.ARCH_IDS]


def _batch_for(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision":
        batch = {
            "embeds": 0.02 * jax.random.normal(key, (B, S, cfg.d_model)),
            "labels": tokens,
            "positions": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (3, B, S)),
        }
    elif cfg.family == "encdec":
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            key, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHES)
def test_smoke_forward_and_train_step(arch):
    cfg = configs_lib.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch_for(cfg, key)

    h, aux = forward(params, cfg, **{
        k: v for k, v in batch.items() if k != "labels"})
    assert h.shape == (2, 16, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all()), arch

    opt = make_optimizer(cfg.optimizer, lr=1e-3, warmup_steps=1,
                         total_steps=10)
    step_fn = make_train_step(cfg, opt)
    params2, opt_state, metrics = step_fn(
        params, opt.init(params), batch, jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0, arch
    # params actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCHES)
def test_smoke_loss_decreases(arch):
    """A few steps on a fixed batch must reduce the loss (end-to-end grad
    correctness through every family's sequence mixer)."""
    cfg = configs_lib.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch_for(cfg, key, B=2, S=16)
    opt = make_optimizer("adamw", lr=3e-3, warmup_steps=0, total_steps=100)
    step_fn = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    losses = []
    for i in range(8):
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


def test_full_configs_match_nameplate_param_counts():
    expect = {
        "arctic-480b": (430e9, 530e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "rwkv6-1.6b": (1.3e9, 1.9e9),
        "qwen3-14b": (13e9, 16e9),
        "command-r-35b": (30e9, 38e9),
        "phi3-medium-14b": (13e9, 16e9),
        "qwen3-8b": (7e9, 9e9),
        "seamless-m4t-large-v2": (1.5e9, 2.6e9),
        "qwen2-vl-72b": (68e9, 78e9),
        "recurrentgemma-9b": (8.5e9, 11e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs_lib.get(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    arctic = configs_lib.get("arctic-480b")
    assert arctic.active_param_count() < 0.05 * arctic.param_count()
    olmoe = configs_lib.get("olmoe-1b-7b")
    assert 0.1 < olmoe.active_param_count() / olmoe.param_count() < 0.3
