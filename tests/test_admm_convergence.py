"""Paper claims C1-C3: unwrapped ADMM converges to the true optimum for
logistic / SVM / lasso, and the Theorem 1/2 rates hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gram as gram_lib
from repro.core.fasta import lasso_mu_max, transpose_reduction_lasso
from repro.core.oracles import (
    lasso_kkt_gap,
    lasso_objective,
    logistic_objective,
    newton_logistic,
    svm_dual_cd,
    svm_objective,
)
from repro.core.prox import (
    StackedProx,
    make_hinge,
    make_l1,
    make_least_squares,
    make_logistic,
)
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import classification_problem, lasso_problem

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def classif():
    return classification_problem(jax.random.PRNGKey(0), N=4,
                                  m_per_node=250, n=20)


def test_logistic_matches_newton_oracle(classif):
    """C1: same optimum as an independent full-data Newton solver."""
    D2 = np.asarray(classif.D.reshape(-1, 20))
    l2 = np.asarray(classif.labels.reshape(-1))
    x_star = newton_logistic(D2, l2)
    obj_star = logistic_objective(D2, l2, x_star)
    res = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(
        classif.D, classif.labels, iters=200)
    obj = logistic_objective(D2, l2, np.asarray(res.x))
    assert obj - obj_star < 1e-3 * abs(obj_star)
    assert np.linalg.norm(np.asarray(res.x) - x_star) \
        / np.linalg.norm(x_star) < 1e-3
    # Boyd stopping triggered well before the iteration cap
    assert int(res.iters) < 200


def test_svm_matches_dual_cd_oracle(classif):
    """C1: SVM objective matches LIBSVM-style dual coordinate descent."""
    D2 = np.asarray(classif.D.reshape(-1, 20))
    l2 = np.asarray(classif.labels.reshape(-1))
    w_star = svm_dual_cd(D2, l2, C=1.0, passes=2000)
    obj_star = svm_objective(D2, l2, w_star, 1.0)
    res = UnwrappedADMM(loss=make_hinge(1.0), tau=0.5, rho=1.0).run(
        classif.D, classif.labels, iters=500)
    obj = svm_objective(D2, l2, np.asarray(res.x), 1.0)
    assert obj - obj_star < 2e-2 * abs(obj_star) + 0.05


def test_lasso_direct_transpose_reduction_kkt():
    """C1 / §4: Gram + FASTA satisfies the lasso KKT certificate."""
    prob = lasso_problem(jax.random.PRNGKey(1), N=4, m_per_node=500, n=60)
    Dflat = prob.D.reshape(-1, 60)
    bflat = prob.b.reshape(-1)
    G, c = gram_lib.gram_and_rhs_chunked(Dflat, bflat)
    res = transpose_reduction_lasso(G, c, float(prob.mu), iters=3000)
    viol, sup_err = lasso_kkt_gap(np.asarray(Dflat), np.asarray(bflat),
                                  np.asarray(res.x), float(prob.mu))
    assert viol < 1e-3 * float(prob.mu)
    assert sup_err < 1e-2 * float(prob.mu)
    # recovers the true support (10 active features)
    sup = np.abs(np.asarray(res.x)) > 1e-6
    true_sup = np.abs(np.asarray(prob.x_true)) > 0
    assert (sup == true_sup).mean() > 0.95


def test_lasso_stacked_unwrapped_matches_fasta():
    """§7 [I; D] stacking and §4 direct reduction agree."""
    prob = lasso_problem(jax.random.PRNGKey(2), N=2, m_per_node=400, n=40)
    Dflat = prob.D.reshape(-1, 40)
    bflat = prob.b.reshape(-1)
    mu = float(prob.mu)
    G, c = gram_lib.gram_and_rhs_chunked(Dflat, bflat)
    xf = np.asarray(transpose_reduction_lasso(G, c, mu, iters=3000).x)
    m = Dflat.shape[0]
    D_hat = jnp.concatenate([jnp.eye(40), Dflat], axis=0)[None]
    sp = StackedProx(blocks=(make_l1(mu), make_least_squares()),
                     sizes=(40, m))
    aux = jnp.concatenate([jnp.zeros(40), bflat])[None]
    res = UnwrappedADMM(loss=sp.as_loss(), tau=0.01 * m).run(
        D_hat, aux, iters=800)
    obj_f = lasso_objective(np.asarray(Dflat), np.asarray(bflat), xf, mu)
    obj_u = lasso_objective(np.asarray(Dflat), np.asarray(bflat),
                            np.asarray(res.x), mu)
    assert obj_u - obj_f < 5e-3 * abs(obj_f)


def test_mu_max_rule():
    """mu >= ||D^T b||_inf forces the zero solution (paper's 10% rule base)."""
    prob = lasso_problem(jax.random.PRNGKey(3), N=2, m_per_node=200, n=30)
    Dflat = prob.D.reshape(-1, 30)
    bflat = prob.b.reshape(-1)
    mu_max = float(lasso_mu_max(Dflat, bflat))
    G, c = gram_lib.gram_and_rhs_chunked(Dflat, bflat)
    res = transpose_reduction_lasso(G, c, mu_max * 1.01, iters=500)
    assert float(jnp.max(jnp.abs(res.x))) < 1e-5


def test_theorem1_residual_rate(classif):
    """Cor. 1: ||y^{k+1}-y^k||^2 + ||Dx-y||^2 <= C/(k+1)."""
    res = UnwrappedADMM(loss=make_logistic(), tau=0.1, eps_rel=0.0,
                        eps_abs=0.0).run(classif.D, classif.labels, iters=300)
    h = res.history
    combined = np.asarray(h.primal_res) ** 2 + np.asarray(h.dual_res) ** 2
    k = np.arange(1, len(combined) + 1)
    # k * r_k should be bounded by a constant: compare the tail to the head.
    prod = combined * k
    assert np.median(prod[150:]) <= np.max(prod[:20]) + 1e-9


def test_theorem2_gradient_rate(classif):
    """Thm 2: ||D^T grad f(Dx^k)||^2 <= C/k for smooth f (logistic)."""
    res = UnwrappedADMM(loss=make_logistic(), tau=0.1, eps_rel=0.0,
                        eps_abs=0.0).run(classif.D, classif.labels, iters=300)
    gsq = np.asarray(res.history.grad_sq)
    k = np.arange(1, len(gsq) + 1)
    prod = gsq * k
    assert np.median(prod[150:]) <= np.max(prod[:20]) + 1e-9
    # and the gradient actually goes to ~0
    assert gsq[-1] < 1e-4 * gsq[0]


def test_objective_monotone_tail(classif):
    """The objective settles to the optimum (not oscillating) at the tail."""
    res = UnwrappedADMM(loss=make_logistic(), tau=0.1).run(
        classif.D, classif.labels, iters=200)
    objs = np.asarray(res.history.objective)
    tail_spread = objs[-20:].max() - objs[-20:].min()
    assert tail_spread < 1e-3 * abs(objs[-1])
