"""Property-based tests (hypothesis) for the prox-operator library —
the mathematical invariants every proximal map must satisfy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.prox import (
    hinge_prox,
    logistic_prox_newton,
    make_hinge,
    make_l1,
    make_least_squares,
    make_logistic,
    project_linf,
    soft_threshold,
)

jax.config.update("jax_platform_name", "cpu")

floats = st.floats(-30.0, 30.0, allow_nan=False, allow_infinity=False)
pos = st.floats(0.05, 20.0, allow_nan=False, allow_infinity=False)
labels_st = st.sampled_from([-1.0, 1.0])


# ---------------------------------------------------------------------------
# prox definition: y* = argmin f(y) + (y-z)^2/(2 delta)
# ---------------------------------------------------------------------------

def _check_prox_optimality(fname, z, delta, label):
    """y* must beat every nearby candidate on the prox objective."""
    z = jnp.asarray(z)
    aux = jnp.asarray(label)
    if fname == "logistic":
        f = lambda y: jnp.log1p(jnp.exp(-aux * y))
        y = logistic_prox_newton(z, delta, aux)
    elif fname == "hinge":
        f = lambda y: jnp.maximum(1.0 - aux * y, 0.0)
        y = hinge_prox(z, delta, aux)
    else:
        raise ValueError(fname)
    obj = lambda y_: f(y_) + (y_ - z) ** 2 / (2 * delta)
    o_star = obj(y)
    for eps in [1e-3, 1e-2, 0.1, 1.0]:
        assert o_star <= obj(y + eps) + 5e-5, (fname, z, delta, label, eps)
        assert o_star <= obj(y - eps) + 5e-5


@settings(max_examples=60, deadline=None)
@given(z=floats, delta=pos, label=labels_st)
def test_logistic_prox_is_argmin(z, delta, label):
    _check_prox_optimality("logistic", z, delta, label)


@settings(max_examples=60, deadline=None)
@given(z=floats, delta=pos, label=labels_st)
def test_hinge_prox_is_argmin(z, delta, label):
    _check_prox_optimality("hinge", z, delta, label)


@settings(max_examples=50, deadline=None)
@given(z1=floats, z2=floats, delta=pos, label=labels_st)
def test_firm_nonexpansiveness(z1, z2, delta, label):
    """||prox(a)-prox(b)||^2 <= <prox(a)-prox(b), a-b> for any convex f."""
    for prox in (
        lambda z: logistic_prox_newton(jnp.asarray(z), delta,
                                       jnp.asarray(label)),
        lambda z: hinge_prox(jnp.asarray(z), delta, jnp.asarray(label)),
        lambda z: soft_threshold(jnp.asarray(z), delta),
    ):
        pa, pb = float(prox(z1)), float(prox(z2))
        lhs = (pa - pb) ** 2
        rhs = (pa - pb) * (z1 - z2)
        assert lhs <= rhs + 1e-4


@settings(max_examples=50, deadline=None)
@given(z=floats, mu=pos, delta=pos)
def test_moreau_decomposition_l1(z, mu, delta):
    """z = prox_{d f}(z) + d * prox_{f*/d}(z/d) for f = mu|.|:
    soft_threshold(z, d*mu) + clip(z, -d*mu, d*mu) == z."""
    st_ = float(soft_threshold(jnp.asarray(z), delta * mu))
    proj = float(project_linf(jnp.asarray(z), delta * mu))
    assert abs(st_ + proj - z) < 1e-4


@settings(max_examples=40, deadline=None)
@given(z=floats, label=labels_st)
def test_logistic_prox_stationarity(z, label):
    """Newton solution satisfies phi'(y) = 0 to tight tolerance."""
    delta = 4.0
    y = float(logistic_prox_newton(jnp.asarray(z), delta, jnp.asarray(label)))
    s = 1.0 / (1.0 + np.exp(label * y))
    grad = -label * s + (y - z) / delta
    assert abs(grad) < 1e-4


def test_prox_losses_vectorized_shapes():
    z = jnp.linspace(-5, 5, 64).reshape(4, 16)
    labels = jnp.sign(jnp.cos(z) + 0.1)
    for loss in (make_logistic(), make_hinge(2.0)):
        y = loss.prox(z, 0.5, labels)
        assert y.shape == z.shape
        assert jnp.isfinite(y).all()
    y = make_l1(0.3).prox(z, 0.5, None)
    assert y.shape == z.shape
    ls = make_least_squares()
    y = ls.prox(z, 2.0, labels)
    assert jnp.allclose(y, (z + 2.0 * labels) / 3.0, atol=1e-6)


def test_hinge_prox_matches_paper_formula():
    """Paper §6.2: prox_h(z,d)_k = z_k + l max(min(1 - l z, d), 0)."""
    z = jnp.linspace(-3, 3, 41)
    l = jnp.where(jnp.arange(41) % 2 == 0, 1.0, -1.0)
    d = 0.7
    got = hinge_prox(z, d, l)
    want = z + l * jnp.maximum(jnp.minimum(1 - l * z, d), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
