"""Observability layer (DESIGN.md §12): registry thread-safety,
histogram percentile accuracy, span nesting/attrs in the exported
Chrome trace, telemetry-JSONL parity with ADMMHistory on a lasso
solve, and cluster snapshot merging (registry + legacy ByteCounter)."""
import json
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.transport import ByteCounter
from repro.core.prox import StackedProx, make_l1, make_least_squares
from repro.core.unwrapped import UnwrappedADMM
from repro.data.synthetic import lasso_problem
from repro.obs import (
    METRICS_FILE,
    TELEMETRY_FILE,
    TRACE_FILE,
    Histogram,
    MetricsRegistry,
    Observability,
    Tracer,
    load_trace,
    read_jsonl,
    span_hotspots,
    summarize_histogram,
)

jax.config.update("jax_platform_name", "cpu")


# -- registry ---------------------------------------------------------------

def test_registry_thread_safety():
    """Concurrent incs/observes from many threads lose no updates."""
    reg = MetricsRegistry()
    threads, per_thread = 8, 2000

    def worker(tid):
        for i in range(per_thread):
            reg.inc("ops", 1, kind="a" if i % 2 else "b")
            reg.observe("lat_s", 1e-3 * (1 + (i % 7)))

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = sum(reg.labeled("ops", "kind").values())
    assert total == threads * per_thread
    snap = reg.histogram_snapshot("lat_s")
    assert snap["count"] == threads * per_thread


def test_histogram_percentiles_vs_numpy():
    """Log-bucket quantile estimates stay within one bucket width
    (factor 10^(1/32) ~ 7.5%) of numpy's exact percentiles."""
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-6.0, sigma=1.0, size=20000)  # ~ms latencies
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        est = h.quantile(q)
        assert abs(est - exact) / exact < 0.08, (q, est, exact)
    assert abs(h.mean - vals.mean()) / vals.mean() < 1e-6
    assert h.min == vals.min() and h.max == vals.max()


def test_histogram_snapshot_roundtrip_and_summary():
    h = Histogram()
    for v in (0.001, 0.002, 0.004, 0.008):
        h.observe(v)
    snap = h.to_snapshot()
    # snapshot is plain JSON (string bucket keys) and round-trips
    h2 = Histogram.from_snapshot(json.loads(json.dumps(snap)))
    assert h2.count == 4 and h2.sum == h.sum
    s = summarize_histogram(snap, scale=1e3)   # seconds -> ms
    assert s["count"] == 4
    assert s["min"] == 1.0 and s["max"] == 8.0
    assert 1.0 <= s["p50"] <= 8.0


def test_registry_merge_with_extra_labels():
    """Coordinator folding two worker snapshots keeps per-worker series
    apart while counters/buckets add."""
    coord, w0, w1 = MetricsRegistry(), MetricsRegistry(), MetricsRegistry()
    for _ in range(3):
        w0.inc("worker.iters")
        w0.observe("worker.iter_s", 0.010)
    for _ in range(5):
        w1.inc("worker.iters")
        w1.observe("worker.iter_s", 0.020)
    coord.merge(w0.snapshot(), extra_labels={"worker": "0"})
    coord.merge(w1.snapshot(), extra_labels={"worker": "1"})
    # merging the same worker twice ADDS (heartbeat-then-bye is diffed by
    # callers; merge itself is additive)
    assert coord.counter_value("worker.iters", worker="0") == 3
    assert coord.counter_value("worker.iters", worker="1") == 5
    assert coord.labeled("worker.iters", "worker") == {"0": 3, "1": 5}
    h0 = coord.histogram_snapshot("worker.iter_s", worker="0")
    h1 = coord.histogram_snapshot("worker.iter_s", worker="1")
    assert h0["count"] == 3 and h1["count"] == 5
    assert abs(h0["sum"] - 0.030) < 1e-12


def test_bytecounter_legacy_snapshot_and_merge():
    """ByteCounter rides the registry but keeps its legacy dict shape
    (coordinator _telemetry and cluster_bench consume it)."""
    a, b = ByteCounter(), ByteCounter()
    a.add("tx", "contrib", 100)
    a.add("tx", "contrib", 50)
    a.add("rx", "x", 24)
    b.add("tx", "hello", 7)
    snap = a.snapshot()
    assert snap["sent_bytes"] == {"contrib": 150}
    assert snap["sent_msgs"] == {"contrib": 2}
    assert snap["received_bytes"] == {"x": 24}
    a.merge(b.snapshot())
    assert a.snapshot()["sent_bytes"] == {"contrib": 150, "hello": 7}
    assert a.total("tx") == 157


# -- tracer -----------------------------------------------------------------

def test_span_nesting_and_attrs(tmp_path):
    tr = Tracer(enabled=True, process_name="test-proc")
    with tr.span("outer", k=1):
        with tr.span("inner", block="b3", k=2):
            pass
    path = str(tmp_path / "trace.json")
    tr.export(path)
    events = load_trace(path)
    xs = {e["name"]: e for e in events if e.get("ph") == "X"}
    assert set(xs) == {"outer", "inner"}
    assert xs["inner"]["args"] == {"block": "b3", "k": 2}
    assert xs["outer"]["args"] == {"k": 1}
    # nesting: inner starts no earlier and ends no later than outer
    # (ts is integer µs, so allow the 1 µs truncation)
    o, i = xs["outer"], xs["inner"]
    assert i["ts"] >= o["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1.0
    # metadata rows: process name + a thread name for the emitting tid
    metas = [e for e in events if e.get("ph") == "M"]
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "test-proc" for e in metas)
    assert any(e["name"] == "thread_name"
               and e["tid"] == o["tid"] for e in metas)
    hot = span_hotspots(events)
    assert hot[0]["name"] == "outer" and hot[0]["count"] == 1


def test_tracer_merges_worker_events():
    """Coordinator folds a worker's shipped event list under its own
    timeline with a process_name track for the worker pid."""
    coord = Tracer(enabled=True, process_name="coordinator")
    worker = Tracer(enabled=True)        # no process meta of its own
    with worker.span("block_step", block=0):
        pass
    shipped = worker.events()
    coord.add_events(shipped, process_name="worker-0", pid=4242)
    events = coord.events()
    assert any(e.get("ph") == "M" and e.get("name") == "process_name"
               and e.get("pid") == 4242
               and e["args"]["name"] == "worker-0" for e in events)
    assert any(e.get("ph") == "X" and e["name"] == "block_step"
               for e in events)


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False, process_name="off")
    with tr.span("x", a=1):
        pass
    tr.instant("i")
    assert tr.events() == []
    obs = Observability(dir=None, enabled=False)
    obs.inc("n")
    obs.observe("h", 1.0)
    obs.record(iter=1)
    with obs.span("s"):
        pass
    assert obs.registry.snapshot()["counters"] == []
    obs.finish()   # no dir: must not write anything / raise


# -- telemetry parity with ADMMHistory --------------------------------------

def test_telemetry_matches_admm_history_lasso(tmp_path):
    """A small lasso solve with --obs-dir-style telemetry: the JSONL
    stream reproduces ADMMHistory (objective / primal / dual residuals)
    to float tolerance."""
    prob = lasso_problem(jax.random.PRNGKey(5), N=2, m_per_node=100, n=16)
    Dflat = prob.D.reshape(-1, 16)
    bflat = prob.b.reshape(-1)
    mu = float(prob.mu)
    m = Dflat.shape[0]
    D_hat = jnp.concatenate([jnp.eye(16), Dflat], axis=0)[None]
    sp = StackedProx(blocks=(make_l1(mu), make_least_squares()),
                     sizes=(16, m))
    aux = jnp.concatenate([jnp.zeros(16), bflat])[None]
    solver = UnwrappedADMM(loss=sp.as_loss(), tau=0.01 * m)

    rundir = str(tmp_path / "obs")
    obs = Observability(dir=rundir, process_name="test")
    res = solver.run(D_hat, aux, iters=25, obs=obs)
    obs.finish()

    recs = [r for r in read_jsonl(str(tmp_path / "obs" / TELEMETRY_FILE))
            if "iter" in r]
    hist = res.history
    assert len(recs) == 25
    np.testing.assert_allclose(
        [r["objective"] for r in recs], np.asarray(hist.objective),
        rtol=1e-6)
    np.testing.assert_allclose(
        [r["primal_res"] for r in recs], np.asarray(hist.primal_res),
        rtol=1e-6)
    np.testing.assert_allclose(
        [r["dual_res"] for r in recs], np.asarray(hist.dual_res),
        rtol=1e-6)
    assert all(r["tau"] == solver.tau for r in recs)

    # the run directory holds all three artifacts and the metrics
    # snapshot counted the run
    with open(tmp_path / "obs" / METRICS_FILE) as f:
        snap = json.load(f)
    assert any(e["name"] == "admm.runs" and e["value"] == 1
               for e in snap["counters"])
    trace = load_trace(str(tmp_path / "obs" / TRACE_FILE))
    assert any(e.get("ph") == "X" and e["name"] == "admm_run"
               for e in trace)


def test_obs_disabled_run_identical(tmp_path):
    """obs=None and an enabled obs produce bit-identical solver output
    (instrumentation reads, never perturbs)."""
    prob = lasso_problem(jax.random.PRNGKey(6), N=1, m_per_node=80, n=16)
    Dflat = prob.D.reshape(-1, 16)
    m = Dflat.shape[0]
    D_hat = jnp.concatenate([jnp.eye(16), Dflat], axis=0)[None]
    sp = StackedProx(blocks=(make_l1(float(prob.mu)), make_least_squares()),
                     sizes=(16, m))
    aux = jnp.concatenate([jnp.zeros(16), prob.b.reshape(-1)])[None]
    solver = UnwrappedADMM(loss=sp.as_loss(), tau=0.01 * m)
    r0 = solver.run(D_hat, aux, iters=10)
    obs = Observability(dir=str(tmp_path / "o"), process_name="t")
    r1 = solver.run(D_hat, aux, iters=10, obs=obs)
    obs.finish()
    np.testing.assert_array_equal(np.asarray(r0.x), np.asarray(r1.x))
