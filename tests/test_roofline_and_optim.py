"""Roofline HLO parser unit tests + optimizer sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import Adafactor, AdamW
from repro.roofline.hlo import (
    CollectiveStats,
    parse_collectives,
    roofline_terms,
)

jax.config.update("jax_platform_name", "cpu")

HLO = """
HloModule test
  %x1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %p0), replica_groups=[16,16]<=[256], to_apply=%add
  %x2 = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %p1), replica_groups=[2,8]<=[16], dimensions={0}
  %x3 = f32[64]{0} reduce-scatter(f32[512]{0} %p2), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %x4 = (f32[32,32]{1,0}, f32[32,32]{1,0}) all-to-all(f32[32,32]{1,0} %a, f32[32,32]{1,0} %b), replica_groups=[4,2]<=[8]
  %x5 = f32[128]{0} collective-permute(f32[128]{0} %p3), source_target_pairs={{0,1}}
  %y = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""


def test_parse_collectives_kinds_and_groups():
    st = parse_collectives(HLO)
    kinds = [op["kind"] for op in st.ops]
    assert kinds == ["all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute"]
    groups = [op["group"] for op in st.ops]
    assert groups == [16, 8, 8, 2, 1]


def test_parse_collectives_byte_accounting():
    st = parse_collectives(HLO)
    ar = st.ops[0]
    assert ar["bytes"] == 1024 * 512 * 4
    assert ar["wire_bytes"] == int(2 * ar["bytes"] * 15 / 16)
    ag = st.ops[1]
    assert ag["bytes"] == 256 * 128 * 2
    assert ag["operand_bytes"] == ag["bytes"] // 8
    rs = st.ops[2]
    assert rs["operand_bytes"] == 512 * 4   # per-device input is the full array
    a2a = st.ops[3]
    assert a2a["bytes"] == 2 * 32 * 32 * 4  # tuple shape


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 100e9, 1e9)     # 1s compute, tiny others
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(1e9, 819e9, 1e9)        # 1s memory
    assert t["bottleneck"] == "memory"
    t = roofline_terms(1e9, 1e9, 50e9)         # 1s collective
    assert t["bottleneck"] == "collective"
    assert t["compute_fraction_of_bound"] < 0.01


def _quadratic_problem():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (20, 10)) / 5.0
    b = jax.random.normal(jax.random.PRNGKey(1), (20,))
    params = {"w": jnp.zeros((10, 4)), "b": jnp.zeros((4,))}

    def loss(p):
        pred = A @ p["w"] + p["b"]
        return jnp.mean((pred - b[:, None]) ** 2)

    return params, loss


@pytest.mark.parametrize("opt", [
    AdamW(lr=0.05, warmup_steps=0, total_steps=400, weight_decay=0.0),
    Adafactor(lr=0.5, warmup_steps=0, total_steps=400),
])
def test_optimizer_decreases_quadratic(opt):
    params, loss = _quadratic_problem()
    # analytic optimum of the (overdetermined) least-squares problem
    import numpy as np
    key = jax.random.PRNGKey(0)
    A = np.asarray(jax.random.normal(key, (20, 10)) / 5.0)
    b = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (20,)))
    A1 = np.concatenate([A, np.ones((20, 1))], axis=1)
    w, *_ = np.linalg.lstsq(A1, b, rcond=None)
    l_star = float(np.mean((A1 @ w - b) ** 2))
    state = opt.init(params)
    l0 = float(loss(params))
    for i in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params,
                                   jnp.asarray(i, jnp.float32))
    l_end = float(loss(params))
    # Adafactor (no momentum, RMS-clipped steps) converges slower on this
    # anisotropic quadratic — looser gate.
    frac = 0.25 if isinstance(opt, AdamW) else 0.55
    assert l_end < l_star + frac * (l0 - l_star), (l_end, l_star, l0)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "stack": jnp.zeros((4, 16, 8)),
              "b": jnp.zeros((7,))}
    st = Adafactor().init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["stack"]["vr"].shape == (4, 16)
    assert st["f"]["stack"]["vc"].shape == (4, 8)
    assert st["f"]["b"]["v"].shape == (7,)
    n_state = sum(x.size for x in jax.tree.leaves(st))
    n_param = sum(x.size for x in jax.tree.leaves(params))
    assert n_state < 0.2 * n_param  # the arctic-480b memory plan


def test_adamw_weight_decay_shrinks():
    opt = AdamW(lr=0.1, weight_decay=0.5, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.zeros((4,))}
    p2, _ = opt.update(g, state, params, jnp.asarray(5, jnp.float32))
    assert float(p2["w"][0]) < 1.0
