"""Serving-layer tests: sufficient-stats algebra, rank-k factor updates,
registry dispatch, batched multi-RHS solving, and the FitServer cache
contract (a warm fingerprint never re-runs the Gram pass)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gram as gram_lib
from repro.core.fit import fit
from repro.data.synthetic import lasso_problem
from repro.service import (
    FitRequest,
    FitServer,
    SufficientStats,
    chol_downdate,
    chol_update,
    registry,
)
from repro.service.batching import (
    batched_gram_solve,
    batched_quad_prox,
    lasso_mu_path,
    rhs_chunked,
)

jax.config.update("jax_platform_name", "cpu")


def _data(m=300, n=16, seed=0):
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(m), jnp.float32)
    return D, b


# ---------------------------------------------------------------------------
# SufficientStats algebra
# ---------------------------------------------------------------------------

def test_merge_of_updates_equals_update_of_union():
    """merge(update(a), update(b)) == update(a+b), fingerprint included."""
    D, b = _data()
    z = SufficientStats.zero(16)
    sa = z.update(D[:100], b[:100])
    sb = z.update(D[100:], b[100:])
    merged = sa.merge(sb)
    direct = z.update(D[:100], b[:100]).update(D[100:], b[100:])
    np.testing.assert_allclose(merged.G, direct.G, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(merged.c, direct.c, rtol=1e-5, atol=1e-4)
    assert merged.rows == direct.rows == 300
    assert merged.fingerprint == direct.fingerprint
    # and both equal the one-shot reduction of the union
    whole = SufficientStats.from_data(D, b)
    np.testing.assert_allclose(merged.G, whole.G, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(merged.c, whole.c, rtol=1e-4, atol=1e-3)


def test_merge_is_commutative():
    D, b = _data()
    z = SufficientStats.zero(16)
    sa = z.update(D[:100], b[:100])
    sb = z.update(D[100:], b[100:])
    ab, ba = sa.merge(sb), sb.merge(sa)
    np.testing.assert_allclose(ab.G, ba.G, rtol=1e-6)
    assert ab.fingerprint == ba.fingerprint


def test_update_then_downdate_roundtrip():
    D, b = _data()
    s = SufficientStats.from_data(D[:200], b[:200])
    s2 = s.update(D[200:], b[200:]).downdate(D[200:], b[200:])
    np.testing.assert_allclose(s2.G, s.G, rtol=1e-4, atol=1e-3)
    assert s2.rows == s.rows
    assert s2.fingerprint == s.fingerprint    # the +/- fold cancels exactly


def test_fingerprint_is_multiplicity_sensitive():
    """Ingesting the same block twice must NOT alias the original stats."""
    D, b = _data()
    s0 = SufficientStats.from_data(D[:200], b[:200])
    s1 = s0.update(D[200:], b[200:])
    s2 = s1.update(D[200:], b[200:])          # same block again
    assert s2.fingerprint != s0.fingerprint
    assert s2.fingerprint != s1.fingerprint


def test_stats_is_a_pytree():
    D, b = _data()
    s = SufficientStats.from_data(D, b)
    doubled = jax.tree_util.tree_map(lambda x: 2 * x, s)
    assert isinstance(doubled, SufficientStats)
    np.testing.assert_allclose(doubled.G, 2 * np.asarray(s.G), rtol=1e-6)
    assert doubled.fingerprint == s.fingerprint


def test_stats_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    D, b = _data()
    s = SufficientStats.from_data(D, b)
    mgr = CheckpointManager(str(tmp_path))
    s.save(mgr, step=0)
    r = SufficientStats.restore(mgr, n=16)
    np.testing.assert_array_equal(np.asarray(r.G), np.asarray(s.G))
    np.testing.assert_array_equal(np.asarray(r.c), np.asarray(s.c))
    assert (r.rows, r.fingerprint) == (s.rows, s.fingerprint)


# ---------------------------------------------------------------------------
# Cholesky rank-k up/downdate
# ---------------------------------------------------------------------------

def test_rank_k_update_matches_fresh_factorization():
    D, _ = _data()
    G = np.asarray(D.T @ D)
    L = gram_lib.gram_factor(jnp.asarray(G), ridge=0.1)
    B = jnp.asarray(np.random.default_rng(1).standard_normal((5, 16)),
                    jnp.float32)
    L_up = chol_update(L, B)
    L_fresh = gram_lib.gram_factor(jnp.asarray(G) + B.T @ B, ridge=0.1)
    np.testing.assert_allclose(np.asarray(L_up), np.asarray(L_fresh),
                               rtol=1e-5, atol=1e-5)


def test_rank_k_downdate_matches_fresh_factorization():
    D, _ = _data()
    B = D[:5]
    G_full = np.asarray(D.T @ D)
    L_full = gram_lib.gram_factor(jnp.asarray(G_full), ridge=0.1)
    L_down = chol_downdate(L_full, B)
    L_fresh = gram_lib.gram_factor(
        jnp.asarray(G_full) - B.T @ B, ridge=0.1)
    np.testing.assert_allclose(np.asarray(L_down), np.asarray(L_fresh),
                               rtol=1e-4, atol=1e-4)


def test_rank_1_vector_block():
    D, _ = _data()
    G = jnp.asarray(np.asarray(D.T @ D))
    L = gram_lib.gram_factor(G, ridge=1.0)
    v = D[0]                                   # 1-D block
    L_up = chol_update(L, v)
    L_fresh = gram_lib.gram_factor(G + jnp.outer(v, v), ridge=1.0)
    np.testing.assert_allclose(np.asarray(L_up), np.asarray(L_fresh),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Registry: one entry point, >= 7 problems, unchanged dispatch semantics
# ---------------------------------------------------------------------------

def test_registry_exposes_at_least_seven_problems():
    assert len(registry.problems()) >= 7
    for p in ("lasso", "logistic", "svm", "sparse_logistic", "ridge",
              "elastic_net", "huber", "nnls"):
        assert p in registry.problems(), p


def test_registry_rejects_unknown_combo():
    D = jnp.zeros((1, 4, 2))
    with pytest.raises(ValueError, match="registered problems"):
        fit("isotonic", D, jnp.zeros((1, 4)))
    with pytest.raises(ValueError, match="methods"):
        fit("ridge", D, jnp.zeros((1, 4)), method="consensus")


def test_ridge_matches_normal_equations():
    D, b = _data()
    r = fit("ridge", D.reshape(4, 75, 16), b.reshape(4, 75), mu=2.0)
    x_ref = np.linalg.solve(np.asarray(D.T @ D) + 2.0 * np.eye(16),
                            np.asarray(D.T @ b))
    np.testing.assert_allclose(np.asarray(r.x), x_ref, rtol=1e-4, atol=1e-5)


def test_elastic_net_reduces_to_lasso_at_zero_l2():
    lp = lasso_problem(jax.random.PRNGKey(0), N=4, m_per_node=100, n=12)
    r_en = fit("elastic_net", lp.D, lp.b, mu=float(lp.mu), l2=0.0,
               iters=1500)
    r_la = fit("lasso", lp.D, lp.b, mu=float(lp.mu), iters=1500)
    np.testing.assert_allclose(np.asarray(r_en.x), np.asarray(r_la.x),
                               rtol=1e-3, atol=1e-4)


def test_nnls_is_nonnegative_and_kkt():
    D, b = _data()
    r = fit("nnls", D.reshape(4, 75, 16), b.reshape(4, 75), iters=2000)
    x = np.asarray(r.x)
    assert (x >= 0).all()
    # KKT: gradient >= 0 where x == 0, ~0 where x > 0
    g = np.asarray(D.T @ D) @ x - np.asarray(D.T @ b)
    assert g[x > 1e-6].max(initial=-np.inf) < 1e-2
    assert g[x <= 1e-6].min(initial=np.inf) > -1e-2


def test_huber_tracks_least_squares_for_large_delta():
    D, b = _data()
    r = fit("huber", D.reshape(4, 75, 16), b.reshape(4, 75), delta=100.0,
            iters=400)
    x_ls = np.linalg.lstsq(np.asarray(D), np.asarray(b), rcond=None)[0]
    np.testing.assert_allclose(np.asarray(r.x), x_ls, rtol=5e-2, atol=5e-3)


def test_warm_start_resumes_at_solution():
    """x0 is honoured: restarting from the solution stays at the solution."""
    D, b = _data()
    Dn, bn = D.reshape(4, 75, 16), b.reshape(4, 75)
    r1 = fit("huber", Dn, bn, delta=1.0, iters=300)
    r2 = fit("huber", Dn, bn, delta=1.0, iters=20, x0=r1.x)
    cold = fit("huber", Dn, bn, delta=1.0, iters=20)
    h1 = float(r1.objective_history[-1])
    assert float(r2.objective_history[0]) < float(cold.objective_history[0])
    assert abs(float(r2.objective_history[-1]) - h1) < 1e-2 * abs(h1)


# ---------------------------------------------------------------------------
# Batched solving
# ---------------------------------------------------------------------------

def test_batched_multi_rhs_matches_per_request():
    D, _ = _data()
    rng = np.random.default_rng(2)
    B = jnp.asarray(rng.standard_normal((300, 8)), jnp.float32)
    G = D.T @ D
    L = gram_lib.gram_factor(G, ridge=1.0)
    C = rhs_chunked(D, B)                       # (n, 8)
    X = batched_gram_solve(L, C.T)              # (8, n)
    for j in range(8):
        x_j = gram_lib.gram_solve(L, D.T @ B[:, j])
        np.testing.assert_allclose(np.asarray(X[j]), np.asarray(x_j),
                                   rtol=1e-4, atol=1e-5)


def test_batched_lasso_matches_per_mu():
    lp = lasso_problem(jax.random.PRNGKey(1), N=4, m_per_node=100, n=12)
    Dflat = lp.D.reshape(-1, 12)
    G, c = gram_lib.gram_and_rhs_chunked(Dflat, lp.b.reshape(-1))
    mus = jnp.asarray([0.5, 2.0, 8.0]) * float(lp.mu) / 4.0
    X = lasso_mu_path(G, c, mus, iters=800)
    from repro.core.fasta import transpose_reduction_lasso
    for j, mu in enumerate(np.asarray(mus)):
        x_j = transpose_reduction_lasso(G, c, float(mu), iters=800).x
        np.testing.assert_allclose(np.asarray(X[j]), np.asarray(x_j),
                                   rtol=1e-3, atol=1e-4)


def test_batched_nnls_lanes():
    D, _ = _data()
    rng = np.random.default_rng(3)
    C = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    G = D.T @ D
    X, _ = batched_quad_prox(G, C, jnp.zeros((4,)), kind="nnls", iters=500)
    assert (np.asarray(X) >= 0).all()


# ---------------------------------------------------------------------------
# FitServer: cache contract + coalescing
# ---------------------------------------------------------------------------

def test_server_warm_fit_skips_gram_pass():
    D, b = _data()
    srv = FitServer(window=1)
    fp = srv.register_dataset(D, b)
    assert srv.counters.gram_passes == 1
    r1 = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert srv.counters.gram_passes == 1        # no recompute on first fit
    r2 = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert srv.counters.gram_passes == 1        # ...nor on the warm fit
    assert srv.counters.factorizations == 1     # factor cached too
    assert srv.counters.factor_cache_hits >= 1
    np.testing.assert_allclose(r1[0].x, r2[0].x, rtol=1e-6)


def test_server_batched_solve_matches_single_solves():
    D, b = _data()
    rng = np.random.default_rng(4)
    B = rng.standard_normal((300, 6)).astype(np.float32)
    srv = FitServer(window=6)
    fp = srv.register_dataset(D)
    reqs = [FitRequest(problem="ridge", fingerprint=fp, b=B[:, j], mu=1.0)
            for j in range(6)]
    resp = srv.serve(reqs)
    assert len(resp) == 6 and resp[0].batch_size == 6
    L = gram_lib.gram_factor(D.T @ D, ridge=1.0)
    for j, r in enumerate(sorted(resp, key=lambda r: r.request_id)):
        x_ref = gram_lib.gram_solve(L, D.T @ jnp.asarray(B[:, j]))
        np.testing.assert_allclose(r.x, np.asarray(x_ref), rtol=1e-4,
                                   atol=1e-5)


def test_server_lasso_group_vmaps_over_mu():
    lp = lasso_problem(jax.random.PRNGKey(2), N=4, m_per_node=100, n=12)
    srv = FitServer(window=3)
    fp = srv.register_dataset(lp.D, lp.b)
    mus = [float(lp.mu) * s for s in (0.2, 0.5, 1.0)]
    resp = srv.serve([FitRequest(problem="lasso", fingerprint=fp, mu=mu,
                                 iters=800) for mu in mus])
    assert len(resp) == 3 and resp[0].batch_size == 3
    assert srv.counters.gram_passes == 1
    for mu, r in zip(mus, sorted(resp, key=lambda r: r.request_id)):
        ref = fit("lasso", lp.D, lp.b, mu=mu, iters=800)
        np.testing.assert_allclose(r.x, np.asarray(ref.x), rtol=1e-3,
                                   atol=1e-4)


def test_server_ingest_updates_factor_in_place():
    D, b = _data()
    srv = FitServer(window=1)
    fp = srv.register_dataset(D[:250], b[:250])
    srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert srv.counters.factorizations == 1
    fp2 = srv.ingest_block(fp, D[250:], b[250:])
    assert fp2 != fp
    assert srv.counters.factor_updates == 1     # rank-k, not refactorized
    r = srv.serve([FitRequest(problem="ridge", fingerprint=fp2, mu=1.0)])
    assert srv.counters.factorizations == 1     # still the one factorization
    x_ref = np.linalg.solve(np.asarray(D.T @ D) + np.eye(16),
                            np.asarray(D.T @ b))
    np.testing.assert_allclose(r[0].x, x_ref, rtol=1e-3, atol=1e-3)


def test_server_full_solve_fallback():
    rng = np.random.default_rng(5)
    D = jnp.asarray(rng.standard_normal((200, 8)), jnp.float32)
    labels = jnp.sign(D @ jnp.ones((8,)) + 0.1)
    srv = FitServer(window=1)
    fp = srv.register_dataset(D)
    resp = srv.serve([FitRequest(problem="logistic", fingerprint=fp,
                                 b=np.asarray(labels), iters=100)])
    assert resp[0].from_cache is False
    assert srv.counters.full_solves == 1
    acc = np.mean(np.sign(np.asarray(D) @ resp[0].x) == np.asarray(labels))
    assert acc > 0.9


def test_server_rejects_l1_requests_without_mu():
    # since the flush-poisoning isolation work, a bad group is answered
    # with per-request error responses instead of raising out of flush()
    D, b = _data()
    srv = FitServer(window=1)
    fp = srv.register_dataset(D, b)
    out = srv.serve([FitRequest(problem="lasso", fingerprint=fp)])
    assert len(out) == 1
    assert out[0].status == "error" and "no mu" in out[0].error


def test_server_full_solve_reuses_registered_labels():
    rng = np.random.default_rng(6)
    D = jnp.asarray(rng.standard_normal((200, 8)), jnp.float32)
    labels = jnp.sign(D @ jnp.ones((8,)) + 0.1)
    srv = FitServer(window=1)
    fp = srv.register_dataset(D, labels)
    resp = srv.serve([FitRequest(problem="logistic", fingerprint=fp,
                                 iters=100)])          # b=None: reuse
    acc = np.mean(np.sign(np.asarray(D) @ resp[0].x) == np.asarray(labels))
    assert acc > 0.9


def test_server_unlabeled_ingest_invalidates_registered_rhs():
    """An unlabeled block grows G but not c: serving the stale c would
    silently mix new-rows Gram with old-rows rhs."""
    D, b = _data()
    srv = FitServer(window=1)
    fp = srv.register_dataset(D[:250], b[:250])
    fp2 = srv.ingest_block(fp, D[250:])          # no labels for the block
    out = srv.serve([FitRequest(problem="ridge", fingerprint=fp2, mu=1.0)])
    assert out[0].status == "error"
    assert "none was registered" in out[0].error
    # fresh-b requests still work: G is consistent, only c went stale
    resp = srv.serve([FitRequest(problem="ridge", fingerprint=fp2,
                                 b=np.asarray(b), mu=1.0)])
    x_ref = np.linalg.solve(np.asarray(D.T @ D) + np.eye(16),
                            np.asarray(D.T @ b))
    np.testing.assert_allclose(resp[0].x, x_ref, rtol=1e-3, atol=1e-3)


def test_register_stats_gates_rhs_on_full_labeling():
    """Partially-labeled stats (G covers more rows than c) adopted on a
    replica must refuse b=None solves — fully_labeled travels with the
    stats, not with the server that built them."""
    D, b = _data()
    partial = SufficientStats.zero(16).update(D[:200]).update(
        D[200:], b[200:])                       # only the tail is labeled
    assert not partial.fully_labeled
    srv = FitServer(window=1)
    fp = srv.register_stats(partial)
    out = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert out[0].status == "error"
    assert "none was registered" in out[0].error
    full = SufficientStats.from_data(D, b)
    assert full.fully_labeled
    fp2 = srv.register_stats(full)
    assert len(srv.serve([FitRequest(problem="ridge", fingerprint=fp2,
                                     mu=1.0)])) == 1


def test_multi_rhs_stats_single_pass():
    """from_data with stacked (m, r) rhs matches per-column reductions."""
    D, _ = _data()
    rng = np.random.default_rng(7)
    B = jnp.asarray(rng.standard_normal((300, 3)), jnp.float32)
    s = SufficientStats.from_data(D, B)
    assert s.c.shape == (16, 3)
    np.testing.assert_allclose(np.asarray(s.c), np.asarray(D.T @ B),
                               rtol=1e-4, atol=1e-3)


def test_register_dataset_keeps_stacked_rhs_2d():
    """(m, r) stacked right-hand sides must not be flattened against D."""
    D, _ = _data()
    rng = np.random.default_rng(8)
    B = jnp.asarray(rng.standard_normal((300, 2)), jnp.float32)
    srv = FitServer(window=1)
    fp = srv.register_dataset(D, B)
    assert srv.stats_for(fp).c.shape == (16, 2)
    np.testing.assert_allclose(np.asarray(srv.stats_for(fp).c),
                               np.asarray(D.T @ B), rtol=1e-4, atol=1e-3)
    # a stacked c is not a reusable single rhs
    out = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert out[0].status == "error"
    assert "none was registered" in out[0].error
    with pytest.raises(ValueError, match="rows"):
        srv.register_dataset(D, jnp.zeros((7,)))


def test_lasso_honours_l2_as_elastic_net():
    lp = lasso_problem(jax.random.PRNGKey(3), N=4, m_per_node=100, n=12)
    r_l = fit("lasso", lp.D, lp.b, mu=float(lp.mu), l2=0.7, iters=1200)
    r_e = fit("elastic_net", lp.D, lp.b, mu=float(lp.mu), l2=0.7,
              iters=1200)
    np.testing.assert_allclose(np.asarray(r_l.x), np.asarray(r_e.x),
                               rtol=1e-4, atol=1e-5)


def test_batched_quad_prox_unknown_kind():
    G = jnp.eye(4)
    with pytest.raises(ValueError, match="no gram solver"):
        batched_quad_prox(G, jnp.zeros((2, 4)), jnp.zeros((2,)),
                          kind="quantile")


def test_server_lru_eviction():
    D, b = _data()
    srv = FitServer(window=1, factor_cache_size=2)
    fp = srv.register_dataset(D, b)
    for mu in (1.0, 2.0, 3.0):                  # 3 factors, capacity 2
        srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=mu)])
    assert srv.counters.factorizations == 3
    assert len(srv._factors) == 2
    srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert srv.counters.factorizations == 4     # mu=1.0 was evicted


# ---------------------------------------------------------------------------
# robustness satellites (DESIGN.md §15): thread safety, flush poisoning,
# atomic ingest/retire
# ---------------------------------------------------------------------------

def test_server_concurrent_submits_lose_nothing():
    """Many threads hammering submit() concurrently: every request gets
    exactly one response, across auto-flushes and the final flush."""
    import threading

    D, b = _data()
    srv = FitServer(window=8)
    fp = srv.register_dataset(D, b)
    n_threads, per_thread = 8, 25
    reqs = [[FitRequest(problem="ridge", fingerprint=fp, mu=1.0)
             for _ in range(per_thread)] for _ in range(n_threads)]
    expected = {r.request_id for batch in reqs for r in batch}
    collected = []
    coll_lock = threading.Lock()

    def worker(batch):
        got = []
        for r in batch:
            got.extend(srv.submit(r))
        with coll_lock:
            collected.extend(got)

    threads = [threading.Thread(target=worker, args=(reqs[i],))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    collected.extend(srv.flush())
    got_ids = [r.request_id for r in collected]
    assert len(got_ids) == len(expected)          # nothing lost
    assert len(set(got_ids)) == len(got_ids)      # nothing double-answered
    assert set(got_ids) == expected
    assert all(r.status == "ok" for r in collected)
    assert srv.counters.responses == n_threads * per_thread


def test_flush_isolates_poisoned_groups():
    """One bad group must not cost sibling groups their responses."""
    D, b = _data()
    srv = FitServer(window=64)
    fp = srv.register_dataset(D, b)
    good1 = FitRequest(problem="ridge", fingerprint=fp, mu=1.0)
    bad_fp = FitRequest(problem="ridge", fingerprint="f" * 64, mu=1.0)
    bad_mu = FitRequest(problem="lasso", fingerprint=fp)    # mu missing
    good2 = FitRequest(problem="ridge", fingerprint=fp, mu=2.0)
    for r in (good1, bad_fp, bad_mu, good2):
        srv.submit(r)
    out = {r.request_id: r for r in srv.flush()}
    assert len(out) == 4
    assert out[good1.request_id].status == "ok"
    assert out[good2.request_id].status == "ok"
    r1 = out[bad_fp.request_id]
    assert r1.status == "error" and r1.x is None
    assert "unknown dataset fingerprint" in r1.error
    r2 = out[bad_mu.request_id]
    assert r2.status == "error" and "no mu" in r2.error
    assert srv.counters.errors == 2
    assert srv.counters.responses == 4
    # the good answers are real solves, not error-path leftovers
    x_ref = np.linalg.solve(np.asarray(D.T @ D) + np.eye(16),
                            np.asarray(D.T @ b))
    np.testing.assert_allclose(out[good1.request_id].x, x_ref,
                               rtol=1e-3, atol=1e-3)


def test_ingest_block_failure_leaves_dataset_intact():
    D, b = _data()
    srv = FitServer()
    fp = srv.register_dataset(D, b)
    # warm a factor so the atomicity claim covers the factor cache too
    srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    hits_before = srv.counters.factor_cache_hits
    bad_block = np.ones((10, 7), np.float32)      # wrong width
    with pytest.raises(ValueError, match="does not match dataset width"):
        srv.ingest_block(fp, bad_block)
    # old fingerprint still serves, factor still cached
    out = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert out[0].status == "ok"
    assert srv.counters.factor_cache_hits == hits_before + 1


def test_ingest_unknown_fingerprint_is_a_clear_error():
    srv = FitServer()
    with pytest.raises(KeyError, match="unknown dataset fingerprint"):
        srv.ingest_block("a" * 64, np.ones((4, 3), np.float32))
    with pytest.raises(KeyError, match="unknown dataset fingerprint"):
        srv.retire_block("a" * 64, np.ones((4, 3), np.float32))


def test_retire_rejects_more_rows_than_dataset():
    D, b = _data(m=50)
    srv = FitServer()
    fp = srv.register_dataset(D, b)
    with pytest.raises(ValueError, match="cannot retire"):
        srv.retire_block(fp, np.ones((51, 16), np.float32))
    assert srv.serve([FitRequest(problem="ridge", fingerprint=fp,
                                 mu=1.0)])[0].status == "ok"


def test_retire_never_ingested_block_detected_before_commit():
    """Downdating by rows that were never ingested drives the factor
    indefinite; the server must detect it and keep the old dataset."""
    D, b = _data()
    srv = FitServer()
    fp = srv.register_dataset(D, b)
    srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    alien = np.asarray(10.0 * D[:50])             # energy G never held
    with pytest.raises(ValueError, match="not previously ingested"):
        srv.retire_block(fp, alien)
    out = srv.serve([FitRequest(problem="ridge", fingerprint=fp, mu=1.0)])
    assert out[0].status == "ok"
    x_ref = np.linalg.solve(np.asarray(D.T @ D) + np.eye(16),
                            np.asarray(D.T @ b))
    np.testing.assert_allclose(out[0].x, x_ref, rtol=1e-3, atol=1e-3)
