"""Pure-jnp oracle for the fused prox/lambda update (paper Alg. 2 lines 7-8)."""
import jax
import jax.numpy as jnp


def _prox(kind, z, delta, aux, newton_iters=3, bisect_iters=40, param=0.0):
    if kind == "logistic":
        # bisection on the monotone phi' over [z-d, z+d], Newton polish
        # (mirrors repro.core.prox.logistic_prox_newton).
        dphi = lambda y: -aux * jax.nn.sigmoid(-aux * y) + (y - z) / delta
        lo, hi = z - delta, z + delta
        for _ in range(bisect_iters):
            mid = 0.5 * (lo + hi)
            pos = dphi(mid) > 0
            lo = jnp.where(pos, lo, mid)
            hi = jnp.where(pos, mid, hi)
        y = 0.5 * (lo + hi)
        for _ in range(newton_iters):
            s = jax.nn.sigmoid(-aux * y)
            g = -aux * s + (y - z) / delta
            h = s * (1.0 - s) + 1.0 / delta
            y = y - jnp.clip(g / h, -delta, delta)
        return y
    if kind == "hinge":
        return z + aux * jnp.maximum(jnp.minimum(1.0 - aux * z, delta), 0.0)
    if kind == "l1":
        return jnp.sign(z) * jnp.maximum(jnp.abs(z) - delta, 0.0)
    if kind == "least_squares":
        return (z + delta * aux) / (1.0 + delta)
    if kind == "quantile":
        # pinball at level q = param: asymmetric soft-threshold on z - aux
        q = param
        r0 = z - aux
        r = jnp.where(r0 > delta * q, r0 - delta * q,
                      jnp.where(r0 < -delta * (1.0 - q),
                                r0 + delta * (1.0 - q), 0.0))
        return aux + r
    raise ValueError(kind)


def prox_update_ref(kind, Dx, lam, aux, delta, newton_iters=8, param=0.0):
    """y = prox_f(Dx + lam, delta); lam' = lam + Dx - y. f32 math."""
    Dxf = Dx.astype(jnp.float32)
    lamf = lam.astype(jnp.float32)
    auxf = aux.astype(jnp.float32) if aux is not None else None
    z = Dxf + lamf
    y = _prox(kind, z, jnp.float32(delta), auxf, newton_iters, param=param)
    return y, lamf + Dxf - y
