"""Jitted wrapper for the fused prox/lambda kernel: 1-D streams of any
length are padded and tiled to the (rows, 1024) lane layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.prox.prox import prox_update_pallas


@functools.partial(
    jax.jit,
    static_argnames=("kind", "delta", "newton_iters", "block_rows",
                     "interpret", "param"),
)
def prox_update(
    Dx: jax.Array,
    lam: jax.Array,
    aux: jax.Array | None,
    *,
    kind: str,
    delta: float,
    newton_iters: int = 3,
    block_rows: int = 256,
    interpret: bool = False,
    param: float = 0.0,
):
    """y = prox_f(Dx + lam, delta); lam' = lam + Dx - y, fused. 1-D inputs."""
    (m,) = Dx.shape
    lanes = 1024
    tile = block_rows * lanes
    pad = (-m) % tile
    if aux is None:
        aux = jnp.zeros_like(Dx)

    def _prep(v):
        return jnp.pad(v, (0, pad)).reshape(-1, lanes)

    # Padded tail: aux=0 is safe for every kind (logistic prox at l=0 returns
    # z; hinge/l1/ls are well-defined) — the tail is sliced away below.
    y, lam_new = prox_update_pallas(
        _prep(Dx), _prep(lam), _prep(aux),
        kind=kind, delta=delta, newton_iters=newton_iters,
        block_rows=block_rows, interpret=interpret, param=param,
    )
    return y.reshape(-1)[:m], lam_new.reshape(-1)[:m]
