"""Pallas TPU kernel: fused ADMM y/lambda update (paper Alg. 2 lines 7-8).

Fuses three elementwise passes (z = Dx + lam; y = prox_f(z, delta);
lam' = lam + Dx - y) into a single HBM read/write sweep — on TPU this step is
pure VPU work and strictly memory-bound, so fusion cuts its HBM traffic from
(3 reads + 2 writes) x m to (3 reads + 2 writes) x m in ONE kernel launch
with no intermediate z/y round-trips (XLA usually fuses too, but here the
fusion is guaranteed and the logistic prox's 8-step Newton iteration stays
in-register, replacing the paper's CPU lookup table — DESIGN.md §3).

Layout: 1-D stream reshaped to (rows, 1024) lanes; grid over row-blocks.
All math f32 in-register regardless of the I/O dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prox_body(kind: str, z, delta: float, aux, newton_iters: int,
               bisect_iters: int = 40, param: float = 0.0):
    if kind == "logistic":
        # Branch-free bisection on the monotone phi' over [z-d, z+d], then a
        # Newton polish — all unrolled in-register on the VPU (undamped
        # Newton oscillates for large delta; see core/prox.py).
        lo = z - delta
        hi = z + delta
        for _ in range(bisect_iters):
            mid = 0.5 * (lo + hi)
            pos = (-aux * jax.nn.sigmoid(-aux * mid)
                   + (mid - z) / delta) > 0
            lo = jnp.where(pos, lo, mid)
            hi = jnp.where(pos, mid, hi)
        y = 0.5 * (lo + hi)
        for _ in range(newton_iters):
            s = jax.nn.sigmoid(-aux * y)
            g = -aux * s + (y - z) / delta
            h = s * (1.0 - s) + 1.0 / delta
            y = y - jnp.clip(g / h, -delta, delta)
        return y
    if kind == "hinge":
        return z + aux * jnp.maximum(jnp.minimum(1.0 - aux * z, delta), 0.0)
    if kind == "l1":
        return jnp.sign(z) * jnp.maximum(jnp.abs(z) - delta, 0.0)
    if kind == "least_squares":
        return (z + delta * aux) / (1.0 + delta)
    if kind == "quantile":
        # pinball loss at level q = param (aux carries the target b):
        # two-sided asymmetric soft-threshold on the residual r0 = z - b —
        # shift by delta*q from above, delta*(1-q) from below, dead-zone
        # to exactly b between (mirrors core/prox.make_quantile).
        q = param
        r0 = z - aux
        r = jnp.where(r0 > delta * q, r0 - delta * q,
                      jnp.where(r0 < -delta * (1.0 - q),
                                r0 + delta * (1.0 - q), 0.0))
        return aux + r
    raise ValueError(kind)


def _kernel(dx_ref, lam_ref, aux_ref, y_ref, lam_out_ref, *, kind, delta,
            newton_iters, param):
    dx = dx_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)
    aux = aux_ref[...].astype(jnp.float32) if aux_ref is not None else None
    z = dx + lam
    y = _prox_body(kind, z, delta, aux, newton_iters, param=param)
    y_ref[...] = y.astype(y_ref.dtype)
    lam_out_ref[...] = (lam + dx - y).astype(lam_out_ref.dtype)


def prox_update_pallas(
    Dx: jax.Array,
    lam: jax.Array,
    aux: jax.Array,
    *,
    kind: str,
    delta: float,
    newton_iters: int = 3,
    block_rows: int = 256,
    lanes: int = 1024,
    interpret: bool = False,
    param: float = 0.0,
):
    """Inputs are (rows, lanes)-shaped streams (ops.py reshapes/pads)."""
    rows, l = Dx.shape
    assert l == lanes and rows % block_rows == 0
    grid = (rows // block_rows,)
    kernel = functools.partial(
        _kernel, kind=kind, delta=float(delta), newton_iters=newton_iters,
        param=float(param),
    )
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(Dx.shape, jnp.float32),
            jax.ShapeDtypeStruct(Dx.shape, jnp.float32),
        ],
        interpret=interpret,
    )(Dx, lam, aux)
