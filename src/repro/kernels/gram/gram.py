"""Pallas TPU kernel for the transpose reduction G = D^T D (paper §4/§5).

TPU-native design (DESIGN.md §7) — this is a *streaming* Gram accumulation,
not a CUDA tile port:

  * D is tall (m >> n): the n x n output tile lives resident in VMEM while
    (bm x bn) row-panels of D stream HBM->VMEM. Arithmetic intensity per
    output tile approaches 2*bm*bn_i*bn_j / (bm*(bn_i+bn_j)) ~ bn FLOP/byte,
    so for bn >= 256 the kernel is MXU-bound, exactly like the paper's
    m >> n regime wants.
  * Grid = (n/bn_i, n/bn_j, m/bm) with the *reduction innermost*: TPU grids
    execute sequentially with the last dimension fastest, so the output
    BlockSpec (constant in k) keeps one accumulator tile in VMEM across the
    entire row stream — no HBM round-trips for partials.
  * Symmetry: G is symmetric, so blocks with i > j skip both the dot and the
    HBM loads' use (the mirror is reconstructed in ops.py) — a ~2x FLOP cut
    the straight jnp lowering does not get.
  * Accumulation is always f32 (bf16 inputs are up-cast in-register via
    preferred_element_type), because the row stream is a very long reduction.

Block shapes are MXU/VREG aligned: bn multiple of 128 (lane), bm multiple of
8 (sublane; 16 for bf16). VMEM budget = bn_i*bn_j*4 + bm*(bn_i+bn_j)*dsize
which for (bm=512, bn=512) f32 is ~3.1 MB — comfortably under ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(d_i_ref, d_j_ref, out_ref, *, symmetric_skip: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def _accum():
        a = d_i_ref[...]
        b = d_j_ref[...]
        out_ref[...] += jax.lax.dot_general(
            a, b,
            dimension_numbers=(((0,), (0,)), ((), ())),   # a^T @ b
            preferred_element_type=jnp.float32,
        )

    if symmetric_skip:
        pl.when(i <= j)(_accum)
    else:
        _accum()


def _gram_rhs_kernel(d_i_ref, d_j_ref, b_ref, g_ref, c_ref, *,
                     symmetric_skip: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init_g():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when((k == 0) & (j == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    def _accum_g():
        g_ref[...] += jax.lax.dot_general(
            d_i_ref[...], d_j_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),   # D_i^T @ D_j
            preferred_element_type=jnp.float32,
        )

    if symmetric_skip:
        pl.when(i <= j)(_accum_g)
    else:
        _accum_g()

    # c_i += D_i^T B, once per (i, k) — the j == 0 sweep reuses the D_i
    # panel already resident in VMEM, so the RHS costs no extra reads of D
    # (and B's own index_map parks on block 0 for j > 0, so B streams only
    # on the sweeps that consume it). B stays f32 even when D streams as
    # bf16 (the rhs is tiny; quantizing it would cost accuracy for no
    # bandwidth win), hence the in-register upcast of the D panel for this
    # dot only.
    @pl.when(j == 0)
    def _accum_c():
        c_ref[...] += jax.lax.dot_general(
            d_i_ref[...].astype(jnp.float32), b_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),   # D_i^T @ B
            preferred_element_type=jnp.float32,
        )


def gram_rhs_pallas(
    D: jax.Array,
    B: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    symmetric_skip: bool = True,
    interpret: bool = False,
):
    """(G, C) = (D^T D, D^T B) in ONE row stream over D (paper §4 setup).

    D: (m, n); B: (m, r) stacked right-hand sides. m % block_m == 0,
    n % block_n == 0, r lane-aligned (ops.py pads; zero rows/cols are exact).
    The C accumulator block (block_n, r) has a j/k-constant index_map so it
    stays VMEM-resident across the whole (j, k) sweep of each row stripe i,
    exactly like the G tiles — the RHS rides the same HBM pass for free.
    """
    m, n = D.shape
    r = B.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (n // block_n, n // block_n, m // block_m)

    kernel = functools.partial(_gram_rhs_kernel,
                               symmetric_skip=symmetric_skip)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, j)),
            # B is consumed only on the j == 0 sweeps; park its index on
            # block 0 for j > 0 so the revisit skips the DMA instead of
            # re-streaming the whole rhs once per column stripe.
            pl.BlockSpec((block_m, r),
                         lambda i, j, k: (jnp.where(j == 0, k, 0), 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_n, r), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, r), jnp.float32),
        ],
        interpret=interpret,
    )(D, D, B)


def gram_pallas(
    D: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    symmetric_skip: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """G = D^T D via Pallas. D: (m, n); returns (n, n) f32.

    m must be a multiple of block_m and n of block_n (ops.py pads; zero rows
    are exact for Gram). When ``symmetric_skip`` the strictly-lower blocks are
    left as garbage and ops.py mirrors the upper triangle.
    """
    m, n = D.shape
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (n // block_n, n // block_n, m // block_m)

    kernel = functools.partial(_gram_kernel, symmetric_skip=symmetric_skip)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(D, D)
