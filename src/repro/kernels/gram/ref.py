"""Pure-jnp oracle for the Gram kernel."""
import jax.numpy as jnp


def gram_ref(D):
    """D^T D with f32 accumulation (f64 passes through)."""
    acc = jnp.float64 if D.dtype == jnp.float64 else jnp.float32
    Dc = D.astype(acc)
    return Dc.T @ Dc


def gram_with_rhs_ref(D, b):
    """(D^T D, D^T b) — the §4 cached quantities."""
    acc = jnp.float64 if D.dtype == jnp.float64 else jnp.float32
    Dc = D.astype(acc)
    bc = b.astype(acc)
    return Dc.T @ Dc, Dc.T @ bc
