"""Jitted public wrapper for the Gram kernel: padding, symmetry restore,
fused RHS (append b as an extra column: Gram([D | b]) contains D^T D, D^T b
and b^T b in one data pass), and interpret-mode fallback for CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_pallas

def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "symmetric_skip", "interpret")
)
def gram(
    D: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    symmetric_skip: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """D^T D, f32, any (m, n) — pads to block multiples (exact for Gram)."""
    m, n = D.shape
    Dp = _pad_to(_pad_to(D, block_m, 0), block_n, 1)
    G = gram_pallas(
        Dp,
        block_m=block_m,
        block_n=block_n,
        symmetric_skip=symmetric_skip,
        interpret=interpret,
    )
    if symmetric_skip:
        # Mirror the computed upper-triangular blocks. Using block-level skip,
        # every full block strictly below the diagonal is garbage; rebuild
        # from the upper triangle (element-wise: the diagonal blocks are full).
        bn = block_n
        nb = Dp.shape[1] // bn
        bi = jnp.arange(Dp.shape[1]) // bn
        upper = bi[:, None] <= bi[None, :]         # block-upper mask
        G = jnp.where(upper, G, G.T)
    return G[:n, :n]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def gram_with_rhs(
    D: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    interpret: bool = False,
):
    """One-pass (D^T D, D^T b) by appending b as column n (paper §4 setup)."""
    m, n = D.shape
    Db = jnp.concatenate([D, b[:, None].astype(D.dtype)], axis=1)
    G = gram(
        Db,
        block_m=block_m,
        block_n=block_n,
        symmetric_skip=True,
        interpret=interpret,
    )
    return G[:n, :n], G[:n, n]
