"""Jitted public wrappers for the Gram kernels: padding, symmetry restore,
the fused Gram+RHS kernel (``gram_and_rhs`` — D^T D and D^T B accumulated in
the same row stream; the engine's setup path), the legacy append-column
trick (``gram_with_rhs``), and interpret-mode fallback for CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_pallas, gram_rhs_pallas

def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "symmetric_skip", "interpret")
)
def gram(
    D: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    symmetric_skip: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """D^T D, f32, any (m, n) — pads to block multiples (exact for Gram)."""
    m, n = D.shape
    Dp = _pad_to(_pad_to(D, block_m, 0), block_n, 1)
    G = gram_pallas(
        Dp,
        block_m=block_m,
        block_n=block_n,
        symmetric_skip=symmetric_skip,
        interpret=interpret,
    )
    if symmetric_skip:
        # Mirror the computed upper-triangular blocks. Using block-level skip,
        # every full block strictly below the diagonal is garbage; rebuild
        # from the upper triangle (element-wise: the diagonal blocks are full).
        G = _mirror_upper(G, block_n)
    return G[:n, :n]


def _mirror_upper(G: jax.Array, block_n: int) -> jax.Array:
    """Rebuild the strictly-lower blocks skipped by ``symmetric_skip``."""
    bi = jnp.arange(G.shape[0]) // block_n
    upper = bi[:, None] <= bi[None, :]             # block-upper mask
    return jnp.where(upper, G, G.T)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def gram_and_rhs(
    D: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    interpret: bool = False,
):
    """Fused (D^T D, D^T b) — ONE row stream over D, any (m, n).

    ``b`` may be (m,) — the classic lasso rhs — or (m, r) stacked
    right-hand sides (multi-probe serving); c comes back (n,) or (n, r).
    Pads rows to block_m, features to block_n and rhs lanes to 128 (zero
    rows/columns are exact for both sums); mirrors the symmetric-skip
    upper triangle like ``gram``.
    """
    m, n = D.shape
    squeeze = b.ndim == 1
    B = b[:, None] if squeeze else b
    r = B.shape[1]
    Dp = _pad_to(_pad_to(D, block_m, 0), block_n, 1)
    Bp = _pad_to(_pad_to(B.astype(jnp.float32), block_m, 0), 128, 1)
    G, C = gram_rhs_pallas(
        Dp, Bp,
        block_m=block_m,
        block_n=block_n,
        symmetric_skip=True,
        interpret=interpret,
    )
    G = _mirror_upper(G, block_n)[:n, :n]
    C = C[:n, :r]
    return G, (C[:, 0] if squeeze else C)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def gram_with_rhs(
    D: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 256,
    interpret: bool = False,
):
    """One-pass (D^T D, D^T b) by appending b as column n (paper §4 setup)."""
    m, n = D.shape
    Db = jnp.concatenate([D, b[:, None].astype(D.dtype)], axis=1)
    G = gram(
        Db,
        block_m=block_m,
        block_n=block_n,
        symmetric_skip=True,
        interpret=interpret,
    )
    return G[:n, :n], G[:n, n]
