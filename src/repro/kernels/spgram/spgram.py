"""Sparse transpose-reduction kernel bodies over padded block-CSR.

The compute shapes here are the sparse analogue of the fused dense bodies
(`kernels/admm_iter`, `kernels/gram`), with one structural inversion
dictated by measurement (DESIGN.md §10): on XLA the accumulation side of
every transpose reduction is a GATHER over the per-block local CSC, never
a scatter-add — CPU XLA scatter-add runs ~70x slower per element than
gather, which would forfeit the whole O(nnz) win. Per block:

  * ``Dx``  — gather ``x`` at the CSR column ids, multiply, row-sum
              (x is n-sized and cache-resident);
  * prox / lam-update — elementwise on the (block_m,) vectors;
  * d/w/v  — gather the block-resident u vectors (y'-lam', y'-y, lam';
              block_m-sized, L1/L2-resident) at the local-CSC row ids,
              multiply by the CSC values, column-sum → a full (n,)
              contribution per block, accumulated by addition.

Everything accumulates in f32 (f64 for f64 data) regardless of the value
residency dtype — the same precision contract as the dense kernels; the
w/v differences are formed on the block vectors BEFORE the reduction
(the dense kernels' anti-cancellation rule).

These are jnp-level XLA bodies, not Pallas: the data-dependent gathers
have no MXU mapping, and on CPU/GPU XLA already emits the fused
gather-multiply-reduce loops these shapes want. The module stays under
``kernels/`` because it is the hot-path compute the engine's ``sparse``
backend dispatches to.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib

Array = jax.Array


def block_matvec(indices: Array, values: Array, x: Array) -> Array:
    """One block's D_b @ x via CSR gather: (bm, kp) -> (bm,)."""
    acc = gram_lib._acc_dtype(values.dtype)
    return jnp.sum(values.astype(acc) * x.astype(acc)[indices], axis=-1)


def block_rmatvec(col_indices: Array, col_values: Array, u: Array) -> Array:
    """One block's D_b^T u_b via local-CSC gather: (n, kc) x (bm,) -> (n,).
    ``u`` may also be (bm, r) stacked — returns (n, r)."""
    acc = gram_lib._acc_dtype(col_values.dtype)
    g = u.astype(acc)[col_indices]                  # (n, kc[, r])
    if u.ndim == 1:
        return jnp.sum(col_values.astype(acc) * g, axis=-1)
    return jnp.einsum("nk,nkr->nr", col_values.astype(acc), g)


def block_iter_body(loss, delta, idx_b, val_b, cidx_b, cval_b,
                    aux_b: Optional[Array], y_b: Array, lam_b: Array,
                    x: Array, want_dual: bool):
    """The fused per-block iteration: gather-Dx, prox, lam-update and the
    three gather-based transpose reductions, one pass over the block's
    nonzeros. Returns (y', lam', d, w, v) with w/v None when
    ``want_dual`` is False (the lean hot-path body)."""
    Dx = block_matvec(idx_b, val_b, x)
    y_new = loss.prox(Dx + lam_b, delta, aux_b)
    lam_new = lam_b + Dx - y_new
    d = block_rmatvec(cidx_b, cval_b, y_new - lam_new)
    w = v = None
    if want_dual:
        w = block_rmatvec(cidx_b, cval_b, y_new - y_b)
        v = block_rmatvec(cidx_b, cval_b, lam_new)
    return y_new, lam_new, d, w, v


def block_gram_scatter(indices: Array, values: Array, G: Array) -> Array:
    """Fold one block's D_b^T D_b into G via per-row outer-product
    scatter — the jit-safe FALLBACK gram (exact, duplicate- and
    pad-safe: pad slots carry value 0). Orders of magnitude slower than
    the host CSR path on CPU XLA (the scatter measurement above); used
    only when scipy is unavailable or the caller needs a traced gram."""
    acc = G.dtype
    v = values.astype(acc)
    outer = v[:, :, None] * v[:, None, :]
    return G.at[indices[:, :, None], indices[:, None, :]].add(outer)


def blocked_vector(x: Array, nb: int, bm: int) -> Array:
    """(m,) -> (nb, bm) zero-padded — the iterate layout for the scan."""
    m = x.shape[0]
    pad = nb * bm - m
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x.reshape((nb, bm) + x.shape[1:])


def sparse_iterate(loss, delta, bcsr, aux: Optional[Array], y: Array,
                   lam: Array, x: Array, want_dual: bool = True
                   ) -> Tuple[Array, Array, Array, Optional[Array],
                              Optional[Array]]:
    """Full fused iteration: lax.scan of :func:`block_iter_body` over the
    static-shaped blocks, d/w/v accumulated as (n,) carries."""
    m, n = bcsr.m, bcsr.n
    nb, bm, _ = bcsr.indices.shape
    acc = gram_lib._acc_dtype(bcsr.dtype)
    xc = x.astype(acc)
    ys = blocked_vector(y, nb, bm)
    lams = blocked_vector(lam, nb, bm)
    xs = [bcsr.indices, bcsr.values, bcsr.col_indices, bcsr.col_values,
          ys, lams]
    if aux is not None:
        xs.append(blocked_vector(aux, nb, bm))

    def body(carry, blk):
        d, w, v = carry
        idx_b, val_b, cidx_b, cval_b, y_b, lam_b = blk[:6]
        a_b = blk[6] if aux is not None else None
        y_nb, l_nb, d_b, w_b, v_b = block_iter_body(
            loss, delta, idx_b, val_b, cidx_b, cval_b, a_b, y_b, lam_b,
            xc, want_dual)
        d = d + d_b
        if want_dual:
            w = w + w_b
            v = v + v_b
        return (d, w, v), (y_nb, l_nb)

    zero = jnp.zeros((n,), acc)
    (d, w, v), (ys, lams) = jax.lax.scan(body, (zero, zero, zero),
                                         tuple(xs))
    return (ys.reshape(-1)[:m], lams.reshape(-1)[:m], d,
            w if want_dual else None, v if want_dual else None)


def sparse_matvec(bcsr, x: Array) -> Array:
    """D @ x over the block scan — warm starts and telemetry."""
    m = bcsr.m
    nb, bm, _ = bcsr.indices.shape

    def body(_, blk):
        idx_b, val_b = blk
        return None, block_matvec(idx_b, val_b, x)

    _, out = jax.lax.scan(body, None, (bcsr.indices, bcsr.values))
    return out.reshape(-1)[:m]


def sparse_rmatvec(bcsr, u: Array) -> Array:
    """D^T u over the block scan; ``u`` is (m,) or (m, r)."""
    n = bcsr.n
    nb, bm, _ = bcsr.indices.shape
    us = blocked_vector(u, nb, bm)
    acc = gram_lib._acc_dtype(bcsr.dtype)
    zero = jnp.zeros((n,) + u.shape[1:], acc)

    def body(c, blk):
        cidx_b, cval_b, u_b = blk
        return c + block_rmatvec(cidx_b, cval_b, u_b), None

    c, _ = jax.lax.scan(body, zero,
                        (bcsr.col_indices, bcsr.col_values, us))
    return c
