"""Public surface of the sparse transpose-reduction kernels.

Two kinds of entry point, split by where they may run:

  * jit-safe (callable under trace): :func:`sparse_admm_iter_full` —
    the fused iteration body the engine's ``sparse`` backend dispatches
    to — and :func:`matvec` / :func:`rmatvec`.
  * HOST-ONLY: :func:`sparse_gram_rhs` — the fused Gram+RHS setup pass.
    The O(nnz * kp) Gram accumulation has no fast XLA lowering (scatter —
    see spgram.py header), so the setup pass runs on the host through
    scipy's compiled CSR matmul when available, with the jit-safe
    scatter fallback behind it. Setup is a once-per-dataset host-driven
    pass everywhere else in the repo too (the store, streaming Gram
    sweeps), so this costs no architectural novelty — but it means
    sparse solvers factor G OUTSIDE their jitted iteration loop
    (``core/unwrapped`` sparse drivers do exactly that).

The RHS rides the jit-safe CSC path (one gather pass, multi-RHS via
(m, r)), so only the n x n Gram itself touches scipy.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.kernels.spgram import spgram

try:                                    # scipy ships with jax; gate anyway
    import scipy.sparse as _scipy_sparse
except ImportError:                     # pragma: no cover - scipy bundled
    _scipy_sparse = None


def sparse_admm_iter_full(bcsr, aux, y, lam, x, *, loss, delta: float,
                          want_dual: bool = True):
    """Fused iteration body (y', lam', d, w, v) — see
    :func:`spgram.sparse_iterate`. jit-safe; the engine wraps it."""
    return spgram.sparse_iterate(loss, delta, bcsr, aux, y, lam, x,
                                 want_dual=want_dual)


# jitted at this layer so host-driven callers (setup passes, telemetry,
# launch metrics) don't run the block scan eagerly; nests fine under the
# solvers' own jit (BlockCSR is a pytree with static (m, n, nnz) aux).
@jax.jit
def matvec(bcsr, x):
    """D @ x."""
    return spgram.sparse_matvec(bcsr, x)


@jax.jit
def rmatvec(bcsr, u):
    """D^T u in accumulation precision; u is (m,) or (m, r)."""
    return spgram.sparse_rmatvec(bcsr, u)


def _gram_scipy(bcsr, acc):
    """D^T D through scipy's compiled CSR matmul — O(nnz * nnz/row).

    Pad slots (and stored zeros) are STRIPPED before the matmul: a zero
    value contributes nothing to any Gram entry, and at low density the
    padding would otherwise multiply scipy's per-entry work by
    kp / mean-row-nnz (~3x measured at 1%)."""
    nb, bm, kp = bcsr.indices.shape
    rows = nb * bm
    data = np.asarray(bcsr.values).reshape(rows, kp)
    if data.dtype not in (np.float32, np.float64):
        data = data.astype(np.float32)          # scipy has no bf16
    mask = data != 0
    counts = np.count_nonzero(mask, axis=1)
    indptr = np.concatenate([[0], np.cumsum(counts, dtype=np.int64)])
    A = _scipy_sparse.csr_matrix(
        (data[mask], np.asarray(bcsr.indices).reshape(rows, kp)[mask],
         indptr), shape=(rows, bcsr.n))
    G = (A.T @ A).toarray()
    return jnp.asarray(G, acc)


def _gram_fallback(bcsr, acc):
    """jit-safe scatter gram — correct everywhere, fast nowhere."""
    def body(G, blk):
        idx_b, val_b = blk
        return spgram.block_gram_scatter(idx_b, val_b, G), None

    G0 = jnp.zeros((bcsr.n, bcsr.n), acc)
    G, _ = jax.lax.scan(body, G0, (bcsr.indices, bcsr.values))
    return G


def sparse_gram_rhs(bcsr, b: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Fused sparse (D^T D, D^T b) setup pass. HOST-ONLY (see module
    docstring); ``b`` may be None, (m,) or (m, r)."""
    acc = gram_lib._acc_dtype(bcsr.dtype)
    if _scipy_sparse is not None:
        G = _gram_scipy(bcsr, acc)
    else:
        G = _gram_fallback(bcsr, acc)
    c = None if b is None else rmatvec(bcsr, jnp.asarray(b))
    return G, c
