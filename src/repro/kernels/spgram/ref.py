"""Reference semantics for the sparse kernels: densify, then run the
textbook dense ops. Parity baseline for tests — O(mn), never a hot path."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import gram as gram_lib


def gram_ref(bcsr):
    """D^T D via densification."""
    return gram_lib.gram(bcsr.to_dense())


def gram_rhs_ref(bcsr, b):
    """D^T b via densification."""
    return gram_lib.gram_rhs(bcsr.to_dense(), b)


def matvec_ref(bcsr, x):
    D = bcsr.to_dense()
    acc = gram_lib._acc_dtype(D.dtype)
    return D.astype(acc) @ x.astype(acc)


def admm_iter_ref(bcsr, aux, y, lam, x, *, loss, delta: float):
    """Dense two-pass iteration body on the densified matrix."""
    D = bcsr.to_dense()
    acc = gram_lib._acc_dtype(D.dtype)
    Df = D.astype(acc)
    Dx = Df @ x.astype(acc)
    y_new = loss.prox(Dx + lam, delta, aux)
    lam_new = lam + Dx - y_new
    dwv = Df.T @ jnp.stack([y_new - lam_new, y_new - y, lam_new], axis=1)
    return y_new, lam_new, dwv[:, 0], dwv[:, 1], dwv[:, 2]
