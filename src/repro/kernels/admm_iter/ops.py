"""Jitted wrapper for the fused ADMM-iteration kernel (pads rows; zero-pad
rows contribute nothing to d since their y' - lam' is forced to 0 via
aux=0/lam=0/D=0 rows: prox(0)=0 for every supported kind at z=0 with l=0)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.admm_iter.admm_iter import admm_iter_pallas


@functools.partial(
    jax.jit, static_argnames=("kind", "delta", "block_m", "interpret"))
def admm_iter(D, aux, y, lam, x, *, kind: str, delta: float,
              block_m: int = 1024, interpret: bool = False):
    m, n = D.shape
    pad = (-m) % block_m
    if pad:
        D = jnp.pad(D, ((0, pad), (0, 0)))
        aux = jnp.pad(aux, (0, pad))
        y = jnp.pad(y, (0, pad))
        lam = jnp.pad(lam, (0, pad))
    y_new, lam_new, d = admm_iter_pallas(
        D, aux, y, lam, x, kind=kind, delta=delta, block_m=block_m,
        interpret=interpret)
    return y_new[:m], lam_new[:m], d
