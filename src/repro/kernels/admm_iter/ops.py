"""Jitted wrappers for the fused ADMM-iteration kernel (pads rows; zero-pad
rows contribute nothing to d/w/v since their y', lam' and y' - y are forced
to 0 via aux=0/y=0/lam=0/D=0 rows: prox(0)=0 for every supported kind at
z=0 with l=0)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.admm_iter.admm_iter import admm_iter_pallas


@functools.partial(
    jax.jit, static_argnames=("kind", "delta", "block_m", "interpret",
                              "param"))
def admm_iter_full(D, aux, y, lam, x, *, kind: str, delta: float,
                   block_m: int = 1024, interpret: bool = False,
                   param: float = 0.0):
    """Fused iteration body returning (y', lam', d, w, v).

    d = D^T(y' - lam') feeds the next x-update (paper Alg. 2 line 6);
    w = D^T(y' - y) and v = D^T lam' feed Boyd's dual residual and
    tolerance without a second pass over D (the engine's one-pass
    telemetry — DESIGN.md §8). Differences are formed in-register before
    the reduction, so the residuals keep full f32 accuracy near
    convergence.
    """
    m, n = D.shape
    pad = (-m) % block_m
    if pad:
        D = jnp.pad(D, ((0, pad), (0, 0)))
        aux = jnp.pad(aux, (0, pad))
        y = jnp.pad(y, (0, pad))
        lam = jnp.pad(lam, (0, pad))
    y_new, lam_new, d, w, v = admm_iter_pallas(
        D, aux, y, lam, x, kind=kind, delta=delta, block_m=block_m,
        interpret=interpret, param=param)
    return y_new[:m], lam_new[:m], d, w, v


@functools.partial(
    jax.jit, static_argnames=("kind", "delta", "block_m", "interpret"))
def admm_iter(D, aux, y, lam, x, *, kind: str, delta: float,
              block_m: int = 1024, interpret: bool = False):
    """Back-compat 3-tuple surface: (y', lam', d)."""
    y_new, lam_new, d, _, _ = admm_iter_full(
        D, aux, y, lam, x, kind=kind, delta=delta, block_m=block_m,
        interpret=interpret)
    return y_new, lam_new, d
