"""Pallas TPU kernel: FUSED unwrapped-ADMM iteration (§Perf, beyond-paper).

The paper's per-iteration body touches D twice when written as separate ops
(Dx, then D^T(y-lam)) and XLA's per-operand accounting cannot merge the
reads. This kernel streams each (bm x n) row-panel of D HBM->VMEM ONCE and
does everything with it while it is resident:

    Dx_b   = D_b @ x            (MXU; x stays in VMEM, n <= ~2k)
    y_b    = prox_f(Dx_b + lam_b)   (VPU, in-register Newton/bisection)
    lam_b' = lam_b + Dx_b - y_b
    d     += D_b^T (y_b - lam_b')   (MXU; n-vector f32 VMEM accumulator)
    w     += D_b^T (y_b - y_b_old)  (Boyd dual residual, same stream)
    v     += D_b^T lam_b'           (dual tolerance, same stream)

Per-iteration HBM traffic drops from 2 x bytes(D) + small to
1 x bytes(D) + small — and with bf16 D residency (f32 in-register upcast,
like the Gram kernel) the memory-bound iteration term shrinks ~4x vs the
f32 2-pass baseline. The d/w/v accumulators live across the row grid in
output blocks (constant index_map), psum'd outside per paper Alg. 2 line 6.

Vector operands ride as (m, 1) columns; the (bm, 1) blocks are lane-padded
on TPU — acceptable since D's (bm, n) tiles dominate the traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prox.prox import _prox_body


def _kernel(x_ref, d_in_ref, y_ref, lam_ref, aux_ref, y_out_ref, lam_out_ref,
            d_out_ref, w_out_ref, v_out_ref, *, kind: str, delta: float,
            param: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        w_out_ref[...] = jnp.zeros_like(w_out_ref)
        v_out_ref[...] = jnp.zeros_like(v_out_ref)

    Db = d_in_ref[...].astype(jnp.float32)          # (bm, n)
    x = x_ref[...].astype(jnp.float32)              # (1, n)
    y_old = y_ref[...].astype(jnp.float32)          # (bm, 1)
    lam = lam_ref[...].astype(jnp.float32)          # (bm, 1)
    aux = aux_ref[...].astype(jnp.float32)
    Dx = jax.lax.dot_general(
        Db, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (bm, 1)
    z = Dx + lam
    y = _prox_body(kind, z, delta, aux, newton_iters=3, param=param)
    lam_new = lam + Dx - y
    y_out_ref[...] = y
    lam_out_ref[...] = lam_new

    def _tdot(col):
        # col^T @ D_b -> one (1, n) accumulator row on the MXU
        return jax.lax.dot_general(
            col, Db, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # Three transpose reductions in the SAME row stream (the tiles of D are
    # already VMEM-resident; each extra (1, n) dot is noise next to the
    # panel's HBM traffic):
    #   d = D^T(y' - lam')  — next x-update RHS (Alg. 2 line 6)
    #   w = D^T(y' - y)     — Boyd dual residual s = tau ||w||; the y-space
    #                         difference is taken in-register BEFORE the
    #                         reduction, avoiding the catastrophic
    #                         cancellation of differencing two accumulated
    #                         D^T y vectors across iterations
    #   v = D^T lam'        — dual tolerance eps_dual needs tau ||v||
    d_out_ref[...] += _tdot(y - lam_new)
    w_out_ref[...] += _tdot(y - y_old)
    v_out_ref[...] += _tdot(lam_new)


def admm_iter_pallas(D, aux, y, lam, x, *, kind: str, delta: float,
                     block_m: int = 1024, interpret: bool = False,
                     param: float = 0.0):
    """D: (m, n); aux/y/lam: (m,); x: (n,). m % block_m == 0 (ops pads).
    Returns (y', lam', d, w, v) with d = D^T(y'-lam'), w = D^T(y'-y) and
    v = D^T lam' accumulated in f32 in the same row stream."""
    m, n = D.shape
    assert m % block_m == 0
    grid = (m // block_m,)
    col = lambda v: v.reshape(m, 1)
    kernel = functools.partial(_kernel, kind=kind, delta=float(delta),
                               param=float(param))
    y_new, lam_new, d, w, v = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # x (replicated)
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),    # D row panel
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),    # y
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),    # lam
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),    # aux
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),    # y'
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),    # lam'
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # d (accumulated)
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # w (accumulated)
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # v (accumulated)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(1, n), D, col(y), col(lam), col(aux))
    return (y_new.reshape(m), lam_new.reshape(m), d.reshape(n),
            w.reshape(n), v.reshape(n))
