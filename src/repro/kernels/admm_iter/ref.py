"""Pure-jnp oracle for the fused ADMM iteration kernel."""
import jax.numpy as jnp

from repro.kernels.prox.ref import _prox


def admm_iter_ref(D, aux, y, lam, x, *, kind: str, delta: float):
    """One unwrapped-ADMM iteration body (paper Alg. 2 lines 5-8, local
    part), given the incoming solve result x:
        Dx   = D @ x
        y'   = prox_f(Dx + lam, delta)
        lam' = lam + Dx - y'
        d    = D^T (y' - lam')        (this node's reduction contribution)
    Returns (y', lam', d). f32 math regardless of D's dtype.
    """
    Df = D.astype(jnp.float32)
    Dx = Df @ x.astype(jnp.float32)
    z = Dx + lam
    y_new = _prox(kind, z, jnp.float32(delta), aux)
    lam_new = lam + Dx - y_new
    d = Df.T @ (y_new - lam_new)
    return y_new, lam_new, d
