"""Jitted public wrapper for flash attention with an XLA (chunked online-
softmax) fallback used on non-TPU backends and in the dry-run path (Pallas
custom-calls do not lower on the CPU host backend; the chunked fallback has
the same O(S) memory profile so compile-time memory analysis stays honest)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "impl")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    impl: str = "xla",          # "pallas" | "pallas_interpret" | "xla"
) -> jax.Array:
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=False,
        )
    if impl == "pallas_interpret":
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=True,
        )
    return chunked_attention_xla(
        q, k, v, causal=causal, scale=scale, chunk_q=block_q
    )


def chunked_attention_xla(q, k, v, *, causal, scale=None, chunk_q=512,
                          window: int = 0, unroll: bool = False):
    """Query-chunked online-softmax attention in pure lax — O(Sq/ck * Sk)
    peak score memory instead of O(Sq*Sk). GQA by head grouping.
    window > 0 adds a local band: q attends to k in (q_pos-window, q_pos]."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if Sq % chunk_q:
        chunk_q = Sq  # degenerate small case
    nq = Sq // chunk_q
    qf = q.reshape(B, Hkv, rep, nq, chunk_q, D)

    def per_chunk(iq, qc):
        # qc: (B, Hkv, rep, cq, D)
        s = jnp.einsum(
            "bhrqd,bhkd->bhrqk", qc.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        if causal or window:
            q_pos = iq * chunk_q + jnp.arange(chunk_q)
            k_pos = jnp.arange(Skv)
            mask = jnp.ones((chunk_q, Skv), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bhrqk,bhkd->bhrqd", p, v.astype(jnp.float32))
        return o / jnp.sum(p, axis=-1, keepdims=True)

    if unroll:
        # Cost-extraction mode: python loop so cost_analysis sees every chunk.
        chunks = [per_chunk(i, qf[:, :, :, i]) for i in range(nq)]
        out = jnp.stack(chunks, axis=0)
    else:
        out = jax.lax.map(
            lambda args: per_chunk(*args),
            (jnp.arange(nq), jnp.moveaxis(qf, 3, 0)),
        )  # (nq, B, Hkv, rep, cq, D)
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hq, Sq, D)
    return out.astype(q.dtype)
