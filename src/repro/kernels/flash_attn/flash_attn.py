"""Pallas TPU flash attention (forward), causal + GQA — the transformer
prefill/train hot-spot for the 8 attention-based assigned architectures.

TPU-native points (DESIGN.md §7):
  * Grid (B, Hq, Sq/bq, Skv/bk), kv innermost — TPU grids run sequentially
    (last dim fastest), so the f32 running statistics (m, l) and the output
    accumulator persist in VMEM scratch across the kv sweep and are
    re-initialized when ik == 0. One q tile stays VMEM-resident per sweep.
  * Causality is exploited at BLOCK granularity: kv blocks strictly above
    the diagonal are skipped (no MXU work, loads dead) — ~2x FLOP cut; only
    diagonal-straddling blocks pay the elementwise iota mask.
  * GQA is an index-map fact (kv head = q head // group), not a materialized
    jnp.repeat: kv tiles are fetched once per q-head group position.
  * All softmax statistics in f32 regardless of I/O dtype (bf16-safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal block skip: process only blocks with k_start <= q_end.
    q_end = (iq + 1) * block_q - 1
    k_start = ik * block_k
    should_run = (k_start <= q_end) if causal else True

    @pl.when(should_run)
    def _run():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (bq, bk)
        if causal:
            # Mask only on diagonal-straddling blocks.
            q_pos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[:, :1] = m_new
        l_scr[:, :1] = l_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D). Sq % bq == Skv % bk == 0."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0 and Sq % block_q == 0 and Skv % block_k == 0
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=float(scale), causal=causal,
        block_q=block_q, block_k=block_k,
    )
    grid = (B, Hq, Sq // block_q, Skv // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
