"""Pure-jnp oracle for causal/GQA flash attention."""
import jax.numpy as jnp


def mha_ref(q, k, v, *, causal: bool, scale: float | None = None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); GQA via head repeat.

    f32 softmax math; returns (B, Hq, Sq, D) in q.dtype.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        Skv = k.shape[2]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
