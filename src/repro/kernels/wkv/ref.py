"""Pure-jnp oracle for the WKV kernel: the exact per-step recurrence."""
import jax.numpy as jnp


def wkv_ref(r, k, v, w_log, u):
    """r/k/v/w_log: (T, hd) single head; u: (hd,). Per-step form:
        y_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (T, hd), S_final (hd, hd)). f32."""
    T, hd = r.shape
    S = jnp.zeros((hd, hd), jnp.float32)
    ys = []
    w = jnp.exp(w_log.astype(jnp.float32))
    for t in range(T):
        kv = jnp.outer(k[t], v[t])
        ys.append(r[t] @ (S + u[:, None] * kv))
        S = w[t][:, None] * S + kv
    return jnp.stack(ys), S
