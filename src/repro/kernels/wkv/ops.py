"""Jitted wrapper for the WKV kernel (clamps log-decay like the model)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv.wkv import wkv_pallas

WKV_LOG_CLAMP = -5.0   # keep in sync with repro.models.rwkv6


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w_log, u, *, chunk: int = 16, interpret: bool = False):
    w_log = jnp.maximum(w_log.astype(jnp.float32), WKV_LOG_CLAMP)
    return wkv_pallas(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), w_log, u.astype(jnp.float32),
                      chunk=chunk, interpret=interpret)
