"""Pallas TPU kernel: RWKV-6 WKV with VMEM-resident state (§Perf rwkv cell).

The XLA chunked form (models/rwkv6.py) must round-trip the (hd x hd)
per-head state S through HBM on every chunk-scan step — at chunk 16 that is
T/16 state read+writes per head per layer, the dominant term of the rwkv
train cell's memory-bound roofline. Here S lives in a VMEM scratch across
the whole sequence sweep:

  grid (B, H, T/Lc), chunk index innermost (TPU grids run sequentially, so
  the scratch persists across the chunk sweep and re-initializes at c == 0).
  Per chunk: the separable-decay intra matmul pair (same math as
  rwkv6._wkv_chunked_matmul, log-decay pre-clamped by the caller), the
  state contribution r~ @ S, and the in-place state update
  S <- diag(e^{cum_Lc}) S + kk^T v — all MXU work on (Lc, hd) tiles.

HBM traffic per layer: read r/k/v/w once + write y once — the state never
leaves VMEM. Projected memory term for the rwkv6-1.6b train cell:
~2.6 s vs 14.6 s XLA-form (EXPERIMENTS.md §Perf R2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    rc = r_ref[0, 0].astype(jnp.float32)          # (Lc, hd)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)
    wc = w_ref[0, 0].astype(jnp.float32)          # log-decay, <= 0 (clamped)
    u = u_ref[0].astype(jnp.float32)              # (hd,)

    cum = jnp.cumsum(wc, axis=0)
    cum_prev = cum - wc
    r_t = rc * jnp.exp(cum_prev)
    k_t = kc * jnp.exp(-cum)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    A = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * mask
    diag = jnp.sum(rc * u[None, :] * kc, axis=-1)
    y = jax.lax.dot_general(
        A, vc, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + diag[:, None] * vc
    y = y + jax.lax.dot_general(
        r_t, s_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update (stays in VMEM)
    kk = kc * jnp.exp(cum[-1][None, :] - cum)
    s_scr[...] = jnp.exp(cum[-1])[:, None] * s_scr[...] + jax.lax.dot_general(
        kk, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def wkv_pallas(r, k, v, w_log, u, *, chunk: int = 16,
               interpret: bool = False):
    """r/k/v/w_log: (B, H, T, hd); u: (H, hd). T % chunk == 0.
    Returns y: (B, H, T, hd) f32. Log-decay must be pre-clamped (the model
    applies WKV_LOG_CLAMP) so exp factors stay in f32 range."""
    B, H, T, hd = r.shape
    assert T % chunk == 0
    grid = (B, H, T // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    blk = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, blk, blk, blk,
                  pl.BlockSpec((1, hd), lambda b, h, c: (h, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u)
