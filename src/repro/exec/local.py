"""LocalExecutor — device-resident data, the paper's single-node Alg. 1.

Replaces the two hand-rolled ``lax.while_loop`` drivers that used to
live in ``core/unwrapped.py`` (``_solve_dense`` / ``_solve_sparse``):
one jitted fused step per iteration, the loop itself in the shared
driver. Accepts node-stacked dense (N, m_i, n) arrays or a flat
:class:`~repro.data.sparse.BlockCSR`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.data.sparse import BlockCSR
from repro.engine.streaming import SweepResult
from repro.exec.base import SolveExecutor

Array = jax.Array


class LocalExecutor(SolveExecutor):
    name = "local"
    checkpoint_kind = "local_solve"
    kind_label = "local"

    def __init__(self, engine, D, aux: Optional[Array] = None,
                 gram_block_rows: Optional[int] = None):
        self.engine = engine
        self.sparse = isinstance(D, BlockCSR)
        if self.sparse:
            self.m, self.n = D.m, D.n
            self._stack = None               # y comes back as (1, m)
            self._Dflat = D
        else:
            N, mi, n = D.shape
            self.m, self.n = N * mi, n
            self._stack = (N, mi)
            self._Dflat = D.reshape(self.m, n)
        self.acc = gram_lib._acc_dtype(D.dtype)
        self.ycols = getattr(engine.loss, "ycols", 1)
        self.backend = "sparse" if self.sparse else engine.resolve(D.dtype)
        self._aux = aux.reshape(self.m) if aux is not None else None
        self._gbr = gram_block_rows
        self._Dres = None
        self._y = None
        self._lam = None
        self._step = _fused_step(engine)

    def _yshape(self):
        return (self.m,) if self.ycols == 1 else (self.m, self.ycols)

    def setup(self, obs) -> Array:
        G, _ = self.engine.gram(self._Dflat, block_rows=self._gbr)
        self._Dres = self.engine.prepare(self._Dflat)
        return G

    def init(self, x0: Optional[Array]) -> Array:
        if x0 is None:
            self._y = jnp.zeros(self._yshape(), self.acc)
            self._lam = jnp.zeros(self._yshape(), self.acc)
            return self.zero_x()
        # warm start: y = D x0, lam = 0, d = D^T(y - lam) — one extra
        # setup-time pass (same semantics the jitted drivers had)
        x0 = jnp.asarray(x0)
        if self.sparse:
            from repro.kernels.spgram import ops as spgram_ops
            y = spgram_ops.matvec(self._Dflat, x0.astype(self.acc))
        else:
            y = self._Dflat.astype(self.acc) @ x0.astype(self.acc)
        self._y = y
        self._lam = jnp.zeros_like(y)
        return self.engine.transpose_d(self._Dflat, y, self._lam)

    def sweep(self, x: Array, k: int) -> SweepResult:
        self._y, self._lam, sw = self._step(
            self._Dres, self._aux, self._y, self._lam, x)
        return sw

    # -- checkpointing (driver-owned cadence) -------------------------------
    def state_arrays(self, k: int) -> dict:
        return {"y": self._y, "lam": self._lam}

    def restore_state(self, k: int, tree: dict) -> Array:
        self._y = jnp.asarray(tree["y"], self.acc)
        self._lam = jnp.asarray(tree["lam"], self.acc)
        return tree["d"]

    def final_iterates(self):
        if self._stack is None:
            return self._y[None], self._lam[None]
        N, mi = self._stack
        shape = (N, mi) + tuple(self._y.shape[1:])
        return self._y.reshape(shape), self._lam.reshape(shape)


def _fused_step(engine):
    """Jitted ``(D, aux, y, lam, x) -> (y', lam', SweepResult)``: the
    engine's fused body plus the stopping-rule scalars in one dispatch.
    Shared across LocalExecutor instances of the same engine config via
    jit's own cache (the engine is a frozen dataclass)."""
    loss = engine.loss

    @jax.jit
    def step(D, aux, y, lam, x):
        st = engine.iterate(D, aux, y, lam, x, want_dual=True)
        Dx = st.lam - lam + st.y
        sw = SweepResult(
            st.d, st.w, st.v,
            jnp.sum((st.lam - lam) ** 2), jnp.sum(Dx * Dx),
            jnp.sum(st.y * st.y), loss.value(Dx, aux))
        return st.y, st.lam, sw

    return step
