"""Problems-on-executors: register a problem ONCE, run it anywhere.

A problem here is (picklable loss spec, tau, rho, optional x-space
regularizer factory) — nothing topology-specific. ``fit_on_executor``
builds the right :class:`~repro.exec.base.SolveExecutor` for the
requested topology and hands everything to the one shared driver, so a
newly registered loss is immediately runnable on local, streaming,
shard_map AND the multi-process cluster with zero per-topology code
(the backend-parity suite asserts exactly that).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.exec.base import (
    Regularizer,
    SolveExecutor,
    make_group_lasso_reg,
    solve_with_executor,
)

EXECUTORS = ("local", "streaming", "shard_map", "cluster")


@dataclasses.dataclass(frozen=True)
class ExecProblem:
    """One solvable problem, topology-free. ``loss_spec`` must be
    picklable (it ships to cluster worker processes and into checkpoint
    extras); ``reg_factory(n)`` builds the x-space penalty — applied by
    the DRIVER's composite x-update, so workers never see it."""

    name: str
    loss_spec: dict
    tau: float = 1.0
    rho: float = 0.0
    reg_factory: Optional[Callable[[int], Regularizer]] = None

    def loss(self):
        from repro.core.prox import loss_from_spec
        return loss_from_spec(self.loss_spec)

    def reg(self, n: int) -> Optional[Regularizer]:
        return self.reg_factory(n) if self.reg_factory else None


def _group_lasso_factory(mu: float, group_size: int):
    def make(n: int) -> Regularizer:
        groups = np.arange(n) // group_size
        return make_group_lasso_reg(mu, groups, int(groups[-1]) + 1)

    return make


def make_problem(name: str, **params) -> ExecProblem:
    """The problem table — one line per problem, every executor."""
    if name == "logistic":
        return ExecProblem("logistic", {"name": "logistic"},
                           tau=params.get("tau", 0.1))
    if name == "svm":
        return ExecProblem(
            "svm", {"name": "hinge", "C": float(params.get("C", 1.0))},
            tau=params.get("tau", 0.5), rho=float(params.get("rho", 1.0)))
    if name == "least_squares":
        return ExecProblem("least_squares", {"name": "least_squares"},
                           tau=params.get("tau", 1.0))
    if name == "quantile":
        return ExecProblem(
            "quantile",
            {"name": "quantile", "q": float(params.get("q", 0.5))},
            tau=params.get("tau", 1.0))
    if name == "group_lasso":
        return ExecProblem(
            "group_lasso", {"name": "least_squares"},
            tau=params.get("tau", 1.0),
            reg_factory=_group_lasso_factory(
                float(params.get("mu", 0.1)),
                int(params.get("group_size", 4))))
    if name == "multinomial":
        return ExecProblem(
            "multinomial",
            {"name": "multinomial",
             "classes": int(params.get("classes", 3))},
            tau=params.get("tau", 0.5))
    raise ValueError(f"unknown executor problem {name!r}; "
                     f"known: logistic, svm, least_squares, quantile, "
                     f"group_lasso, multinomial")


def make_executor(kind: str, prob: ExecProblem, D, aux=None,
                  backend: str = "auto", **opts) -> SolveExecutor:
    """Build the executor for one topology over in-memory (m, n) data.
    ``cluster`` is NOT built here — it owns worker processes and goes
    through :class:`repro.cluster.coordinator.ClusterCoordinator`."""
    from repro.engine import IterationEngine
    engine = IterationEngine(loss=prob.loss(), tau=prob.tau,
                             backend=backend)
    D = np.asarray(D)
    D2 = D.reshape(-1, D.shape[-1])
    if kind == "local":
        from repro.exec.local import LocalExecutor
        return LocalExecutor(engine, D2[None],
                             aux=None if aux is None else np.asarray(aux))
    if kind == "streaming":
        from repro.data.store import ShardedMatrixStore
        from repro.exec.streaming import StreamingExecutor
        store = opts.get("store")
        if store is None:
            aux_a = None if aux is None else np.asarray(aux)
            br = opts.get("block_rows")
            store = (ShardedMatrixStore.from_arrays(D2, aux_a) if br is None
                     else ShardedMatrixStore.from_arrays(D2, aux_a,
                                                         block_rows=br))
        return StreamingExecutor(engine, store)
    if kind == "shard_map":
        from repro.exec.shard_map import ShardMapExecutor
        return ShardMapExecutor(
            engine, D2, aux=None if aux is None else np.asarray(aux),
            mesh=opts.get("mesh"),
            compress=bool(opts.get("compress", False)))
    raise ValueError(f"unknown executor kind {kind!r}; "
                     f"expected one of {EXECUTORS}")


def fit_on_executor(prob: ExecProblem, executor: str, D, aux=None, *,
                    x0=None, max_iters: int = 300, record: bool = False,
                    eps_rel: float = 1e-3, eps_abs: float = 1e-6,
                    checkpoint_dir: Optional[str] = None,
                    checkpoint_every: int = 0, resume: bool = False,
                    n_workers: int = 2, store_dir: Optional[str] = None,
                    cluster_config=None, obs=None, **opts):
    """Solve ``prob`` over ``D``/``aux`` on the named executor. Returns
    an :class:`~repro.core.unwrapped.ADMMResult` (local / streaming /
    shard_map) or a :class:`~repro.cluster.coordinator.ClusterResult`
    (cluster) — both carry ``.x`` and ``.iters``."""
    n = int(np.asarray(D).shape[-1])
    reg = prob.reg(n)
    if executor == "cluster":
        import dataclasses as _dc

        from repro.cluster.coordinator import ClusterConfig, cluster_solve
        cfg = cluster_config or ClusterConfig(n_workers=n_workers)
        if checkpoint_dir is not None:
            cfg = _dc.replace(cfg, checkpoint_dir=checkpoint_dir,
                              checkpoint_every=checkpoint_every,
                              resume=resume)
        D2 = np.asarray(D).reshape(-1, n)
        return cluster_solve(
            D2, None if aux is None else np.asarray(aux),
            loss=prob.loss_spec, tau=prob.tau, rho=prob.rho,
            max_iters=max_iters, store_dir=store_dir, config=cfg,
            eps_rel=eps_rel, eps_abs=eps_abs, record=record,
            x0=x0, reg=reg)
    ex = make_executor(executor, prob, D, aux, **opts)
    return solve_with_executor(
        ex, loss=prob.loss(), tau=prob.tau, rho=prob.rho,
        eps_rel=eps_rel, eps_abs=eps_abs, max_iters=max_iters, x0=x0,
        record=record, reg=reg, checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every, resume=resume, obs=obs)


def synth_data(prob: ExecProblem, m: int = 96, n: int = 12,
               seed: int = 0):
    """Deterministic synthetic (D, aux) matched to the problem's aux
    contract — labels in {-1, +1} (logistic / svm), targets b
    (least-squares family), integer class ids (multinomial)."""
    rng = np.random.default_rng(seed)
    D = (rng.standard_normal((m, n)) / np.sqrt(n)).astype(np.float32)
    x_true = rng.standard_normal((n,)).astype(np.float32)
    z = D @ x_true
    name = prob.loss_spec["name"]
    if name in ("logistic", "hinge"):
        aux = np.sign(z + 0.1 * rng.standard_normal(m)).astype(np.float32)
        aux[aux == 0] = 1.0
        # flip 15% of labels: separable data has NO finite logistic
        # minimizer (x diverges, ADMM never converges) — noise keeps the
        # optimum finite so every executor reaches the same fixed point
        flip = rng.random(m) < 0.15
        aux[flip] = -aux[flip]
        return D, aux
    if name == "multinomial":
        K = int(prob.loss_spec["classes"])
        W = rng.standard_normal((n, K)).astype(np.float32)
        aux = np.argmax(D @ W + 0.1 * rng.standard_normal((m, K)),
                        axis=1).astype(np.float32)
        flip = rng.random(m) < 0.15
        aux[flip] = np.floor(rng.random(flip.sum()) * K).astype(np.float32)
        return D, aux
    # least-squares family (quantile / group_lasso / least_squares)
    aux = (z + 0.1 * rng.standard_normal(m)).astype(np.float32)
    return D, aux
