"""StreamingExecutor — out-of-core data behind a ShardedMatrixStore.

The loop that used to be ``engine.streaming.solve_streaming`` reduced to
its three primitives: Gram over the store blocks, warm-start init, and
the double-buffered fused sweep — everything else (stopping rule,
checkpoint cadence, telemetry) is the shared driver's. The checkpoint is
bound to the store's content fingerprint, restored BITWISE-compatibly
(the restored state is exactly the live state, so the remaining
iterations replay the identical op sequence).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.data.store import ShardedMatrixStore
from repro.engine.streaming import StreamingEngine, SweepResult
from repro.exec.base import SolveExecutor

Array = jax.Array


class StreamingExecutor(SolveExecutor):
    name = "streaming"
    checkpoint_kind = "streaming_solve"
    kind_label = "streaming"

    def __init__(self, engine, store: ShardedMatrixStore,
                 overlap: bool = True, prefetch: int = 2,
                 device_dtype: Optional[str] = None):
        self.engine = engine
        self.store = store
        self.m, self.n = store.m, store.n
        self.ycols = getattr(engine.loss, "ycols", 1)
        self.backend = engine.resolve(
            jnp.dtype(device_dtype or store.dtype.name))
        self.overlap = overlap
        self.seng = StreamingEngine(engine=engine,
                                    prefetch=prefetch if overlap else 0,
                                    device_dtype=device_dtype)
        self.acc = gram_lib._acc_dtype(self.seng.residency_dtype(store))
        shape = ((self.m,) if self.ycols == 1
                 else (self.m, self.ycols))
        self._y = np.zeros(shape, jnp.dtype(self.acc).name)
        self._lam = np.zeros(shape, jnp.dtype(self.acc).name)

    def setup(self, obs) -> Array:
        return self.seng.gram_from_store(self.store)

    def init(self, x0: Optional[Array]) -> Array:
        if x0 is None:
            return self.zero_x()
        return self.seng.init_from_x0(
            self.store, jnp.asarray(x0, self.acc), self._y)

    def sweep(self, x: Array, k: int) -> SweepResult:
        return self.seng.sweep(self.store, x, self._y, self._lam,
                               overlap=self.overlap)

    def pad_objective(self) -> float:
        return self.seng.pad_objective(self.store)

    # -- checkpointing ------------------------------------------------------
    def checkpoint_extra(self) -> dict:
        return {"store_fingerprint": self.store.fingerprint}

    def verify_checkpoint(self, extra: dict):
        if extra.get("store_fingerprint") != self.store.fingerprint:
            raise ValueError(
                "checkpoint was written against a different store "
                "(content fingerprint mismatch)")

    def restore_state(self, k: int, tree: dict) -> Array:
        self._y[:] = np.asarray(tree["y"])
        self._lam[:] = np.asarray(tree["lam"])
        return tree["d"]

    def state_arrays(self, k: int) -> dict:
        return {"y": jnp.asarray(self._y), "lam": jnp.asarray(self._lam)}

    def final_iterates(self):
        return jnp.asarray(self._y)[None], jnp.asarray(self._lam)[None]
