"""ClusterExecutor — worker processes behind the fault-tolerant runtime.

Reduces ``ClusterCoordinator.solve``'s hand-rolled loop to the three
executor primitives: the setup stats-reduce, the warm-start base-state
shipment, and one broadcast-collect round per sweep (joins, chaos,
recovery and degradation all live INSIDE the sweep — the driver only
sees a SweepResult or None). Everything coordinator-flavored that the
other topologies also need (stopping rule, checkpoint cadence, history)
moved to the shared driver.

Wire format note: Contributions carry strictly flat f32 n-vectors. For
multi-column iterates (multinomial, ycols=K) the workers ravel their
(n, K) reductions to (n*K,) and this executor folds them back — the
tree reduce, int8 compression and row accounting never learn about K.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.streaming import SweepResult
from repro.exec.base import SolveExecutor


class ClusterExecutor(SolveExecutor):
    name = "cluster"
    checkpoint_kind = "cluster_solve"
    kind_label = "cluster"
    restore_fallback = True              # a relaunched coordinator must
    # survive one corrupt newest step when an older intact one exists

    def __init__(self, coord):
        from repro.cluster.coordinator import ClusterError
        self.error_cls = ClusterError
        self.coord = coord
        self.m, self.n = coord.store.m, coord.store.n
        self.ycols = getattr(coord.loss, "ycols", 1)
        self.backend = coord.cfg.backend
        self.converged = False
        self._prev_wire = (coord.counter.snapshot()
                           if coord.obs.enabled else None)
        self._wire_delta = {}
        if coord.cfg.staleness > 0:
            coord._latest = {}

    def _mat(self, flat: np.ndarray) -> np.ndarray:
        v = np.asarray(flat, np.float32)
        return v if self.ycols == 1 else v.reshape(self.n, self.ycols)

    def setup(self, obs) -> jnp.ndarray:
        with self.coord.obs.span("stats_reduce"):
            return self.coord.stats().G

    def init(self, x0) -> jnp.ndarray:
        if x0 is None:
            return self.zero_x()
        # warm start = a zero-length resume: ship (y=Dx0, lam=0) as the
        # recovery base at iteration 0, force-overwriting worker state.
        # One streaming pass over the coordinator's own store replica
        # computes the base — n-vectors aside, nothing crosses the wire
        # that a checkpoint restore wouldn't.
        from repro.engine import IterationEngine
        from repro.engine.streaming import StreamingEngine
        coord = self.coord
        eng = StreamingEngine(engine=IterationEngine(
            loss=coord.loss, tau=coord.tau, backend="reference"))
        shape = ((self.m,) if self.ycols == 1 else (self.m, self.ycols))
        y = np.zeros(shape, np.float32)
        d = eng.init_from_x0(coord.store, jnp.asarray(x0, jnp.float32), y)
        coord._base_iter = 0
        coord._base_y = y
        coord._base_lam = np.zeros(shape, np.float32)
        coord._x_hist = []
        for w in coord.members.alive():
            coord._send_assign(w.wid, sorted(w.blocks), upto_iter=0,
                               force=True)
        return d

    def sweep(self, x, k: int) -> Optional[SweepResult]:
        coord = self.coord
        # membership grows only at iteration boundaries: spawn any
        # chaos-scheduled joiners, then fold completed registrations in
        # (rebalance + epoch bump) before broadcasting k
        coord._spawn_due_joins(k)
        coord._apply_joins()
        if coord._coord_injector is not None:
            coord._coord_injector.set_iteration(k)
        x_np = np.asarray(x, np.float32)
        assert len(coord._x_hist) == k - 1 - coord._base_iter
        coord._x_hist.append(x_np)
        coord._broadcast_iter(k, x_np)
        with coord.obs.span("collect", k=k):
            total = (coord._collect_stale(k) if coord.cfg.staleness > 0
                     else coord._collect_strict(k, x_np))
        if total is None:
            # DegradePolicy exhausted: stop with the best-so-far x (the
            # newest broadcast) instead of hanging forever
            coord._status = "degraded"
            self.status = "degraded"
            return None
        coord._close_recovery(k)
        if coord.obs.enabled:
            wire = coord.counter.snapshot()
            prev = self._prev_wire
            tx = {t: v - prev["sent_bytes"].get(t, 0)
                  for t, v in wire["sent_bytes"].items()}
            rx = {t: v - prev["received_bytes"].get(t, 0)
                  for t, v in wire["received_bytes"].items()}
            self._prev_wire = wire
            self._wire_delta = {
                "tx_bytes": {t: v for t, v in tx.items() if v},
                "rx_bytes": {t: v for t, v in rx.items() if v}}
        sc = total.scalars
        return SweepResult(
            jnp.asarray(self._mat(total.d)),
            jnp.asarray(self._mat(total.w)),
            jnp.asarray(self._mat(total.v)),
            jnp.asarray(sc["r_sq"]), jnp.asarray(sc["dx_sq"]),
            jnp.asarray(sc["y_sq"]), jnp.asarray(sc["obj"]))

    def pad_objective(self) -> float:
        return self.coord._pad_objective()

    def extra_record(self) -> dict:
        return dict(self._wire_delta)

    def finish(self, iters: int, converged: bool):
        self.converged = converged
        coord = self.coord
        coord._iters_run += iters - self.resume_iter
        if coord._status != "degraded":
            coord._status = "converged" if converged else "max_iters"

    # -- checkpointing ------------------------------------------------------
    def checkpoint_extra(self) -> dict:
        coord = self.coord
        return {"loss": coord.loss_spec, "tau": coord.tau,
                "rho": coord.rho,
                "store_fingerprint": coord.store.fingerprint}

    def verify_checkpoint(self, extra: dict):
        if extra.get("store_fingerprint") != self.coord.store.fingerprint:
            raise self.error_cls("checkpoint belongs to a different store")

    def state_arrays(self, k: int) -> Optional[dict]:
        got = self.coord._gather_iterates(k)
        if got is None:
            return None                  # membership raced; next interval
        y, lam = got
        return {"y": y, "lam": lam}

    def on_checkpointed(self, k: int, state: dict):
        # the checkpoint is also the new recovery base: replays start
        # here, and the x-history before it can be dropped
        coord = self.coord
        coord._base_iter = k
        coord._base_y = np.asarray(state["y"], np.float32)
        coord._base_lam = np.asarray(state["lam"], np.float32)
        coord._x_hist = []

    def restore_state(self, k: int, tree: dict) -> np.ndarray:
        coord = self.coord
        coord._base_iter = k
        coord._base_y = np.asarray(tree["y"], np.float32)
        coord._base_lam = np.asarray(tree["lam"], np.float32)
        coord._x_hist = []
        for w in coord.members.alive():
            coord._send_assign(w.wid, sorted(w.blocks), upto_iter=k,
                               force=True)
        return np.asarray(tree["d"], np.float32)

    def final_iterates(self):
        # the coordinator never holds full (y, lam); gathering them for
        # the result would cost a round — expose the empty node-stacked
        # convention instead (ClusterResult never carried them either)
        shape = ((0, self.m) if self.ycols == 1
                 else (0, self.m, self.ycols))
        return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)
