"""ShardMapExecutor — paper Alg. 2 across a device mesh, driver-paced.

The same per-shard fused body ``DistributedUnwrappedADMM.build`` runs
inside its fixed-iteration ``lax.scan``, but exposed as the three
executor primitives so the SHARED driver owns the stopping rule, warm
starts and checkpointing — capabilities the scan-based path never had.
Rows are zero-padded host-side to a shard multiple (exact: zero rows
contribute nothing to any reduction); y/lam live on-device as sharded
arrays between sweeps, and only n-sized reductions (one psum per
quantity, optionally int8 error-feedback compressed for d) come back
replicated.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import gram as gram_lib
from repro.core.distributed import compressed_psum, shard_rows
from repro.engine.streaming import SweepResult
from repro.exec.base import SolveExecutor
from repro.sharding.compat import shard_map

Array = jax.Array


def default_mesh(axes: Tuple[str, ...] = ("data",)) -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape((len(devs),) + (1,) * (len(axes) - 1)), axes)


class ShardMapExecutor(SolveExecutor):
    name = "shard_map"
    checkpoint_kind = "shard_map_solve"
    kind_label = "shard_map"

    def __init__(self, engine, D, aux: Optional[Array] = None,
                 mesh: Optional[Mesh] = None,
                 data_axes: Tuple[str, ...] = ("data",),
                 compress: bool = False):
        self.engine = engine
        self.axes = tuple(data_axes)
        self.mesh = mesh if mesh is not None else default_mesh(self.axes)
        D = np.asarray(D)
        if D.ndim == 3:                    # node-stacked convention
            D = D.reshape(-1, D.shape[-1])
        self.m, self.n = D.shape
        self.ycols = getattr(engine.loss, "ycols", 1)
        self.acc = gram_lib._acc_dtype(D.dtype)
        self.backend = engine.resolve(D.dtype)
        # int8 EF compression quantizes flat n-vectors; matrix-valued d
        # (multinomial) falls back to the plain psum
        self.compress = bool(compress) and self.ycols == 1
        nshards = 1
        for a in self.axes:
            nshards *= self.mesh.shape[a]
        self.nshards = nshards
        self.pad = -(-self.m // nshards) * nshards - self.m
        Dp = np.pad(D, ((0, self.pad), (0, 0)))
        self._D = shard_rows(self.mesh, Dp, self.axes)
        if aux is not None:
            aux = np.asarray(aux).reshape(self.m)
            self._aux = shard_rows(self.mesh, np.pad(aux, (0, self.pad)),
                                   self.axes)
        else:
            self._aux = None
        self.has_aux = aux is not None
        self._y = None
        self._lam = None
        self._err = None
        self._fns = _shard_fns(engine, self.axes, self.mesh,
                               self.has_aux, self.compress)

    def _yshape(self):
        mp = self.m + self.pad
        return (mp,) if self.ycols == 1 else (mp, self.ycols)

    def _place_iterate(self, host: np.ndarray) -> Array:
        return shard_rows(self.mesh, host, self.axes)

    def setup(self, obs) -> Array:
        gram_fn, _, _ = self._fns
        return gram_fn(self._D)

    def init(self, x0: Optional[Array]) -> Array:
        shape = self._yshape()
        if x0 is None:
            self._y = self._place_iterate(
                np.zeros(shape, jnp.dtype(self.acc).name))
            self._lam = self._place_iterate(
                np.zeros(shape, jnp.dtype(self.acc).name))
            self._zero_err()
            return self.zero_x()
        _, init_fn, _ = self._fns
        self._y, d = init_fn(self._D, jnp.asarray(x0, self.acc))
        self._lam = self._place_iterate(
            np.zeros(shape, jnp.dtype(self.acc).name))
        self._zero_err()
        return d

    def _zero_err(self):
        self._err = shard_rows(
            self.mesh, np.zeros((self.nshards, self.n), np.float32),
            self.axes)

    def sweep(self, x: Array, k: int) -> SweepResult:
        _, _, step_fn = self._fns
        self._y, self._lam, self._err, sw = step_fn(
            self._D, self._aux, self._y, self._lam,
            jnp.asarray(x, self.acc), self._err)
        return sw

    def pad_objective(self) -> float:
        if self.pad == 0:
            return 0.0
        z = jnp.zeros((self.pad,) if self.ycols == 1
                      else (self.pad, self.ycols), jnp.float32)
        a = jnp.zeros((self.pad,), jnp.float32)
        return float(self.engine.loss.value(z, a if self.has_aux
                                            else None))

    def extra_record(self) -> dict:
        return {"shards": self.nshards}

    # -- checkpointing ------------------------------------------------------
    def state_arrays(self, k: int) -> dict:
        return {"y": jnp.asarray(np.asarray(self._y)[:self.m]),
                "lam": jnp.asarray(np.asarray(self._lam)[:self.m])}

    def restore_state(self, k: int, tree: dict) -> Array:
        shape = self._yshape()

        def repad(a):
            host = np.zeros(shape, jnp.dtype(self.acc).name)
            host[:self.m] = np.asarray(a)
            return self._place_iterate(host)

        self._y = repad(tree["y"])
        self._lam = repad(tree["lam"])
        self._zero_err()                 # EF error restarts at zero: it
        # is a wire optimization, not solver state — resume stays exact
        return tree["d"]

    def final_iterates(self):
        y = jnp.asarray(np.asarray(self._y)[:self.m])
        lam = jnp.asarray(np.asarray(self._lam)[:self.m])
        return y[None], lam[None]


def _shard_fns(engine, axes, mesh, has_aux: bool, compress: bool):
    """Jitted (gram, init, step) shard_map bodies for one engine config."""
    yspec = P(axes)                       # rows sharded, trailing dims full
    loss = engine.loss

    def gram_body(D):
        G, _ = engine.gram(D)
        return jax.lax.psum(G, axes)

    def init_body(D, x0):
        acc = gram_lib._acc_dtype(D.dtype)
        y = D.astype(acc) @ x0.astype(acc)
        d = jax.lax.psum(D.astype(acc).T @ y, axes)
        return y, d

    def step_body(D, aux, y, lam, x, err):
        Dres = engine.prepare(D)
        st = engine.iterate(Dres, aux, y, lam, x, want_dual=True)
        Dx = st.lam - lam + st.y
        if compress:
            d, e = compressed_psum(st.d, axes, err[0])
            err = e[None]
        else:
            d = jax.lax.psum(st.d, axes)
        sw = SweepResult(
            d, jax.lax.psum(st.w, axes), jax.lax.psum(st.v, axes),
            jax.lax.psum(jnp.sum((st.lam - lam) ** 2), axes),
            jax.lax.psum(jnp.sum(Dx * Dx), axes),
            jax.lax.psum(jnp.sum(st.y * st.y), axes),
            jax.lax.psum(loss.value(Dx, aux), axes))
        return st.y, st.lam, err, sw

    dspec = P(axes, None)
    espec = P(axes, None)
    rspec = SweepResult(*([P()] * 7))
    gram_fn = jax.jit(shard_map(gram_body, mesh=mesh, in_specs=(dspec,),
                                out_specs=P(), check_vma=False))
    init_fn = jax.jit(shard_map(init_body, mesh=mesh,
                                in_specs=(dspec, P()),
                                out_specs=(yspec, P()), check_vma=False))
    aspec = P(axes) if has_aux else None
    step_fn = jax.jit(shard_map(
        step_body, mesh=mesh,
        in_specs=(dspec, aspec, yspec, yspec, P(), espec),
        out_specs=(yspec, yspec, espec, rspec), check_vma=False))
    return gram_fn, init_fn, step_fn
