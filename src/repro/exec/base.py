"""The SolveExecutor contract and the ONE unwrapped-ADMM solve driver.

The paper's thesis is that transpose reduction makes the global
sub-problem identical no matter where the data lives: every topology
produces the same three n-sized reductions d = D^T(y'-lam'),
w = D^T(y'-y), v = D^T lam' plus four scalars, and everything above that
line — the x-update, Boyd's stopping rule, warm starts, checkpoint/
resume, obs spans/telemetry, history assembly — is topology-independent.
This module owns that shared half exactly once (DESIGN.md §14).

A :class:`SolveExecutor` backend owns only the three topology-specific
primitives:

  * ``setup()`` — stage the data and produce the Gram matrix G = D^T D
    (one pass over D, however the topology stores it);
  * ``init(x0)`` — establish the iterate state (y, lam) it keeps between
    sweeps (host buffers, device shards, or worker processes) and return
    the warm-start reduction d = D^T(y - lam);
  * ``sweep(x, k)`` — run the fused per-block body over all rows once
    and reduce to a :class:`~repro.engine.streaming.SweepResult`
    (``None`` aborts the solve as ``degraded``).

plus small hooks for checkpoint state ownership. Backends must NOT
re-implement the stopping rule, residual formulas, history, or
checkpoint cadence — that is the driver's job, and having four copies of
it is the bug class this module deletes.
"""
from __future__ import annotations

import abc
import dataclasses
import time
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.engine.streaming import SweepResult

Array = jax.Array


# ---------------------------------------------------------------------------
# composite x-update: argmin g(x) + tau/2 (x'Gx - 2 d'x), prox-gradient
# ---------------------------------------------------------------------------

@jax.jit
def power_lmax(G: Array) -> Array:
    """Largest eigenvalue of G by 30 power iterations — the inner
    prox-gradient stepsize for composite x-updates."""
    n = G.shape[0]
    v = jnp.ones((n,), G.dtype) / jnp.sqrt(n * 1.0)

    def piter(v, _):
        w = G @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30), None

    v, _ = jax.lax.scan(piter, v, None, length=30)
    return jnp.vdot(v, G @ v)


def composite_x_update(G: Array, lmax: Array, d: Array, x_warm: Array,
                       tau: float, prox: Callable[[Array, Array], Array],
                       inner_iters: int = 25) -> Array:
    """Warm-started proximal gradient on the cached Gram: minimizes
    g(x) + tau/2 (x'Gx - 2 d'x) where ``prox(z, step)`` is the prox of
    ``step * g``. Shared by the driver (group lasso / l1 regularizers)
    and ``DistributedUnwrappedADMM``'s in-jit composite x-update —
    traceable (pure jnp), so it works inside shard_map bodies too."""
    step = 1.0 / (tau * lmax)

    def body(x, _):
        grad = tau * (G @ x - d)
        return prox(x - step * grad, step), None

    x, _ = jax.lax.scan(body, x_warm, None, length=inner_iters)
    return x


@dataclasses.dataclass(frozen=True)
class Regularizer:
    """A separable penalty g(x) on the SOLUTION (not on y = Dx): the
    x-update becomes the composite prox-gradient above instead of a
    Cholesky solve. ``prox(z, step)`` is the prox of ``step * g``."""

    name: str
    value: Callable[[Array], Array]
    prox: Callable[[Array, Array], Array]
    inner_iters: int = 25


def make_l1_reg(mu: float, inner_iters: int = 25) -> Regularizer:
    from repro.core.prox import soft_threshold
    return Regularizer("l1", lambda x: mu * jnp.sum(jnp.abs(x)),
                       lambda z, step: soft_threshold(z, step * mu),
                       inner_iters)


def make_group_lasso_reg(mu: float, groups, num_groups: int,
                         inner_iters: int = 40) -> Regularizer:
    """Group-lasso penalty mu * sum_g ||x_g||_2 over the coordinate
    partition ``groups`` (int array mapping coordinate -> group id)."""
    from repro.core.prox import group_soft_threshold
    g = jnp.asarray(groups, jnp.int32)

    def value(x):
        sq = jax.ops.segment_sum(x * x, g, num_segments=num_groups)
        return mu * jnp.sum(jnp.sqrt(sq))

    return Regularizer(
        "group_lasso", value,
        lambda z, step: group_soft_threshold(z, step * mu, g, num_groups),
        inner_iters)


# ---------------------------------------------------------------------------
# the executor contract
# ---------------------------------------------------------------------------

class SolveExecutor(abc.ABC):
    """One solve topology reduced to its three primitives (module
    docstring). Concrete backends: local / streaming / shard_map /
    cluster. Instances are single-solve: the driver assumes exclusive
    ownership of the iterate state between ``init`` and the last
    ``sweep``."""

    name: str = "?"                  # stamped into telemetry / BENCH json
    backend: str = "?"               # resolved engine backend, ditto
    checkpoint_kind: str = "solve"   # checkpoint `extra["kind"]` tag
    kind_label: str = "executor"     # human label in restore errors
    restore_fallback: bool = False   # CheckpointManager fallback scan
    error_cls = ValueError           # restore-refusal exception type
    status: str = "ok"               # backends may set "degraded"

    m: int
    n: int
    ycols: int = 1
    acc = jnp.float32                # accumulation dtype of x/d

    # -- the three topology primitives --------------------------------------
    @abc.abstractmethod
    def setup(self, obs) -> Array:
        """Stage the data; return the Gram matrix G = D^T D (n, n)."""

    @abc.abstractmethod
    def init(self, x0: Optional[Array]) -> Array:
        """Establish iterate state; return d = D^T(y0 - lam0). ``x0``
        None is the cold start (y = lam = 0 without touching D)."""

    @abc.abstractmethod
    def sweep(self, x: Array, k: int) -> Optional[SweepResult]:
        """One fused pass over all rows for iteration ``k`` (1-based):
        update the backend's (y, lam), return the reductions. ``None``
        stops the solve with ``status='degraded'``."""

    # -- shared-driver hooks (defaults fit most backends) -------------------
    def zero_x(self) -> Array:
        shape = (self.n,) if self.ycols == 1 else (self.n, self.ycols)
        return jnp.zeros(shape, self.acc)

    def pad_objective(self) -> float:
        return 0.0

    def extra_record(self) -> dict:
        """Backend-specific keys merged into each telemetry record."""
        return {}

    def finish(self, iters: int, converged: bool):
        """Post-loop bookkeeping (cluster status accounting)."""

    # -- checkpoint ownership: backend owns SHAPES, driver owns CADENCE -----
    def state_like(self) -> dict:
        yshape = ((self.m,) if self.ycols == 1 else (self.m, self.ycols))
        z = partial(jnp.zeros, dtype=self.acc)
        return {"x": self.zero_x(), "y": z(yshape), "lam": z(yshape),
                "d": self.zero_x()}

    def checkpoint_extra(self) -> dict:
        return {}

    def verify_checkpoint(self, extra: dict):
        """Raise ``error_cls`` when the checkpoint belongs elsewhere."""

    def restore_state(self, k: int, tree: dict) -> Array:
        """Adopt restored (y, lam); return the restored d."""
        raise self.error_cls(
            f"{self.name} executor does not support resume")

    def state_arrays(self, k: int) -> Optional[dict]:
        """{"y": ..., "lam": ...} at iteration k, or None to skip this
        checkpoint round (cluster mid-recovery)."""
        return None

    def on_checkpointed(self, k: int, state: dict):
        """A checkpoint at k was committed (cluster: new replay base)."""

    @abc.abstractmethod
    def final_iterates(self) -> Tuple[Array, Array]:
        """(y, lam) in the node-stacked ADMMResult convention."""


# ---------------------------------------------------------------------------
# THE driver
# ---------------------------------------------------------------------------

def solve_with_executor(ex: SolveExecutor, *, loss, tau: float,
                        rho: float = 0.0, eps_rel: float = 1e-3,
                        eps_abs: float = 1e-6, max_iters: int = 500,
                        x0: Optional[Array] = None, record: bool = False,
                        reg: Optional[Regularizer] = None,
                        checkpoint_dir: Optional[str] = None,
                        checkpoint_every: int = 0, resume: bool = False,
                        obs=None):
    """Unwrapped ADMM (paper Alg. 1/2) over any :class:`SolveExecutor`.

    Owns, exactly once, everything the four topologies used to
    duplicate: the x-update (Cholesky on the cached Gram, or the
    composite prox-gradient when ``reg`` is given), Boyd's stopping rule
    with its eps_pri/eps_dual tolerances, warm starts, checkpoint
    cadence + resume validation, obs spans and per-iteration telemetry
    (stamped with executor name + backend), and history assembly.
    Returns an :class:`~repro.core.unwrapped.ADMMResult`.
    """
    from repro.core.unwrapped import ADMMHistory, ADMMResult
    from repro.obs import NOOP

    obs = obs if obs is not None else NOOP
    m, n, K = ex.m, ex.n, ex.ycols
    m_eff, n_eff = m * K, n * K

    with obs.span("gram_setup", executor=ex.name):
        G = ex.setup(obs)
        if reg is None:
            L = gram_lib.gram_factor(G, ridge=rho / tau)
            lmax = None
        else:
            L = None
            lmax = power_lmax(G)

    manager = None
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(checkpoint_dir)

    k = 0
    ex.resume_iter = 0
    if manager is not None and resume and manager.latest_step() is not None:
        tree, extra = manager.restore(ex.state_like(),
                                      fallback=ex.restore_fallback)
        if extra.get("kind") != ex.checkpoint_kind:
            raise ex.error_cls(
                f"not a {ex.kind_label} checkpoint: {extra}")
        ex.verify_checkpoint(extra)
        k = int(extra["iter"])
        ex.resume_iter = k
        d = ex.restore_state(k, tree)
        x = tree["x"]            # returned as-is if no iterations remain
    elif x0 is not None:
        with obs.span("init_from_x0", executor=ex.name):
            d = ex.init(x0)
        x = ex.zero_x()
    else:
        d = ex.init(None)
        x = ex.zero_x()

    pad_obj = ex.pad_objective()
    objs, rs, ss = [], [], []
    k_conv = -1
    while k < max_iters:
        t_it = time.perf_counter()
        with obs.span("x_solve", k=k + 1):
            if reg is None:
                x = gram_lib.gram_solve(L, jnp.asarray(d))
            else:
                x = composite_x_update(G, lmax, jnp.asarray(d),
                                       jnp.asarray(x), tau, reg.prox,
                                       reg.inner_iters)
        t_sw = time.perf_counter()
        with obs.span("sweep", k=k + 1):
            sw = ex.sweep(x, k + 1)
        sweep_s = time.perf_counter() - t_sw
        if sw is None:           # degraded stop: best-so-far x
            break
        d = sw.d
        r = float(jnp.sqrt(sw.r_sq))
        s = tau * float(jnp.linalg.norm(sw.w))
        eps_pri = np.sqrt(m_eff) * eps_abs + eps_rel * max(
            float(jnp.sqrt(sw.dx_sq)), float(jnp.sqrt(sw.y_sq)))
        eps_dual = np.sqrt(n_eff) * eps_abs + (
            eps_rel * tau * float(jnp.linalg.norm(sw.v)))
        k += 1
        if record or obs.enabled:
            obj = float(sw.obj) - pad_obj
            if rho:
                obj += 0.5 * rho * float(jnp.sum(jnp.asarray(x) ** 2))
            if reg is not None:
                obj += float(reg.value(jnp.asarray(x)))
            if record:
                objs.append(obj)
                rs.append(r)
                ss.append(s)
            if obs.enabled:
                dt = time.perf_counter() - t_it
                obs.observe(f"{ex.name}.sweep_s", sweep_s)
                obs.observe(f"{ex.name}.iter_s", dt)
                obs.record(iter=k, objective=obj, primal_res=r,
                           dual_res=s, eps_pri=float(eps_pri),
                           eps_dual=float(eps_dual), tau=tau, rho=rho,
                           iter_s=round(dt, 6),
                           sweep_s=round(sweep_s, 6),
                           executor=ex.name, backend=ex.backend,
                           **ex.extra_record())
        if manager is not None and checkpoint_every \
                and k % checkpoint_every == 0:
            state = ex.state_arrays(k)
            if state is not None:
                manager.save(k, {"x": x, "y": state["y"],
                                 "lam": state["lam"], "d": d},
                             extra={"kind": ex.checkpoint_kind, "iter": k,
                                    **ex.checkpoint_extra()})
                ex.on_checkpointed(k, state)
        if r <= eps_pri and s <= eps_dual:
            k_conv = k - 1
            break

    converged = k_conv >= 0
    ex.finish(k, converged)
    history = None
    if record:
        acc = ex.acc
        nan = jnp.full((len(objs),), jnp.nan, acc)
        history = ADMMHistory(jnp.asarray(objs, acc), jnp.asarray(rs, acc),
                              jnp.asarray(ss, acc), nan,
                              jnp.asarray(k_conv, jnp.int32))
    y, lam = ex.final_iterates()
    return ADMMResult(jnp.asarray(x), y, lam, jnp.asarray(k, jnp.int32),
                      history)
