"""repro.exec — the SolveExecutor contract + the one shared ADMM driver.

DESIGN.md §14: four interchangeable topology backends (local row blocks,
out-of-core streaming, shard_map device mesh, multi-process cluster)
behind one protocol of three primitives, with the stopping rule, warm
starts, checkpoint/resume, telemetry and history in exactly one place.
"""
from repro.exec.base import (
    Regularizer,
    SolveExecutor,
    composite_x_update,
    make_group_lasso_reg,
    make_l1_reg,
    power_lmax,
    solve_with_executor,
)
from repro.exec.cluster import ClusterExecutor
from repro.exec.local import LocalExecutor
from repro.exec.problems import (
    EXECUTORS,
    ExecProblem,
    fit_on_executor,
    make_executor,
    make_problem,
    synth_data,
)
from repro.exec.shard_map import ShardMapExecutor
from repro.exec.streaming import StreamingExecutor

__all__ = [
    "Regularizer",
    "SolveExecutor",
    "composite_x_update",
    "make_group_lasso_reg",
    "make_l1_reg",
    "power_lmax",
    "solve_with_executor",
    "ClusterExecutor",
    "LocalExecutor",
    "ShardMapExecutor",
    "StreamingExecutor",
    "EXECUTORS",
    "ExecProblem",
    "fit_on_executor",
    "make_executor",
    "make_problem",
    "synth_data",
]
