"""Synthetic GLM data generators — paper §10.1 and §10.2 analogue.

All generators emit the node-stacked layout (N, m_i, n) used by the solvers,
with deterministic per-node seeding (node i derives its own fold of the key,
so generation is reproducible shard-by-shard without materializing the global
matrix anywhere — the same discipline the distributed pipeline uses).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class LassoProblem(NamedTuple):
    D: Array          # (N, m_i, n)
    b: Array          # (N, m_i)
    x_true: Array     # (n,)
    mu: Array         # scalar: the paper's 10% rule


class ClassifProblem(NamedTuple):
    D: Array          # (N, m_i, n)
    labels: Array     # (N, m_i) in {-1, +1}


def _hetero_shift(key, N: int, scale: float) -> Array:
    """Paper: 'one random Gaussian scalar for each node, added to D_i'."""
    return scale * jax.random.normal(key, (N, 1, 1))


def lasso_problem(
    key,
    N: int,
    m_per_node: int,
    n: int,
    active: int = 10,
    heterogeneity: float = 0.0,
    noise_sigma: float = 1.0,
    dtype=jnp.float32,
) -> LassoProblem:
    """Boyd-style lasso test problem (paper §10.1 'Lasso problems').

    D random Gaussian; x_true has ``active`` unit-magnitude entries;
    b = D x_true + sigma*eta; mu = 10% of mu_max = ||D^T b||_inf.
    """
    kD, kx, keta, kh, ksgn = jax.random.split(key, 5)
    D = jax.random.normal(kD, (N, m_per_node, n), dtype)
    if heterogeneity:
        D = D + _hetero_shift(kh, N, heterogeneity).astype(dtype)
    idx = jax.random.permutation(kx, n)[:active]
    signs = jnp.sign(jax.random.normal(ksgn, (active,))) .astype(dtype)
    x_true = jnp.zeros((n,), dtype).at[idx].set(signs)
    b = jnp.einsum("imn,n->im", D, x_true) + noise_sigma * jax.random.normal(
        keta, (N, m_per_node), dtype
    )
    Dt_b = jnp.einsum("imn,im->n", D.astype(jnp.float32), b.astype(jnp.float32))
    mu = 0.1 * jnp.max(jnp.abs(Dt_b))
    return LassoProblem(D, b, x_true, mu)


def classification_problem(
    key,
    N: int,
    m_per_node: int,
    n: int,
    informative: int = 5,
    mean_shift: float = 1.0,
    heterogeneity: float = 0.0,
    dtype=jnp.float32,
) -> ClassifProblem:
    """Paper §10.1 'Classification problems'.

    Two Gaussian classes; class 2 has mean ``mean_shift`` in its first
    ``informative`` columns (classes are NOT perfectly separable). Rows of the
    two classes are interleaved evenly per node; optional per-node scalar
    shift creates heterogeneity.
    """
    kD, kh, kperm = jax.random.split(key, 3)
    m_half = m_per_node // 2
    D = jax.random.normal(kD, (N, m_per_node, n), dtype)
    labels = jnp.concatenate(
        [
            -jnp.ones((N, m_per_node - m_half), dtype),
            jnp.ones((N, m_half), dtype),
        ],
        axis=1,
    )
    shift = jnp.zeros((n,), dtype).at[:informative].set(mean_shift)
    D = D + jnp.where(labels[..., None] > 0, shift, 0.0)
    if heterogeneity:
        D = D + _hetero_shift(kh, N, heterogeneity).astype(dtype)
    # Shuffle rows within each node so classes are interleaved.
    perm = jax.vmap(lambda k: jax.random.permutation(k, m_per_node))(
        jax.random.split(kperm, N)
    )
    D = jnp.take_along_axis(D, perm[..., None], axis=1)
    labels = jnp.take_along_axis(labels, perm, axis=1)
    return ClassifProblem(D, labels)


def star_catalog_problem(
    key,
    N: int,
    m_per_node: int,
    base_features: int = 17,
    dtype=jnp.float32,
) -> ClassifProblem:
    """GSC-II analogue (paper §10.2): 17 base measurements + ALL second-order
    products (17x17 = 289) + bias = 307 features, matching the paper.

    Base features are drawn from a node-dependent (heterogeneous) Gaussian —
    empirical sky-survey data is not iid across shards — and the label is a
    noisy sparse logistic teacher over the interaction features, mimicking
    'star / not-a-star' structure. Features are normalized as in the paper.
    """
    kD, kh, kw, kn = jax.random.split(key, 4)
    base = jax.random.normal(kD, (N, m_per_node, base_features), dtype)
    base = base + 0.5 * _hetero_shift(kh, N, 1.0).astype(dtype)
    # ALL second-order products (full 17x17 grid, as the paper's 307 needs).
    inter = (base[..., :, None] * base[..., None, :]).reshape(
        N, m_per_node, base_features * base_features)
    ones = jnp.ones((N, m_per_node, 1), dtype)
    D = jnp.concatenate([base, inter, ones], axis=-1)
    # Normalize features (global scale; per-feature std over a sample).
    std = jnp.maximum(jnp.std(D.reshape(-1, D.shape[-1]), axis=0), 1e-6)
    D = D / std
    n = D.shape[-1]
    w = jax.random.normal(kw, (n,), dtype) * (
        jax.random.bernoulli(kw, 0.1, (n,))
    )
    logits = jnp.einsum("imn,n->im", D, w)
    noise = 0.5 * jax.random.normal(kn, logits.shape, dtype)
    labels = jnp.sign(logits + noise)
    labels = jnp.where(labels == 0, 1.0, labels).astype(dtype)
    return ClassifProblem(D, labels)
