"""Padded block-CSR — the sparse data path's container (DESIGN.md §10).

The paper's headline regime (5 Tb of rows on 7000+ cores) is SPARSE: the
transpose reductions ``D^T D``, ``D^T(y - lam)`` cost O(nnz), not O(mn).
:class:`BlockCSR` stores a tall (m, n) matrix so every solver pass keeps
that asymptotic:

  * rows are grouped into ``block_m``-row blocks; each block stores per-row
    column indices + values padded to the matrix's max row-nnz ``kp``
    (pad slots are ``(index 0, value 0)`` — a zero VALUE kills the padded
    contribution under every gather-multiply, whatever it gathers), so
    every pass is a ``lax.scan`` over static-shaped blocks — the same
    scaffold the chunked engine and ShardedMatrixStore use;
  * each block ALSO carries its local transpose: a per-block CSC with
    block-LOCAL row ids, ``(n, kc)`` per block. This is the transpose
    reduction applied to the format itself: the d/w/v reductions
    ``D_b^T u_b`` become GATHERS from the block-resident (block_m,)
    vector u_b instead of scatter-adds into the (n,) accumulator —
    measured on CPU XLA, scatter-add runs ~70x slower per element than
    gather (DESIGN.md §10), so the scatter formulation would forfeit the
    entire sparsity win;
  * duplicate column indices within a row are legal and SUM (both
    ``to_dense`` and every reduction treat the entries as COO triples).

Memory: ~``2 * nnz * (4 + itemsize)`` bytes plus padding slack — the CSR
and CSC copies each hold every nonzero once. At 5% density and f32 that
is ~13x under the dense bytes; stores built from this container scale
with nnz, so the out-of-core path fits ~1/density more rows per device
budget.

Generators mirror ``data/synthetic`` (classification / lasso problems)
with controllable density, building the sparse triples directly — the
dense matrix never materializes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_SLOT_MULT = 4            # pad kp / kc up to a multiple of this


def _round_up(v: int, mult: int) -> int:
    return -(-max(int(v), 1) // mult) * mult


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """Padded block-CSR + per-block local CSC for a tall (m, n) matrix.

    ``indices/values``: (nblocks, block_m, kp) — per-row padded CSR.
    ``col_indices/col_values``: (nblocks, n, kc) — per-block padded CSC
    with block-local row ids in [0, block_m). Rows beyond ``m`` in the
    tail block are zero-nnz (static shapes; padding is free in
    sparse-land). Registered as a pytree (arrays are children; m/n/nnz
    ride as static aux) so solvers jit/scan over it directly.
    """

    indices: Array        # (nb, bm, kp) int32 column ids
    values: Array         # (nb, bm, kp)
    col_indices: Array    # (nb, n, kc) int32 block-local row ids
    col_values: Array     # (nb, n, kc)
    m: int
    n: int
    nnz: int

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return ((self.indices, self.values, self.col_indices,
                 self.col_values), (self.m, self.n, self.nnz))

    @classmethod
    def tree_unflatten(cls, aux, children):
        idx, val, cidx, cval = children
        m, n, nnz = aux
        return cls(indices=idx, values=val, col_indices=cidx,
                   col_values=cval, m=m, n=n, nnz=nnz)

    # -- shape surface ------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.m, self.n)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nblocks(self) -> int:
        return self.indices.shape[0]

    @property
    def block_m(self) -> int:
        return self.indices.shape[1]

    @property
    def kp(self) -> int:
        return self.indices.shape[2]

    @property
    def kc(self) -> int:
        return self.col_indices.shape[2]

    @property
    def density(self) -> float:
        return self.nnz / float(max(self.m * self.n, 1))

    @property
    def nbytes(self) -> int:
        return sum(np.asarray(a).nbytes for a in
                   (self.indices, self.values, self.col_indices,
                    self.col_values))

    # -- residency ----------------------------------------------------------
    def astype(self, dtype) -> "BlockCSR":
        """Cast the VALUE arrays (indices stay int32) — the engine's
        residency hook (bf16 values, f32 accumulation)."""
        if jnp.dtype(dtype) == jnp.dtype(self.dtype):
            return self
        return dataclasses.replace(
            self, values=self.values.astype(dtype),
            col_values=self.col_values.astype(dtype))

    def reblock(self, block_m: int) -> "BlockCSR":
        """Rebuild with a different block height (the out-of-core path's
        device-budget knob). Extracts the nonzero slots and re-runs the
        COO builder; explicit STORED zeros are dropped (exact under
        every reduction), duplicates survive."""
        nb, bm, kp = self.indices.shape
        val = np.asarray(self.values).reshape(nb * bm, kp)
        idx = np.asarray(self.indices).reshape(nb * bm, kp)
        rows, slots = np.nonzero(val)
        return BlockCSR.from_coo(rows.astype(np.int64), idx[rows, slots],
                                 val[rows, slots], self.m, self.n,
                                 block_m=block_m)

    # -- conversion ---------------------------------------------------------
    def to_dense(self) -> Array:
        """Dense (m, n) — duplicates SUM (COO semantics); pad slots are
        value-0 so they contribute nothing."""
        nb, bm, kp = self.indices.shape
        rows = jnp.arange(nb * bm, dtype=jnp.int32).reshape(nb, bm, 1)
        out = jnp.zeros((nb * bm, self.n), self.dtype)
        out = out.at[jnp.broadcast_to(rows, self.indices.shape),
                     self.indices].add(self.values)
        return out[:self.m]

    @classmethod
    def from_dense(cls, D, block_m: Optional[int] = None) -> "BlockCSR":
        """Extract the nonzeros of a dense (m, n) or node-stacked
        (N, m_i, n) matrix. Exact: stored zeros do not exist in dense
        input, so the round trip ``to_dense(from_dense(D)) == D``."""
        D = np.asarray(D)
        if D.ndim == 3:
            D = D.reshape(-1, D.shape[-1])
        m, n = D.shape
        rows, cols = np.nonzero(D)
        return cls.from_coo(rows.astype(np.int64), cols.astype(np.int32),
                            D[rows, cols], m, n, block_m=block_m)

    @classmethod
    def from_coo(cls, rows, cols, vals, m: int, n: int,
                 block_m: Optional[int] = None,
                 kp: Optional[int] = None) -> "BlockCSR":
        """Build from COO triples (duplicates kept — they sum)."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int32)
        vals = np.asarray(vals)
        order = np.argsort(rows, kind="stable")
        rows, cols, vals = rows[order], cols[order], vals[order]
        counts = np.bincount(rows, minlength=m).astype(np.int64)
        kp = kp or _round_up(counts.max() if m else 1, _SLOT_MULT)
        if block_m is None:
            # Lazy: repro.engine imports this module, so a top-level
            # import of the autotuner would be circular.
            from repro.engine import autotune
            block_m = autotune.sparse_block_m(m, n, kp, vals.dtype)
        bm = int(min(block_m, _round_up(max(m, 1), 8)))
        nb = max(1, -(-m // bm))
        mp = nb * bm

        idx = np.zeros((mp, kp), np.int32)
        val = np.zeros((mp, kp), vals.dtype)
        starts = np.concatenate([[0], np.cumsum(counts)])
        slot = np.arange(rows.shape[0], dtype=np.int64) - starts[rows]
        idx[rows, slot] = cols
        val[rows, slot] = vals

        # per-block local CSC: sort triples by (block, col, row)
        blocks = rows // bm
        local = (rows % bm).astype(np.int32)
        key = blocks * n + cols
        corder = np.argsort(key, kind="stable")     # row-major in, so
        bkey = key[corder]                          # rows stay sorted
        ccnt = np.bincount(bkey, minlength=nb * n).astype(np.int64)
        kc = _round_up(ccnt.max() if ccnt.size else 1, _SLOT_MULT)
        cstarts = np.concatenate([[0], np.cumsum(ccnt)])
        cslot = np.arange(bkey.shape[0], dtype=np.int64) - cstarts[bkey]
        cidx = np.zeros((nb * n, kc), np.int32)
        cval = np.zeros((nb * n, kc), vals.dtype)
        cidx[bkey, cslot] = local[corder]
        cval[bkey, cslot] = vals[corder]

        return cls(indices=jnp.asarray(idx.reshape(nb, bm, kp)),
                   values=jnp.asarray(val.reshape(nb, bm, kp)),
                   col_indices=jnp.asarray(cidx.reshape(nb, n, kc)),
                   col_values=jnp.asarray(cval.reshape(nb, n, kc)),
                   m=int(m), n=int(n), nnz=int(vals.shape[0]))

    def __repr__(self) -> str:
        return (f"BlockCSR(m={self.m}, n={self.n}, nnz={self.nnz}, "
                f"density={self.density:.4f}, block_m={self.block_m}, "
                f"kp={self.kp}, kc={self.kc}, dtype={self.dtype})")


def host_blocks(bcsr: BlockCSR):
    """Per-block host numpy views ``(indices, values, col_indices,
    col_values)`` — the store's write path."""
    return (np.asarray(bcsr.indices), np.asarray(bcsr.values),
            np.asarray(bcsr.col_indices), np.asarray(bcsr.col_values))


# ---------------------------------------------------------------------------
# sparse synthetic generators (data/synthetic.py analogues, O(nnz) build)
# ---------------------------------------------------------------------------

class SparseLassoProblem(NamedTuple):
    D: BlockCSR
    b: Array          # (m,)
    x_true: Array     # (n,)
    mu: Array


class SparseClassifProblem(NamedTuple):
    D: BlockCSR
    labels: Array     # (m,) in {-1, +1}


def _random_coo(rng, m: int, n: int, density: float, chunk: int = 1 << 15):
    """Bernoulli(density) sparsity pattern, built row-chunk by row-chunk
    so the dense mask never exceeds ``chunk * n`` — O(nnz) output."""
    rows, cols = [], []
    for s in range(0, m, chunk):
        e = min(m, s + chunk)
        mask = rng.random((e - s, n), dtype=np.float32) < density
        r, c = np.nonzero(mask)
        rows.append((r + s).astype(np.int64))
        cols.append(c.astype(np.int32))
    rows = np.concatenate(rows) if rows else np.zeros((0,), np.int64)
    cols = np.concatenate(cols) if cols else np.zeros((0,), np.int32)
    return rows, cols


def random_block_csr(seed: int, m: int, n: int, density: float,
                     block_m: Optional[int] = None,
                     dtype=np.float32) -> BlockCSR:
    """Gaussian values on a Bernoulli(density) pattern."""
    rng = np.random.default_rng(seed)
    rows, cols = _random_coo(rng, m, n, density)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    return BlockCSR.from_coo(rows, cols, vals, m, n, block_m=block_m)


def sparse_classification_problem(
    seed: int, m: int, n: int, density: float,
    informative: int = 5, mean_shift: float = 1.0,
    block_m: Optional[int] = None, dtype=np.float32,
) -> SparseClassifProblem:
    """Sparse two-class problem (paper §10.1 analogue): +1 rows get a
    ``mean_shift`` added to their entries in the first ``informative``
    columns — signal only where the sparsity pattern touches those
    columns, so classes stay non-separable."""
    rng = np.random.default_rng(seed)
    rows, cols = _random_coo(rng, m, n, density)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    labels = np.where(rng.random(m) < 0.5, 1.0, -1.0).astype(dtype)
    boost = (labels[rows] > 0) & (cols < informative)
    vals = vals + mean_shift * boost.astype(dtype)
    D = BlockCSR.from_coo(rows, cols, vals, m, n, block_m=block_m)
    return SparseClassifProblem(D, jnp.asarray(labels))


def sparse_lasso_problem(
    seed: int, m: int, n: int, density: float, active: int = 10,
    noise_sigma: float = 1.0, block_m: Optional[int] = None,
    dtype=np.float32,
) -> SparseLassoProblem:
    """Sparse lasso problem: b = D x_true + noise, mu = 10% of
    ||D^T b||_inf (the paper's rule) — both computed in O(nnz)."""
    rng = np.random.default_rng(seed)
    rows, cols = _random_coo(rng, m, n, density)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    x_true = np.zeros((n,), dtype)
    idx = rng.permutation(n)[:active]
    x_true[idx] = np.where(rng.random(active) < 0.5, 1.0, -1.0)
    Dx = np.bincount(rows, weights=(vals * x_true[cols]).astype(np.float64),
                     minlength=m).astype(dtype)
    b = Dx + noise_sigma * rng.standard_normal(m).astype(dtype)
    Dtb = np.bincount(cols, weights=(vals * b[rows]).astype(np.float64),
                      minlength=n)
    mu = 0.1 * float(np.abs(Dtb).max() or 1.0)
    D = BlockCSR.from_coo(rows, cols, vals, m, n, block_m=block_m)
    return SparseLassoProblem(D, jnp.asarray(b), jnp.asarray(x_true),
                              jnp.asarray(mu, dtype))
