"""Deterministic sharded data pipeline.

Synthetic-token LM stream with the properties the fault-tolerance layer
needs: (a) every (step, shard) batch is a pure function of (seed, step) — no
pipeline state files; (b) restart at step k reproduces exactly the batches a
non-interrupted run would have seen; (c) elastic re-sharding (different DP
size) re-partitions the same global batch, so restarts on a different mesh
consume identical global data.

A host-side prefetch thread keeps ``prefetch`` batches ready — the CPU-side
straggler mitigation for the synchronous TPU step (DESIGN.md §6).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    def __init__(self, *, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, frontend: str = "none", d_model: int = 0,
                 mrope: bool = False):
        self.vocab = vocab_size
        self.B = global_batch
        self.S = seq_len
        self.seed = seed
        self.frontend = frontend
        self.d_model = d_model
        self.mrope = mrope

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for ``step`` — pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        # Markov-ish synthetic stream: mixture of ngram-copy and uniform.
        toks = rng.integers(0, self.vocab, (self.B, self.S + 1), np.int32)
        copy_mask = rng.random((self.B, self.S + 1)) < 0.3
        toks[:, 1:][copy_mask[:, 1:]] = toks[:, :-1][copy_mask[:, 1:]]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.frontend == "vision":
            emb = rng.standard_normal(
                (self.B, self.S, self.d_model), np.float32) * 0.02
            batch = {"embeds": emb.astype(jnp.bfloat16),
                     "labels": toks[:, 1:],
                     "positions": np.broadcast_to(
                         np.arange(self.S, dtype=np.int32),
                         (3, self.B, self.S)).copy()}
        elif self.frontend == "audio":
            emb = rng.standard_normal(
                (self.B, self.S, self.d_model), np.float32) * 0.02
            batch["enc_embeds"] = emb.astype(jnp.bfloat16)
        return batch

    def shard_iterator(self, start_step: int, shardings=None,
                       prefetch: int = 2) -> Iterator:
        """Yields device-placed batches from ``start_step`` with a host
        prefetch thread."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                b = self.batch_at(step)
                if shardings is not None:
                    b = {k: jax.device_put(v, shardings[k])
                         for k, v in b.items()}
                q.put((step, b))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
