"""ShardedMatrixStore — host-RAM / memory-mapped row-block data store.

The out-of-core half of the paper's regime (DESIGN.md §9): the 5 Tb
datasets of §10 never fit an accelerator, but every solver object is a
reduction over ROW BLOCKS of D — Gram setup, the d/w/v transpose
reductions, the prox. This store holds the rows where they fit (host RAM,
or on disk behind ``numpy`` memory maps) and hands the streaming engine a
uniform iterator of ``(D_block, aux_block)`` pairs; device memory is then
bounded by one block regardless of m.

Layout contract:

  * rows are split into fixed-height blocks of ``block_rows``; the tail
    block is stored UNPADDED (logical length) and zero-padded on read when
    ``padded=True`` — zero rows are exact under every transpose reduction
    (``gram.blocked_rows``) so padded reads need no masks;
  * ``aux`` (labels / right-hand sides) rides along row-aligned, optional;
  * every block carries a content fingerprint computed at WRITE time, so
    downstream ingestion (``SufficientStats.from_store``) folds the
    store's fingerprints instead of re-hashing gigabytes on every pass.

On-disk format (``save`` / ``open``): a directory of ``block_*.npy`` (+
``aux_*.npy``) files, loaded back with ``mmap_mode="r"`` — the OS page
cache becomes the block cache and the prefetch thread of the streaming
engine overlaps page-in with compute.

Fingerprinting lives HERE (the data layer owns content identity);
``repro.service.stats`` re-exports the helpers for backward compatibility.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

ZERO_FINGERPRINT = "0" * 64

_META_NAME = "store_meta.json"


def fingerprint_array(*arrays) -> str:
    """sha256 content fingerprint of host-backed arrays (shape + bytes)."""
    h = hashlib.sha256()
    for a in arrays:
        if a is None:
            h.update(b"none")
            continue
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def combine_fingerprints(fp_a: str, fp_b: str, sign: int = 1) -> str:
    """Commutative, associative, multiplicity-sensitive fold.

    Addition mod 2^256 (not XOR): ingest order cannot matter, but ingesting
    the same block twice must NOT cancel back to the original fingerprint —
    the stats really do contain it twice. ``sign=-1`` is the downdate
    inverse, so retiring a block restores the prior fingerprint exactly.
    """
    return format((int(fp_a, 16) + sign * int(fp_b, 16)) % (1 << 256),
                  "064x")


def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad the leading axis up to ``rows`` (no-op when already there)."""
    k = a.shape[0]
    if k == rows:
        return a
    out = np.zeros((rows,) + a.shape[1:], a.dtype)
    out[:k] = a
    return out


class ShardedMatrixStore:
    """Row-block store for a tall (m, n) design matrix + row-aligned aux.

    Blocks are host ``numpy`` arrays — plain RAM when built with
    :meth:`from_arrays`, read-only memory maps when opened from disk with
    :meth:`open`. The solver never sees more than one block at a time.
    """

    def __init__(self, blocks_D: Sequence[np.ndarray],
                 blocks_aux: Optional[Sequence[np.ndarray]],
                 block_rows: int,
                 fingerprints: Sequence[str],
                 path: Optional[str] = None):
        if not blocks_D:
            raise ValueError("store needs at least one block")
        if blocks_aux is not None and len(blocks_aux) != len(blocks_D):
            raise ValueError("aux block count != D block count")
        if len(fingerprints) != len(blocks_D):
            raise ValueError("fingerprint count != block count")
        self._blocks_D = list(blocks_D)
        self._blocks_aux = list(blocks_aux) if blocks_aux is not None else None
        self.block_rows = int(block_rows)
        self.fingerprints = list(fingerprints)
        self.path = path
        self.n = int(blocks_D[0].shape[1])
        self.m = int(sum(b.shape[0] for b in blocks_D))
        self.dtype = np.dtype(blocks_D[0].dtype)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_arrays(cls, D, aux=None,
                    block_rows: int = 4096) -> "ShardedMatrixStore":
        """Split host arrays into row blocks (tail unpadded) and fingerprint
        each block once at build time."""
        D = np.asarray(D)
        if D.ndim == 3:                       # node-stacked (N, m_i, n)
            D = D.reshape(-1, D.shape[-1])
        if aux is not None:
            aux = np.asarray(aux).reshape(-1)
            if aux.shape[0] != D.shape[0]:
                raise ValueError(
                    f"aux rows {aux.shape[0]} != D rows {D.shape[0]}")
        m = D.shape[0]
        block_rows = int(min(block_rows, m))
        starts = range(0, m, block_rows)
        blocks_D = [np.ascontiguousarray(D[s:s + block_rows]) for s in starts]
        blocks_aux = (None if aux is None else
                      [np.ascontiguousarray(aux[s:s + block_rows])
                       for s in starts])
        fps = [fingerprint_array(bd, None if blocks_aux is None
                                 else blocks_aux[i])
               for i, bd in enumerate(blocks_D)]
        return cls(blocks_D, blocks_aux, block_rows, fps)

    # -- persistence (memory-mapped reopen) ---------------------------------
    def save(self, path: str) -> str:
        """Write blocks as .npy files + a JSON manifest; reopen with
        :meth:`open` for memory-mapped (out-of-RAM) access."""
        os.makedirs(path, exist_ok=True)
        for i, b in enumerate(self._blocks_D):
            np.save(os.path.join(path, f"block_{i:06d}.npy"), b)
            if self._blocks_aux is not None:
                np.save(os.path.join(path, f"aux_{i:06d}.npy"),
                        self._blocks_aux[i])
        meta = {"m": self.m, "n": self.n, "block_rows": self.block_rows,
                "nblocks": self.nblocks, "dtype": self.dtype.name,
                "has_aux": self._blocks_aux is not None,
                "fingerprints": self.fingerprints}
        with open(os.path.join(path, _META_NAME), "w") as f:
            json.dump(meta, f, indent=1)
        return path

    @classmethod
    def open(cls, path: str) -> "ShardedMatrixStore":
        """Memory-map a saved store; blocks page in lazily on first touch,
        so opening a multi-terabyte store costs only the manifest read."""
        with open(os.path.join(path, _META_NAME)) as f:
            meta = json.load(f)
        blocks_D = [np.load(os.path.join(path, f"block_{i:06d}.npy"),
                            mmap_mode="r")
                    for i in range(meta["nblocks"])]
        blocks_aux = None
        if meta["has_aux"]:
            blocks_aux = [np.load(os.path.join(path, f"aux_{i:06d}.npy"),
                                  mmap_mode="r")
                          for i in range(meta["nblocks"])]
        return cls(blocks_D, blocks_aux, meta["block_rows"],
                   meta["fingerprints"], path=path)

    # -- block access -------------------------------------------------------
    @property
    def nblocks(self) -> int:
        return len(self._blocks_D)

    @property
    def has_aux(self) -> bool:
        return self._blocks_aux is not None

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._blocks_D)

    @property
    def fingerprint(self) -> str:
        """Order-independent fold of the per-block fingerprints — equals the
        fingerprint of ingesting every block through
        ``SufficientStats.update``."""
        fp = ZERO_FINGERPRINT
        for b in self.fingerprints:
            fp = combine_fingerprints(fp, b)
        return fp

    def block_slice(self, k: int) -> slice:
        """Logical row range [start, stop) of block k (tail may be short)."""
        start = k * self.block_rows
        return slice(start, start + self._blocks_D[k].shape[0])

    def block(self, k: int, padded: bool = False
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Block k as host arrays. ``padded=True`` zero-pads the tail block
        to the uniform (block_rows, n) shape so every device step compiles
        once — exact, per the zero-row argument above."""
        D_b = self._blocks_D[k]
        a_b = self._blocks_aux[k] if self._blocks_aux is not None else None
        if padded and D_b.shape[0] != self.block_rows:
            D_b = _pad_rows(np.asarray(D_b), self.block_rows)
            if a_b is not None:
                a_b = _pad_rows(np.asarray(a_b), self.block_rows)
        return D_b, a_b

    def iter_blocks(self, padded: bool = False
                    ) -> Iterator[Tuple[np.ndarray, Optional[np.ndarray]]]:
        """The store's contract with the streaming engine: ``(D_block,
        aux_block)`` pairs in row order (aux_block is None for unlabeled
        stores)."""
        for k in range(self.nblocks):
            yield self.block(k, padded=padded)

    def __repr__(self) -> str:
        where = f"mmap:{self.path}" if self.path else "ram"
        return (f"ShardedMatrixStore(m={self.m}, n={self.n}, "
                f"block_rows={self.block_rows}, nblocks={self.nblocks}, "
                f"dtype={self.dtype.name}, {where})")
