"""ShardedMatrixStore — host-RAM / memory-mapped row-block data store.

The out-of-core half of the paper's regime (DESIGN.md §9): the 5 Tb
datasets of §10 never fit an accelerator, but every solver object is a
reduction over ROW BLOCKS of D — Gram setup, the d/w/v transpose
reductions, the prox. This store holds the rows where they fit (host RAM,
or on disk behind ``numpy`` memory maps) and hands the streaming engine a
uniform iterator of ``(D_block, aux_block)`` pairs; device memory is then
bounded by one block regardless of m.

Layout contract:

  * rows are split into fixed-height blocks of ``block_rows``; the tail
    block is stored UNPADDED (logical length) and zero-padded on read when
    ``padded=True`` — zero rows are exact under every transpose reduction
    (``gram.blocked_rows``) so padded reads need no masks;
  * ``aux`` (labels / right-hand sides) rides along row-aligned, optional;
  * every block carries a content fingerprint computed at WRITE time, so
    downstream ingestion (``SufficientStats.from_store``) folds the
    store's fingerprints instead of re-hashing gigabytes on every pass.

On-disk format (``save`` / ``open``): a directory of ``block_*.npy`` (+
``aux_*.npy``) files, loaded back with ``mmap_mode="r"`` — the OS page
cache becomes the block cache and the prefetch thread of the streaming
engine overlaps page-in with compute.

SPARSE stores (:meth:`from_sparse`) hold padded block-CSR blocks
(``data/sparse.BlockCSR``): each block is its four index/value arrays,
so store bytes scale with nnz — the out-of-core path fits ~1/density
more rows per device budget. Sparse blocks carry static shapes (padding
is free in sparse-land: pad rows are zero-nnz), so ``padded`` only
selects the block's LOGICAL row count; ``block()`` returns a one-block
``BlockCSR`` in place of the dense array.

Fingerprinting lives HERE (the data layer owns content identity);
``repro.service.stats`` re-exports the helpers for backward compatibility.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

ZERO_FINGERPRINT = "0" * 64

_META_NAME = "store_meta.json"


def fingerprint_array(*arrays) -> str:
    """sha256 content fingerprint of host-backed arrays (shape + bytes)."""
    h = hashlib.sha256()
    for a in arrays:
        if a is None:
            h.update(b"none")
            continue
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def combine_fingerprints(fp_a: str, fp_b: str, sign: int = 1) -> str:
    """Commutative, associative, multiplicity-sensitive fold.

    Addition mod 2^256 (not XOR): ingest order cannot matter, but ingesting
    the same block twice must NOT cancel back to the original fingerprint —
    the stats really do contain it twice. ``sign=-1`` is the downdate
    inverse, so retiring a block restores the prior fingerprint exactly.
    """
    return format((int(fp_a, 16) + sign * int(fp_b, 16)) % (1 << 256),
                  "064x")


def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad the leading axis up to ``rows`` (no-op when already there)."""
    k = a.shape[0]
    if k == rows:
        return a
    out = np.zeros((rows,) + a.shape[1:], a.dtype)
    out[:k] = a
    return out


class ShardedMatrixStore:
    """Row-block store for a tall (m, n) design matrix + row-aligned aux.

    Blocks are host ``numpy`` arrays — plain RAM when built with
    :meth:`from_arrays`, read-only memory maps when opened from disk with
    :meth:`open`. The solver never sees more than one block at a time.
    """

    def __init__(self, blocks_D: Sequence,
                 blocks_aux: Optional[Sequence[np.ndarray]],
                 block_rows: int,
                 fingerprints: Sequence[str],
                 path: Optional[str] = None,
                 sparse_meta: Optional[dict] = None):
        if not blocks_D:
            raise ValueError("store needs at least one block")
        if blocks_aux is not None and len(blocks_aux) != len(blocks_D):
            raise ValueError("aux block count != D block count")
        if len(fingerprints) != len(blocks_D):
            raise ValueError("fingerprint count != block count")
        self._blocks_D = list(blocks_D)
        self._blocks_aux = list(blocks_aux) if blocks_aux is not None else None
        self.block_rows = int(block_rows)
        self.fingerprints = list(fingerprints)
        self.path = path
        self.sparse_meta = dict(sparse_meta) if sparse_meta else None
        if self.sparse_meta:
            # blocks are (indices, values, col_indices, col_values) tuples
            self.n = int(self.sparse_meta["n"])
            self.m = int(self.sparse_meta["m"])
            self.dtype = np.dtype(self.sparse_meta["dtype"])
        else:
            self.n = int(blocks_D[0].shape[1])
            self.m = int(sum(b.shape[0] for b in blocks_D))
            self.dtype = np.dtype(blocks_D[0].dtype)

    @property
    def sparse(self) -> bool:
        return self.sparse_meta is not None

    # -- construction -------------------------------------------------------
    @classmethod
    def from_arrays(cls, D, aux=None,
                    block_rows: int = 4096) -> "ShardedMatrixStore":
        """Split host arrays into row blocks (tail unpadded) and fingerprint
        each block once at build time."""
        D = np.asarray(D)
        if D.ndim == 3:                       # node-stacked (N, m_i, n)
            D = D.reshape(-1, D.shape[-1])
        if aux is not None:
            aux = np.asarray(aux).reshape(-1)
            if aux.shape[0] != D.shape[0]:
                raise ValueError(
                    f"aux rows {aux.shape[0]} != D rows {D.shape[0]}")
        m = D.shape[0]
        block_rows = int(min(block_rows, m))
        starts = range(0, m, block_rows)
        blocks_D = [np.ascontiguousarray(D[s:s + block_rows]) for s in starts]
        blocks_aux = (None if aux is None else
                      [np.ascontiguousarray(aux[s:s + block_rows])
                       for s in starts])
        fps = [fingerprint_array(bd, None if blocks_aux is None
                                 else blocks_aux[i])
               for i, bd in enumerate(blocks_D)]
        return cls(blocks_D, blocks_aux, block_rows, fps)

    @classmethod
    def from_sparse(cls, bcsr, aux=None) -> "ShardedMatrixStore":
        """Store a :class:`repro.data.sparse.BlockCSR`: one store block
        per CSR block (``block_rows = bcsr.block_m``), bytes scaling with
        nnz. Fingerprints hash each block's (indices, values, aux) at
        write time, like the dense path."""
        from repro.data.sparse import host_blocks
        idx, val, cidx, cval = host_blocks(bcsr)
        nb = idx.shape[0]
        if aux is not None:
            aux = np.asarray(aux).reshape(-1)
            if aux.shape[0] != bcsr.m:
                raise ValueError(
                    f"aux rows {aux.shape[0]} != D rows {bcsr.m}")
        blocks, blocks_aux, fps = [], [], []
        for k in range(nb):
            blocks.append((np.ascontiguousarray(idx[k]),
                           np.ascontiguousarray(val[k]),
                           np.ascontiguousarray(cidx[k]),
                           np.ascontiguousarray(cval[k])))
            a_b = None
            if aux is not None:
                s = k * bcsr.block_m
                a_b = np.ascontiguousarray(
                    aux[s:s + min(bcsr.block_m, bcsr.m - s)])
                blocks_aux.append(a_b)
            fps.append(fingerprint_array(blocks[-1][0], blocks[-1][1],
                                         a_b))
        meta = {"m": bcsr.m, "n": bcsr.n, "nnz": bcsr.nnz,
                "kp": bcsr.kp, "kc": bcsr.kc,
                "dtype": np.dtype(bcsr.dtype).name}
        return cls(blocks, blocks_aux if aux is not None else None,
                   bcsr.block_m, fps, sparse_meta=meta)

    # -- persistence (memory-mapped reopen) ---------------------------------
    _SPARSE_PARTS = ("idx", "val", "cidx", "cval")

    def save(self, path: str) -> str:
        """Write blocks as .npy files + a JSON manifest; reopen with
        :meth:`open` for memory-mapped (out-of-RAM) access."""
        os.makedirs(path, exist_ok=True)
        for i, b in enumerate(self._blocks_D):
            if self.sparse:
                for part, arr in zip(self._SPARSE_PARTS, b):
                    np.save(os.path.join(path,
                                         f"block_{i:06d}_{part}.npy"), arr)
            else:
                np.save(os.path.join(path, f"block_{i:06d}.npy"), b)
            if self._blocks_aux is not None:
                np.save(os.path.join(path, f"aux_{i:06d}.npy"),
                        self._blocks_aux[i])
        meta = {"m": self.m, "n": self.n, "block_rows": self.block_rows,
                "nblocks": self.nblocks, "dtype": self.dtype.name,
                "has_aux": self._blocks_aux is not None,
                "fingerprints": self.fingerprints,
                "sparse": self.sparse_meta}
        with open(os.path.join(path, _META_NAME), "w") as f:
            json.dump(meta, f, indent=1)
        return path

    @classmethod
    def open(cls, path: str) -> "ShardedMatrixStore":
        """Memory-map a saved store; blocks page in lazily on first touch,
        so opening a multi-terabyte store costs only the manifest read."""
        with open(os.path.join(path, _META_NAME)) as f:
            meta = json.load(f)
        sparse_meta = meta.get("sparse")
        if sparse_meta:
            blocks_D = [tuple(
                np.load(os.path.join(path, f"block_{i:06d}_{part}.npy"),
                        mmap_mode="r") for part in cls._SPARSE_PARTS)
                for i in range(meta["nblocks"])]
        else:
            blocks_D = [np.load(os.path.join(path, f"block_{i:06d}.npy"),
                                mmap_mode="r")
                        for i in range(meta["nblocks"])]
        blocks_aux = None
        if meta["has_aux"]:
            blocks_aux = [np.load(os.path.join(path, f"aux_{i:06d}.npy"),
                                  mmap_mode="r")
                          for i in range(meta["nblocks"])]
        return cls(blocks_D, blocks_aux, meta["block_rows"],
                   meta["fingerprints"], path=path,
                   sparse_meta=sparse_meta)

    # -- block access -------------------------------------------------------
    @property
    def nblocks(self) -> int:
        return len(self._blocks_D)

    @property
    def has_aux(self) -> bool:
        return self._blocks_aux is not None

    @property
    def nbytes(self) -> int:
        if self.sparse:
            return sum(a.nbytes for b in self._blocks_D for a in b)
        return sum(b.nbytes for b in self._blocks_D)

    @property
    def fingerprint(self) -> str:
        """Order-independent fold of the per-block fingerprints — equals the
        fingerprint of ingesting every block through
        ``SufficientStats.update``."""
        fp = ZERO_FINGERPRINT
        for b in self.fingerprints:
            fp = combine_fingerprints(fp, b)
        return fp

    def block_slice(self, k: int) -> slice:
        """Logical row range [start, stop) of block k (tail may be short)."""
        start = k * self.block_rows
        if self.sparse:
            stop = min(start + self.block_rows, self.m)
        else:
            stop = start + self._blocks_D[k].shape[0]
        return slice(start, stop)

    def block(self, k: int, padded: bool = False
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Block k as host arrays. ``padded=True`` zero-pads the tail block
        to the uniform (block_rows, n) shape so every device step compiles
        once — exact, per the zero-row argument above. Sparse stores
        return a one-block :class:`~repro.data.sparse.BlockCSR` whose
        arrays are ALWAYS full-shape (pad rows are zero-nnz); ``padded``
        only selects whether its logical ``m`` is the uniform block_rows
        or the tail's true row count."""
        a_b = self._blocks_aux[k] if self._blocks_aux is not None else None
        if self.sparse:
            from repro.data.sparse import BlockCSR
            idx, val, cidx, cval = self._blocks_D[k]
            sl = self.block_slice(k)
            rows = self.block_rows if padded else sl.stop - sl.start
            # nnz is static pytree aux: it must be block-INDEPENDENT
            # (slot capacity, never an exact count) or the streaming
            # step would retrace per block AND pay a full host scan of
            # the (possibly memory-mapped) values every sweep.
            D_b = BlockCSR(indices=np.asarray(idx)[None],
                           values=np.asarray(val)[None],
                           col_indices=np.asarray(cidx)[None],
                           col_values=np.asarray(cval)[None],
                           m=int(rows), n=self.n,
                           nnz=int(self.block_rows) * int(idx.shape[-1]))
            if padded and a_b is not None and a_b.shape[0] != self.block_rows:
                a_b = _pad_rows(np.asarray(a_b), self.block_rows)
            return D_b, a_b
        D_b = self._blocks_D[k]
        if padded and D_b.shape[0] != self.block_rows:
            D_b = _pad_rows(np.asarray(D_b), self.block_rows)
            if a_b is not None:
                a_b = _pad_rows(np.asarray(a_b), self.block_rows)
        return D_b, a_b

    def verify_block(self, k: int) -> bool:
        """Re-hash block k's CONTENT and compare against its write-time
        fingerprint. The cluster runtime's reassignment path calls this
        before a new owner computes on an orphaned block: ownership
        moves by index, so the fingerprint is what guarantees the
        survivor's store really holds the same rows the dead worker
        held (a stale or torn mmap fails here instead of corrupting the
        solve). Hashes exactly what write time hashed: the UNPADDED
        dense block (or the sparse index/value arrays) plus aux."""
        a_b = self._blocks_aux[k] if self._blocks_aux is not None else None
        if self.sparse:
            idx, val, _, _ = self._blocks_D[k]
            fp = fingerprint_array(np.ascontiguousarray(idx),
                                   np.ascontiguousarray(val), a_b)
        else:
            fp = fingerprint_array(self._blocks_D[k], a_b)
        return fp == self.fingerprints[k]

    def verify_blocks(self, blocks) -> list:
        """Batch :meth:`verify_block`; returns the block indices whose
        content does NOT match (empty = all verified). The elastic-join
        path uses this so a joiner can report every bad block of an
        assignment at once instead of dying on the first."""
        return [int(k) for k in blocks if not self.verify_block(int(k))]

    def iter_blocks(self, padded: bool = False
                    ) -> Iterator[Tuple[np.ndarray, Optional[np.ndarray]]]:
        """The store's contract with the streaming engine: ``(D_block,
        aux_block)`` pairs in row order (aux_block is None for unlabeled
        stores)."""
        for k in range(self.nblocks):
            yield self.block(k, padded=padded)

    def __repr__(self) -> str:
        where = f"mmap:{self.path}" if self.path else "ram"
        kind = (f"sparse nnz={self.sparse_meta['nnz']}, "
                if self.sparse else "")
        return (f"ShardedMatrixStore(m={self.m}, n={self.n}, "
                f"block_rows={self.block_rows}, nblocks={self.nblocks}, "
                f"{kind}dtype={self.dtype.name}, {where})")
