"""FASTA-style forward-backward splitting (Goldstein et al. 2014b/2015).

Solves ``min_x g(x) + J(x)`` with smooth g and proximable J via

    x^{k+1} = prox_J(x^k - t_k grad g(x^k), t_k)

with spectral (Barzilai-Borwein) adaptive stepsizes and a non-monotone
backtracking line search — the single-node solver the paper uses for the
transpose-reduced lasso (§4): after the Gram reduction the whole problem is

    min_x J(x) + 0.5 x^T (D^T D) x - x^T (D^T b)

whose gradient only needs the cached n x n Gram matrix (paper eq. 8).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.prox import soft_threshold

Array = jax.Array


class FastaResult(NamedTuple):
    x: Array
    iters: Array
    objective: Array          # per-iteration g+J telemetry (fixed length)
    residual: Array           # ||x^{k+1}-x^k|| / t_k (prox-gradient residual)


@dataclasses.dataclass(frozen=True)
class Fasta:
    gradg: Callable[[Array], Array]
    g: Callable[[Array], Array]
    proxJ: Callable[[Array, Array], Array]    # (z, t) -> prox_{tJ}(z)
    J: Callable[[Array], Array]
    tol: float = 1e-10                        # on normalized residual
    window: int = 10                          # non-monotone window M
    backtrack_factor: float = 0.5
    max_backtracks: int = 20

    @partial(jax.jit, static_argnames=("self", "iters"))
    def run(self, x0: Array, t0: float, iters: int) -> FastaResult:
        M = self.window

        def fg(x):
            return self.g(x), self.gradg(x)

        f0, g0 = fg(x0)
        fmem0 = jnp.full((M,), f0, x0.dtype)

        def body(carry, k):
            x, gx, fx, fmem, t, done, last_res = carry

            def do_step(_):
                # Candidate step with backtracking against the window max.
                fmax = jnp.max(fmem)

                def bt_cond(state):
                    tt, xn, fn, tries = state
                    # Sufficient decrease wrt the proximal-gradient model.
                    dx = xn - x
                    model = fmax + jnp.vdot(gx, dx) + jnp.sum(dx * dx) / (2 * tt)
                    return (fn > model + 1e-12) & (tries < self.max_backtracks)

                def bt_body(state):
                    tt, _, _, tries = state
                    tt = tt * self.backtrack_factor
                    xn = self.proxJ(x - tt * gx, tt)
                    fn = self.g(xn)
                    return (tt, xn, fn, tries + 1)

                xn0 = self.proxJ(x - t * gx, t)
                fn0 = self.g(xn0)
                tt, xn, fn, _ = jax.lax.while_loop(
                    bt_cond, bt_body, (t, xn0, fn0, jnp.asarray(0))
                )
                gn = self.gradg(xn)
                # Adaptive BB stepsize (steepest-descent / min-residual hybrid).
                dx = xn - x
                dg = gn - gx
                dxdg = jnp.vdot(dx, dg)
                t_s = jnp.where(dxdg > 0, jnp.vdot(dx, dx) / dxdg, tt * 2.0)
                t_m = jnp.where(dxdg > 0, dxdg / jnp.vdot(dg, dg), tt * 2.0)
                t_new = jnp.where(2.0 * t_m > t_s, t_m, t_s - 0.5 * t_m)
                t_new = jnp.where(
                    (t_new <= 0) | ~jnp.isfinite(t_new), tt * 1.5, t_new
                )
                res = jnp.linalg.norm(dx) / jnp.maximum(tt, 1e-30)
                nrm = jnp.maximum(jnp.linalg.norm(gx), 1e-30)
                done_new = res / nrm < self.tol
                fmem_new = fmem.at[k % M].set(fn)
                return (xn, gn, fn, fmem_new, t_new, done_new, res)

            def skip(_):
                return (x, gx, fx, fmem, t, done, last_res)

            carry_new = jax.lax.cond(done, skip, do_step, None)
            xn = carry_new[0]
            obj = carry_new[2] + self.J(xn)
            return carry_new, (obj, carry_new[6], done)

        init = (
            x0,
            g0,
            f0,
            fmem0,
            jnp.asarray(t0, x0.dtype),
            jnp.asarray(False),
            jnp.asarray(jnp.inf, x0.dtype),
        )
        carry, (objs, ress, dones) = jax.lax.scan(body, init, jnp.arange(iters))
        x = carry[0]
        iters_used = jnp.sum(~dones)
        return FastaResult(x, iters_used, objs, ress)


def power_lmax(G: Array, iters: int = 20) -> Array:
    """lambda_max(G) for PSD G by power iteration (the Lipschitz estimate)."""
    n = G.shape[0]
    v = jnp.ones((n,), G.dtype) / jnp.sqrt(n)

    def piter(v, _):
        w = G @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30), None

    v, _ = jax.lax.scan(piter, v, None, length=iters)
    return jnp.maximum(jnp.vdot(v, G @ v), 1e-12)


def transpose_reduction_lasso(
    G: Array, c: Array, mu: float, iters: int = 2000,
    x0: Optional[Array] = None, l2: float = 0.0
) -> FastaResult:
    """Paper §4: solve lasso from cached (D^T D, D^T b) on a single node.

    min_x mu|x| + l2/2||x||^2 + 0.5 x^T G x - x^T c. Gradient = G x - c
    (+ l2 x); Lipschitz constant = lambda_max(G) + l2, estimated by a few
    power iterations for the initial step. ``l2 > 0`` is the elastic net —
    the extra quadratic folds into the smooth part, so the same cached Gram
    serves the whole family.
    """
    n = G.shape[0]
    if x0 is None:
        x0 = jnp.zeros((n,), G.dtype)
    t0 = 1.0 / (power_lmax(G) + l2)

    solver = Fasta(
        gradg=lambda x: G @ x - c + l2 * x,
        g=lambda x: 0.5 * jnp.vdot(x, G @ x) - jnp.vdot(x, c)
                    + 0.5 * l2 * jnp.vdot(x, x),
        proxJ=lambda z, t: soft_threshold(z, t * mu),
        J=lambda x: mu * jnp.sum(jnp.abs(x)),
    )
    return solver.run(x0, t0, iters)


def lasso_mu_max(D2: Array, b: Array) -> Array:
    """Smallest mu for which the lasso solution is exactly 0: ||D^T b||_inf.

    The paper's "10% rule" (§10.1) sets mu = 0.1 * mu_max.
    """
    return jnp.max(jnp.abs(gram_lib.gram_rhs(D2, b)))
