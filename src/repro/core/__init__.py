"""The paper's contribution: unwrapped ADMM with transpose reduction."""
from repro.core.prox import (
    ProxLoss,
    StackedProx,
    make_hinge,
    make_l1,
    make_least_squares,
    make_linf_ball,
    make_logistic,
    make_shifted_least_squares,
    soft_threshold,
)
# NOTE: the submodule is ``repro.core.gram``; we deliberately do not re-export
# the bare ``gram()`` function here so the submodule binding is not shadowed.
from repro.core.gram import (
    gram_and_rhs_chunked,
    gram_chunked,
    gram_factor,
    gram_rhs,
    gram_solve,
)
from repro.core.unwrapped import ADMMResult, UnwrappedADMM
from repro.core.consensus import ConsensusLasso, ConsensusLogistic, ConsensusSVM
from repro.core.fasta import Fasta, lasso_mu_max, transpose_reduction_lasso
from repro.core.distributed import DistributedUnwrappedADMM, shard_rows
from repro.core.fit import FitResult, fit

__all__ = [
    "ProxLoss", "StackedProx", "make_hinge", "make_l1", "make_least_squares",
    "make_linf_ball", "make_logistic", "make_shifted_least_squares",
    "soft_threshold", "gram_and_rhs_chunked", "gram_chunked",
    "gram_factor", "gram_rhs", "gram_solve", "ADMMResult", "UnwrappedADMM",
    "ConsensusLasso", "ConsensusLogistic", "ConsensusSVM", "Fasta",
    "lasso_mu_max", "transpose_reduction_lasso", "DistributedUnwrappedADMM",
    "shard_rows", "FitResult", "fit",
]
