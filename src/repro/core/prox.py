"""Proximal operators and loss objects — the paper's y-update building blocks.

Every separable term ``f`` used by unwrapped ADMM (paper Alg. 1/2) is bundled
as a :class:`ProxLoss`: the loss value ``f(z)``, its proximal map
``prox_f(z, delta) = argmin_y f(y) + ||y - z||^2 / (2 delta)`` and, when f is
differentiable, its gradient (used for Theorem-2 diagnostics and oracles).

All maps are coordinate-wise separable (paper §5: "the minimization in Line 4
is coordinate-wise decoupled") and fully vectorized — on TPU the fused Pallas
kernel in ``repro.kernels.prox`` evaluates the same maps in a single VMEM pass.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProxLoss:
    """A separable convex term f with a proximal map.

    Attributes:
      name: identifier used by kernels/config.
      value: ``f(z, aux) -> scalar`` (sum over coordinates).
      prox: ``prox(z, delta, aux) -> y`` with delta the prox weight (tau^-1).
      grad: coordinate-wise gradient (None for non-smooth terms).
      lipschitz: Lipschitz constant of grad (paper: logistic = 1/4).
      coordinatewise: True when prox acts per-coordinate with per-row aux —
        the property the iteration engine needs to stream arbitrary row
        blocks (DESIGN.md §8). StackedProx is position-dependent and sets
        this False, forcing the reference backend.
      kernel_delta_scale: the Pallas prox kernel evaluates the BARE map for
        ``name`` at a static delta; losses that fold a weight into their
        prox (hinge absorbs C: prox_{C h}(z, d) = prox_h(z, C d)) record it
        here so the engine passes delta * scale to the kernel.
      kernel_param: extra static shape parameter the kernel prox needs
        beyond delta (quantile level q); 0.0 for parameter-free kinds.
      ycols: columns of the splitting variable y (and of x). 1 for scalar-
        response losses; K for multinomial logistic, whose iterates are
        (m, K) matrices flowing through the same multi-RHS Gram machinery.
      spec: picklable ``{"name": ..., **params}`` rebuilding this loss via
        :func:`loss_from_spec` — how the cluster runtime ships losses
        across process boundaries (closures don't pickle).
    """

    name: str
    value: Callable[[Array, Optional[Array]], Array]
    prox: Callable[[Array, Array, Optional[Array]], Array]
    grad: Optional[Callable[[Array, Optional[Array]], Array]] = None
    lipschitz: Optional[float] = None
    coordinatewise: bool = True
    kernel_delta_scale: float = 1.0
    kernel_param: float = 0.0
    ycols: int = 1
    # compare=False keeps the frozen dataclass hashable (dict field):
    # spec is serialization metadata, not solver identity — engines key
    # jit/lru caches on the loss and must not hash the dict.
    spec: Optional[dict] = dataclasses.field(default=None, compare=False)


# ---------------------------------------------------------------------------
# Elementary maps
# ---------------------------------------------------------------------------

def soft_threshold(z: Array, thresh) -> Array:
    """prox of ``thresh * |.|`` — the lasso shrink (Tibshirani 1994)."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thresh, 0.0)


def project_linf(z: Array, radius) -> Array:
    """Projection onto the l-inf ball (dual lasso constraint, paper §7.1)."""
    return jnp.clip(z, -radius, radius)


def logistic_prox_newton(z: Array, delta, labels: Array,
                         bisect_iters: int = 40,
                         newton_iters: int = 3) -> Array:
    """prox of the logistic NLL ``log(1 + exp(-l*y))``.

    The paper suggests a precomputed lookup table; on the TPU VPU a
    vectorized, branch-free root-find is cheaper than a gather (DESIGN.md
    §3). phi'(y) = -l*sigmoid(-l y) + (y-z)/d is strictly increasing with a
    guaranteed sign change on [z-d, z+d] (|sigmoid| <= 1), so we bisect the
    bracket (undamped Newton OSCILLATES here for large d: the sigmoid tails
    are flat, curvature ~ 1/d, and steps of size ~d overshoot the root
    back and forth) and polish with a few safe Newton steps.
    """
    delta = jnp.asarray(delta, z.dtype)

    def dphi(y):
        return -labels * jax.nn.sigmoid(-labels * y) + (y - z) / delta

    lo = z - delta
    hi = z + delta

    def bis(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        pos = dphi(mid) > 0
        return (jnp.where(pos, lo, mid), jnp.where(pos, mid, hi)), None

    (lo, hi), _ = jax.lax.scan(bis, (lo, hi), None, length=bisect_iters)
    y = 0.5 * (lo + hi)

    def newton(y, _):
        s = jax.nn.sigmoid(-labels * y)
        g = -labels * s + (y - z) / delta
        h = s * (1.0 - s) + 1.0 / delta
        step = g / h
        # clamp into the bracket-sized trust region for safety
        step = jnp.clip(step, -delta, delta)
        return y - step, None

    y, _ = jax.lax.scan(newton, y, None, length=newton_iters)
    return y


def hinge_prox(z: Array, delta, labels: Array) -> Array:
    """prox of the hinge loss sum_k max(1 - l_k z_k, 0)  (paper §6.2).

    prox_h(z, d)_k = z_k + l_k * max(min(1 - l_k z_k, d), 0)
    """
    return z + labels * jnp.maximum(jnp.minimum(1.0 - labels * z, delta), 0.0)


# ---------------------------------------------------------------------------
# ProxLoss instances
# ---------------------------------------------------------------------------

def make_logistic(labels_required: bool = True) -> ProxLoss:
    """Paper §6.1 — logistic regression loss f_lr(z) = sum log(1+exp(-l z))."""

    def value(z, aux):
        # log(1+exp(-lz)) computed stably via softplus.
        return jnp.sum(jax.nn.softplus(-aux * z))

    def prox(z, delta, aux):
        return logistic_prox_newton(z, delta, aux)

    def grad(z, aux):
        return -aux * jax.nn.sigmoid(-aux * z)

    return ProxLoss("logistic", value, prox, grad, lipschitz=0.25)


def make_hinge(C: float = 1.0) -> ProxLoss:
    """Paper §6.2 — SVM hinge term C * h(z). The prox weight absorbs C:
    prox_{C h}(z, d) = prox_h(z, C d)."""

    def value(z, aux):
        return C * jnp.sum(jnp.maximum(1.0 - aux * z, 0.0))

    def prox(z, delta, aux):
        return hinge_prox(z, C * delta, aux)

    return ProxLoss("hinge", value, prox, grad=None, lipschitz=None,
                    kernel_delta_scale=C)


def make_l1(mu: float) -> ProxLoss:
    """mu * |z| — the sparsity block of paper §7 (rows of D_hat = I)."""

    def value(z, aux):
        return mu * jnp.sum(jnp.abs(z))

    def prox(z, delta, aux):
        return soft_threshold(z, mu * delta)

    return ProxLoss("l1", value, prox, grad=None, lipschitz=None,
                    kernel_delta_scale=mu)


def make_least_squares() -> ProxLoss:
    """0.5 * ||z - b||^2 with b passed as aux (lasso residual block)."""

    def value(z, aux):
        return 0.5 * jnp.sum((z - aux) ** 2)

    def prox(z, delta, aux):
        delta = jnp.asarray(delta, z.dtype)
        return (z + delta * aux) / (1.0 + delta)

    def grad(z, aux):
        return z - aux

    return ProxLoss("least_squares", value, prox, grad, lipschitz=1.0)


def make_huber(delta: float = 1.0) -> ProxLoss:
    """Huber loss sum_k h_delta(z_k - b_k) with b passed as aux.

    h_delta(r) = r^2/2 for |r| <= delta, delta(|r| - delta/2) beyond — the
    robust-regression data term. The prox is closed form: shrink the
    residual r0 = z - b by 1/(1+d) in the quadratic region, shift it by
    d*delta toward zero in the linear (outlier) region; the two branches
    agree at |r0| = delta (1 + d).
    """

    def value(z, aux):
        r = z - aux
        a = jnp.abs(r)
        return jnp.sum(
            jnp.where(a <= delta, 0.5 * r * r, delta * (a - 0.5 * delta))
        )

    def prox(z, d, aux):
        d = jnp.asarray(d, z.dtype)
        r0 = z - aux
        r = jnp.where(
            jnp.abs(r0) <= delta * (1.0 + d),
            r0 / (1.0 + d),
            r0 - d * delta * jnp.sign(r0),
        )
        return aux + r

    def grad(z, aux):
        return jnp.clip(z - aux, -delta, delta)

    return ProxLoss("huber", value, prox, grad, lipschitz=1.0)


def make_quantile(q: float = 0.5) -> ProxLoss:
    """Pinball (quantile) loss sum_k rho_q(z_k - b_k) with b passed as aux.

    rho_q(r) = q*r for r >= 0, (q-1)*r for r < 0 — quantile regression at
    level q (q=0.5 is LAD / median regression). The prox is a two-sided
    asymmetric soft-threshold on the residual r0 = z - b: shift by d*q
    from above, by d*(1-q) from below, dead-zone to exactly b between.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile level must be in (0, 1), got {q}")

    def value(z, aux):
        r = z - aux
        return jnp.sum(jnp.where(r >= 0, q * r, (q - 1.0) * r))

    def prox(z, d, aux):
        d = jnp.asarray(d, z.dtype)
        r0 = z - aux
        r = jnp.where(r0 > d * q, r0 - d * q,
                      jnp.where(r0 < -d * (1.0 - q), r0 + d * (1.0 - q),
                                0.0))
        return aux + r

    return ProxLoss("quantile", value, prox, grad=None, lipschitz=None,
                    kernel_delta_scale=1.0, kernel_param=float(q),
                    spec={"name": "quantile", "q": float(q)})


def multinomial_prox_newton(z: Array, delta, labels: Array,
                            newton_iters: int = 12) -> Array:
    """Row-wise prox of the multinomial (softmax cross-entropy) NLL.

    For each row: argmin_y logsumexp(y) - y_c + ||y - z||^2 / (2 delta).
    The Hessian of the objective is H = diag(p) - p p^T + I/delta with
    p = softmax(y), so each Newton solve is Sherman-Morrison against the
    diagonal A = diag(p + 1/delta):

        H^{-1} g = A^{-1} g + A^{-1} p (p^T A^{-1} g) / (1 - p^T A^{-1} p)

    and the denominator is positive (p^T A^{-1} p < sum p_k = 1). Since
    the CE gradient is bounded by 1 per coordinate, the minimizer lies in
    ``|y - z| <= delta`` — steps are clipped to that trust region, which
    keeps the undamped iteration from overshooting at large delta.
    """
    delta = jnp.asarray(delta, z.dtype)
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), z.shape[-1],
                            dtype=z.dtype)

    def newton(y, _):
        p = jax.nn.softmax(y, axis=-1)
        g = p - onehot + (y - z) / delta
        a = p + 1.0 / delta
        u = g / a
        t = jnp.sum(p * u, axis=-1, keepdims=True) / (
            1.0 - jnp.sum(p * p / a, axis=-1, keepdims=True))
        step = u + (p / a) * t
        return y - jnp.clip(step, -delta, delta), None

    y, _ = jax.lax.scan(newton, z, None, length=newton_iters)
    return y


def make_multinomial(classes: int) -> ProxLoss:
    """Multinomial logistic (softmax cross-entropy) over K classes.

    The splitting variable y and the solution x are (rows, K) matrices:
    z_row = D_row @ x gives per-class scores, aux holds integer class
    labels in [0, K). Everything downstream reuses the multi-RHS Gram
    machinery — d/w/v become (n, K) stacked right-hand sides.
    """
    if classes < 2:
        raise ValueError(f"multinomial needs >= 2 classes, got {classes}")

    def value(z, aux):
        lab = aux.astype(jnp.int32)
        lse = jax.nn.logsumexp(z, axis=-1)
        picked = jnp.take_along_axis(z, lab[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    def prox(z, delta, aux):
        return multinomial_prox_newton(z, delta, aux)

    def grad(z, aux):
        onehot = jax.nn.one_hot(aux.astype(jnp.int32), z.shape[-1],
                                dtype=z.dtype)
        return jax.nn.softmax(z, axis=-1) - onehot

    return ProxLoss("multinomial", value, prox, grad, lipschitz=0.5,
                    coordinatewise=False, ycols=int(classes),
                    spec={"name": "multinomial", "classes": int(classes)})


def group_soft_threshold(z: Array, thresh, groups: Array,
                         num_groups: int) -> Array:
    """prox of ``thresh * sum_g ||z_g||_2`` — the group-lasso shrink.

    ``groups`` maps each coordinate to its group id in [0, num_groups);
    each group's subvector is scaled by max(0, 1 - thresh/||z_g||) —
    whole groups hit exactly zero together (Yuan & Lin 2006).
    """
    nrm = jnp.sqrt(jax.ops.segment_sum(z * z, groups,
                                       num_segments=num_groups))
    scale = jnp.where(nrm > thresh,
                      1.0 - thresh / jnp.maximum(nrm, 1e-30), 0.0)
    return z * scale[groups]


def loss_from_spec(spec: dict) -> ProxLoss:
    """ProxLoss from a picklable ``{"name": ..., **params}`` spec — the one
    factory both the cluster coordinator and its workers use, so a loss
    built on either side of a process boundary is identical."""
    name = spec["name"]
    if name == "logistic":
        loss = make_logistic()
    elif name == "hinge":
        loss = make_hinge(float(spec.get("C", 1.0)))
    elif name == "least_squares":
        loss = make_least_squares()
    elif name == "l1":
        loss = make_l1(float(spec.get("mu", 1.0)))
    elif name == "huber":
        loss = make_huber(float(spec.get("delta", 1.0)))
    elif name == "quantile":
        loss = make_quantile(float(spec.get("q", 0.5)))
    elif name == "multinomial":
        loss = make_multinomial(int(spec["classes"]))
    else:
        raise ValueError(f"unknown loss spec {name!r}")
    return dataclasses.replace(loss, spec=dict(spec))


def project_nonneg(z: Array) -> Array:
    """Projection onto the nonnegative orthant (NNLS constraint)."""
    return jnp.maximum(z, 0.0)


def make_linf_ball(radius: float) -> ProxLoss:
    """Characteristic function of the l-inf ball (dual lasso, paper §7.1)."""

    def value(z, aux):
        # Indicator: 0 inside (we report violation magnitude for diagnostics).
        return jnp.asarray(0.0, z.dtype)

    def prox(z, delta, aux):
        return project_linf(z, radius)

    return ProxLoss("linf_ball", value, prox, grad=None, lipschitz=None)


def make_shifted_least_squares() -> ProxLoss:
    """0.5 * ||z + b||^2 — the dual-lasso data block f*(alpha) (paper §7.1)."""

    def value(z, aux):
        return 0.5 * jnp.sum((z + aux) ** 2)

    def prox(z, delta, aux):
        delta = jnp.asarray(delta, z.dtype)
        return (z - delta * aux) / (1.0 + delta)

    def grad(z, aux):
        return z + aux

    return ProxLoss("shifted_least_squares", value, prox, grad, lipschitz=1.0)


@dataclasses.dataclass(frozen=True)
class StackedProx:
    """Blockwise f-hat for the sparse formulation (paper §7).

    f_hat(z)_k = mu |z_k| for k < n (identity block), f(z_k) for k >= n.
    ``sizes`` are the block lengths in stacking order; each block has its own
    ProxLoss and aux array. Used for D_hat = [I; D] and the dual column-split.
    """

    blocks: Tuple[ProxLoss, ...]
    sizes: Tuple[int, ...]

    def _split(self, z: Array):
        out, off = [], 0
        for s in self.sizes:
            out.append(jax.lax.dynamic_slice_in_dim(z, off, s, axis=z.ndim - 1))
            off += s
        return out

    def value(self, z: Array, aux) -> Array:
        parts = self._split(z)
        auxs = self._split(aux) if aux is not None else [None] * len(parts)
        return sum(b.value(p, a) for b, p, a in zip(self.blocks, parts, auxs))

    def prox(self, z: Array, delta, aux) -> Array:
        parts = self._split(z)
        auxs = self._split(aux) if aux is not None else [None] * len(parts)
        return jnp.concatenate(
            [b.prox(p, delta, a) for b, p, a in zip(self.blocks, parts, auxs)],
            axis=z.ndim - 1,
        )

    def as_loss(self, name: str = "stacked") -> ProxLoss:
        # Position-dependent prox: row k's map depends on which block k
        # falls in, so the engine may not stream arbitrary row chunks.
        return ProxLoss(name, self.value, self.prox, grad=None,
                        lipschitz=None, coordinatewise=False)


LOSSES = {
    "logistic": make_logistic,
    "hinge": make_hinge,
    "huber": make_huber,
    "l1": make_l1,
    "least_squares": make_least_squares,
    "linf_ball": make_linf_ball,
    "shifted_least_squares": make_shifted_least_squares,
    "quantile": make_quantile,
    "multinomial": make_multinomial,
}
