"""Paper §7.1 — splitting over COLUMNS via the dual.

When D is wide (m << n) and nodes store column blocks, the lasso
    min_x 0.5||Dx - b||^2 + mu|x|
is solved through its dual
    min_alpha 0.5||alpha + b||^2   s.t.  ||D^T alpha||_inf <= mu
with unwrapped ADMM on D_hat = [I; D^T] and
    f_hat = [ 0.5||. + b||^2 (m rows) ; X_{|.| <= mu} (n rows) ].
Each node forms D_i D_i^T (not D_i^T D_i): the Gram reduction is over
column blocks, sum_i D_i D_i^T, an m x m matrix — the transpose-reduction
trick mirrored. The primal solution is recovered from the scaled multiplier
of the constraint rows: x* = tau * lambda_2 (verified in tests against the
row-split §4 solution).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.prox import (
    StackedProx,
    make_linf_ball,
    make_shifted_least_squares,
)
from repro.core.unwrapped import UnwrappedADMM

Array = jax.Array


class ColumnSplitResult(NamedTuple):
    x: Array          # primal lasso solution (n,)
    alpha: Array      # dual optimum (m,)
    iters: int


def lasso_column_split(D_cols: Array, b: Array, mu: float, tau: float = 1.0,
                       iters: int = 800) -> ColumnSplitResult:
    """D_cols: (N, m, n_i) — N nodes each holding n_i columns; b: (m,).

    Emulated-nodes layout (matches the row-split solvers' convention); the
    distributed version reduces sum_i D_i D_i^T with one psum exactly like
    repro.core.distributed does for D_i^T D_i.
    """
    N, m, n_i = D_cols.shape
    n = N * n_i
    Dflat = jnp.concatenate([D_cols[i] for i in range(N)], axis=1)  # (m, n)
    # D_hat = [I_m ; D^T]: stacked operator applied to alpha in R^m.
    D_hat = jnp.concatenate([jnp.eye(m, dtype=Dflat.dtype), Dflat.T], 0)[None]
    sp = StackedProx(
        blocks=(make_shifted_least_squares(), make_linf_ball(mu)),
        sizes=(m, n),
    )
    aux = jnp.concatenate([b, jnp.zeros((n,), b.dtype)])[None]
    solver = UnwrappedADMM(loss=sp.as_loss("dual_lasso"), tau=tau)
    res = solver.run(D_hat, aux, iters=iters)
    alpha = res.x
    # Multiplier of the ||D^T alpha||_inf <= mu rows, scaled by -tau, is the
    # primal x (sign from the convention lam <- lam + D_hat a - y; verified
    # against the §4 row-split solution: alpha* = D x* - b).
    lam2 = res.lam[0, m:]
    x = -tau * lam2
    return ColumnSplitResult(x, alpha, int(res.iters))
