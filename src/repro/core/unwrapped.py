"""Unwrapped ADMM with transpose reduction — paper Algorithms 1 & 2.

Solves ``min_x rho/2 ||x||^2 + f(Dx)`` (rho=0 for plain ``min f(Dx)``) by
splitting ``y = Dx``:

    x^{k+1} = argmin_x rho/2||x||^2 + tau/2 ||Dx - y^k + lam^k||^2
            = (D^T D + (rho/tau) I)^{-1} D^T (y^k - lam^k)          (global LS)
    y^{k+1} = prox_f(D x^{k+1} + lam^k, 1/tau)                      (separable)
    lam^{k+1} = lam^k + D x^{k+1} - y^{k+1}

The x-update is the transpose-reduction step: only ``d = sum_i D_i^T(y_i -
lam_i)`` crosses the network (an n-vector), and the n x n Gram factor is
computed once at setup from ``sum_i D_i^T D_i`` (paper Alg. 2 lines 2-3).

The per-iteration body lives in :mod:`repro.engine` (DESIGN.md §8): the
drivers here carry ``(y, lam, d = D^T(y-lam), x)`` and call
``engine.iterate`` once per iteration — ONE streaming pass over D instead
of the textbook two (d-reduction pass + Dx pass). The engine accumulates
the stopping-rule reductions w = D^T(y^{k+1}-y^k) and v = D^T lam^{k+1}
in the same stream, and the remaining residual quantities are elementwise:

    Dx  = lam^{k+1} - lam^k + y^{k+1}
    r   = ||Dx - y^{k+1}|| = ||lam^{k+1} - lam^k||
    s   = tau ||w||,   eps_dual ~ tau ||v||

Data layout: ``D`` is ``(N, m_i, n)`` — N nodes, m_i rows each. N=1 recovers
the single-node Alg. 1. This module is the *reference semantics*; the
multi-device version (``repro.core.distributed``) runs the same engine body
per shard under ``shard_map`` with a psum where this module sums over rows.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.prox import ProxLoss
from repro.data.sparse import BlockCSR

Array = jax.Array


class ADMMHistory(NamedTuple):
    """Per-iteration telemetry (paper Fig. 2 curves + Theorems 1/2 checks)."""

    objective: Array      # f(Dx^k) (+ rho/2||x||^2)
    primal_res: Array     # ||D x^k - y^k||
    dual_res: Array       # tau * ||D^T (y^k - y^{k-1})||  (Boyd dual residual)
    grad_sq: Array        # ||D^T grad f(D x^k)||^2 if f smooth else nan
    converged_at: Array   # first iteration k meeting Boyd's stopping rule


class ADMMResult(NamedTuple):
    x: Array
    y: Array
    lam: Array
    iters: Array                 # iterations actually informative (stop point)
    history: Optional[ADMMHistory]


@dataclasses.dataclass(frozen=True)
class UnwrappedADMM:
    """Configured solver. ``loss`` acts on y with per-row aux (labels / b).

    ``backend`` / ``residency`` select the engine hot path (DESIGN.md §8):
    "auto" picks the fused Pallas kernel on TPU and the chunked lax.scan
    stream elsewhere; ``residency="bf16"`` keeps the iteration copy of D in
    bf16 (f32 accumulation) to halve the per-iteration HBM bytes again.
    """

    loss: ProxLoss
    tau: float = 1.0
    rho: float = 0.0              # ridge g(x) = rho/2 ||x||^2 (SVM: rho=1)
    eps_rel: float = 1e-3         # paper §9 stopping constants
    eps_abs: float = 1e-6
    gram_block_rows: Optional[int] = None   # None -> engine autotune;
                                            # set to bound setup memory
    backend: str = "auto"         # engine backend (reference | chunked |
                                  # pallas | pallas_interpret | auto)
    residency: Optional[str] = None   # None | "bf16" iteration data dtype

    @property
    def engine(self):
        # Imported lazily: repro.engine imports repro.core.gram, whose
        # package __init__ imports this module — a module-level import
        # here would be circular.
        from repro.engine import IterationEngine
        return IterationEngine(loss=self.loss, tau=self.tau,
                               backend=self.backend,
                               residency=self.residency)

    # -- setup (Alg. 2 lines 2-3): one Gram all-reduce + one factorization --
    def setup(self, D: Array) -> Array:
        N, mi, n = D.shape
        G, _ = self.engine.gram(D.reshape(N * mi, n),
                                block_rows=self.gram_block_rows)
        ridge = self.rho / self.tau
        return gram_lib.gram_factor(G, ridge=ridge)

    # -- one iteration (Alg. 2 lines 5-8), reference-shaped API -------------
    def step(self, L: Array, D: Array, aux: Optional[Array], y: Array,
             lam: Array):
        """Single step on node-stacked arrays — the oracle surface kernel
        tests compare against; the drivers below inline the same engine
        body around a carried ``d`` instead of recomputing it."""
        N, mi, n = D.shape
        eng = self.engine
        Dflat = D.reshape(N * mi, n)
        d = eng.transpose_d(Dflat, y.reshape(-1), lam.reshape(-1))
        x = gram_lib.gram_solve(L, d)
        st = eng.iterate(Dflat, aux.reshape(-1) if aux is not None else None,
                         y.reshape(-1), lam.reshape(-1), x, want_dual=False)
        Dx = st.lam - lam.reshape(-1) + st.y
        return (x, Dx.reshape(N, mi), st.y.reshape(N, mi),
                st.lam.reshape(N, mi))

    def _objective(self, x, Dx, aux_flat):
        obj = self.loss.value(Dx, aux_flat)
        if self.rho:
            obj = obj + 0.5 * self.rho * jnp.sum(x * x)
        return obj

    def _residuals_tolerances(self, st, lam, m, n):
        """All of Boyd's stopping quantities from the engine's same-pass
        reductions — no extra pass over D (module docstring identities)."""
        Dx = st.lam - lam + st.y
        r = jnp.linalg.norm(st.lam - lam)                 # ||Dx - y_new||
        s = self.tau * jnp.linalg.norm(st.w)
        eps_pri = jnp.sqrt(m) * self.eps_abs + self.eps_rel * jnp.maximum(
            jnp.linalg.norm(Dx), jnp.linalg.norm(st.y))
        eps_dual = jnp.sqrt(n * 1.0) * self.eps_abs + (
            self.eps_rel * self.tau * jnp.linalg.norm(st.v))
        return Dx, r, s, eps_pri, eps_dual

    def _init_state(self, Dflat, x0, m, n, acc):
        if x0 is not None:
            # Warm start (the serving layer's repeated solves): seed the
            # split variable at y = D x0, so the first x-update returns
            # (D^T D + rI)^{-1} D^T D x0 — exactly x0 when rho = 0. One
            # extra setup-time pass builds the carried reduction.
            y = Dflat.astype(acc) @ x0.astype(acc)
            lam = jnp.zeros((m,), acc)
            d = self.engine.transpose_d(Dflat, y, lam)
        else:
            y = jnp.zeros((m,), acc)
            lam = jnp.zeros((m,), acc)
            d = jnp.zeros((n,), acc)
        return y, lam, d

    # -- fixed-iteration driver with full telemetry (lax.scan) --
    def run(
        self,
        D,
        aux: Optional[Array],
        iters: int,
        x0: Optional[Array] = None,
        record: bool = True,
        obs=None,
    ) -> ADMMResult:
        """``D`` is node-stacked dense (N, m_i, n) or a flat
        :class:`BlockCSR` (sparse solves return y/lam as (1, m)).

        ``obs`` (:class:`repro.obs.Observability`) is handled entirely
        OUTSIDE the jitted driver: one span around the dispatch, then the
        recorded :class:`ADMMHistory` is streamed to the telemetry sink
        post-hoc — the scan body never sees a host callback."""
        if obs is None or not obs.enabled:
            if isinstance(D, BlockCSR):
                return self._run_sparse(D, aux, iters, x0=x0, record=record)
            return self._run_dense(D, aux, iters, x0, record)
        with obs.span("admm_run", iters=iters, sparse=isinstance(D, BlockCSR)):
            if isinstance(D, BlockCSR):
                res = self._run_sparse(D, aux, iters, x0=x0, record=record)
            else:
                res = self._run_dense(D, aux, iters, x0, record)
            jax.block_until_ready(res.x)
        obs.inc("admm.runs")
        if res.history is not None:
            obs.write_history(res.history, tau=self.tau, rho=self.rho)
        return res

    @partial(jax.jit, static_argnames=("self", "iters", "record"))
    def _run_dense(
        self,
        D: Array,
        aux: Optional[Array],
        iters: int,
        x0: Optional[Array] = None,
        record: bool = True,
    ) -> ADMMResult:
        N, mi, n = D.shape
        m = N * mi
        acc = gram_lib._acc_dtype(D.dtype)
        eng = self.engine
        Dflat = D.reshape(m, n)
        L = self.setup(D)
        Dres = eng.prepare(Dflat)
        aux_f = aux.reshape(m) if aux is not None else None
        y, lam, d = self._init_state(Dflat, x0, m, n, acc)

        def body(carry, _):
            y, lam, d, _, k_conv, k = carry
            x = gram_lib.gram_solve(L, d)
            st = eng.iterate(Dres, aux_f, y, lam, x, want_dual=True)
            Dx, r, s, eps_pri, eps_dual = self._residuals_tolerances(
                st, lam, m, n)
            done = (r <= eps_pri) & (s <= eps_dual)
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            obj = self._objective(x, Dx, aux_f)
            if record and self.loss.grad is not None:
                # Theorem 2 diagnostic: ||d/dx f(Dx^k)||^2 = ||D^T grad f||^2.
                # The one telemetry quantity that is not derivable from the
                # carried n-vectors; costs an extra pass, so it only runs on
                # the recording driver (solve(), the hot path, never pays).
                # Routed through the engine's streaming rmatvec: the dense
                # ``Dflat.astype(acc).T @ g`` would materialize a full
                # accumulation-precision copy of D every iteration on
                # streaming-class backends.
                g = self.loss.grad(Dx, aux_f)
                gsq = jnp.sum(eng.rmatvec(Dflat, g) ** 2)
            else:
                gsq = jnp.asarray(jnp.nan, acc)
            hist = (obj, r, s, gsq)
            return (st.y, st.lam, st.d, x, k_conv, k + 1), hist

        init = (y, lam, d, jnp.zeros((n,), acc),
                jnp.asarray(-1, jnp.int32), jnp.asarray(0, jnp.int32))
        (y, lam, d, x, k_conv, _), hist = jax.lax.scan(
            body, init, None, length=iters)
        objs, rs, ss, gsqs = hist
        history = (
            ADMMHistory(objs, rs, ss, gsqs, k_conv) if record else None
        )
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ADMMResult(x, y.reshape(N, mi), lam.reshape(N, mi),
                          iters_used, history)

    # -- early-stopping driver, deployment path -----------------------------
    def solve(
        self, D, aux: Optional[Array], max_iters: int = 500,
        x0: Optional[Array] = None, record: bool = False,
        reg=None, checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0, resume: bool = False, obs=None,
    ) -> ADMMResult:
        """``D`` is node-stacked dense (N, m_i, n) or a flat
        :class:`BlockCSR`. Runs through the shared executor driver
        (DESIGN.md §14) on a :class:`repro.exec.LocalExecutor` — the
        same stopping rule / warm start / checkpoint code path every
        other topology uses. ``reg`` (a :class:`repro.exec.Regularizer`)
        switches the x-update to the composite prox-gradient."""
        from repro.exec import LocalExecutor, solve_with_executor
        ex = LocalExecutor(self.engine, D, aux=aux,
                           gram_block_rows=self.gram_block_rows)

        def _drive(obs_arg):
            return solve_with_executor(
                ex, loss=self.loss, tau=self.tau, rho=self.rho,
                eps_rel=self.eps_rel, eps_abs=self.eps_abs,
                max_iters=max_iters, x0=x0, record=record, reg=reg,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every, resume=resume,
                obs=obs_arg)

        if obs is None or not obs.enabled:
            return _drive(None)
        with obs.span("admm_solve", max_iters=max_iters,
                      sparse=isinstance(D, BlockCSR)):
            res = _drive(obs)
            jax.block_until_ready(res.x)
        obs.inc("admm.solves")
        obs.record(event="solve_done", iters=int(res.iters),
                   tau=self.tau, rho=self.rho)
        return res

    # -- sparse drivers: same semantics over a BlockCSR ---------------------
    # The Gram setup is a HOST pass for sparse data (the O(nnz) gram has
    # no fast XLA lowering — kernels/spgram/ops.py), so these drivers
    # factor L outside the jitted loop and hand it in; the per-iteration
    # body, stopping rule, telemetry and warm-start semantics are the
    # dense drivers' own, through the engine's sparse backend.

    def _sparse_setup(self, D: BlockCSR) -> Array:
        G, _ = self.engine.gram(D)
        return gram_lib.gram_factor(G, ridge=self.rho / self.tau)

    def _sparse_init(self, D: BlockCSR, x0, m, n, acc):
        from repro.kernels.spgram import ops as spgram_ops
        if x0 is not None:
            y = spgram_ops.matvec(D, x0.astype(acc))
            lam = jnp.zeros((m,), acc)
            d = self.engine.transpose_d(D, y, lam)
        else:
            y = jnp.zeros((m,), acc)
            lam = jnp.zeros((m,), acc)
            d = jnp.zeros((n,), acc)
        return y, lam, d

    def _run_sparse(self, D: BlockCSR, aux, iters, x0=None, record=True):
        L = self._sparse_setup(D)
        return self._run_sparse_jit(D, aux, L, iters, x0, record)

    @partial(jax.jit, static_argnames=("self", "iters", "record"))
    def _run_sparse_jit(self, D: BlockCSR, aux, L, iters, x0, record):
        m, n = D.m, D.n
        acc = gram_lib._acc_dtype(D.dtype)
        eng = self.engine
        Dres = eng.prepare(D)
        aux_f = aux.reshape(m) if aux is not None else None
        y, lam, d = self._sparse_init(D, x0, m, n, acc)

        def body(carry, _):
            y, lam, d, _, k_conv, k = carry
            x = gram_lib.gram_solve(L, d)
            st = eng.iterate(Dres, aux_f, y, lam, x, want_dual=True)
            Dx, r, s, eps_pri, eps_dual = self._residuals_tolerances(
                st, lam, m, n)
            done = (r <= eps_pri) & (s <= eps_dual)
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            obj = self._objective(x, Dx, aux_f)
            if record and self.loss.grad is not None:
                g = self.loss.grad(Dx, aux_f)
                gsq = jnp.sum(eng.rmatvec(D, g) ** 2)
            else:
                gsq = jnp.asarray(jnp.nan, acc)
            hist = (obj, r, s, gsq)
            return (st.y, st.lam, st.d, x, k_conv, k + 1), hist

        init = (y, lam, d, jnp.zeros((n,), acc),
                jnp.asarray(-1, jnp.int32), jnp.asarray(0, jnp.int32))
        (y, lam, d, x, k_conv, _), hist = jax.lax.scan(
            body, init, None, length=iters)
        objs, rs, ss, gsqs = hist
        history = (
            ADMMHistory(objs, rs, ss, gsqs, k_conv) if record else None
        )
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ADMMResult(x, y[None], lam[None], iters_used, history)

    # -- out-of-core driver: D streams from a host/disk block store --------
    def solve_streaming(
        self, store, max_iters: int = 500, x0: Optional[Array] = None,
        record: bool = False, overlap: bool = True, prefetch: int = 2,
        device_dtype: Optional[str] = None,
        checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
        resume: bool = False, obs=None,
    ) -> ADMMResult:
        """``solve`` for data that does not fit device memory: ``store``
        is a :class:`repro.data.store.ShardedMatrixStore` (host RAM or
        memory-mapped) and every pass — Gram setup, each iteration's
        fused body — streams one row block at a time with double-buffered
        host→device transfers (DESIGN.md §9). The m-sized iterates
        (y, lam) persist to host per block, so device memory is bounded
        by one block regardless of m. Same stopping rule and warm-start
        semantics as ``solve``; ``overlap=False`` degrades to the naive
        synchronous transfer loop (the benchmark baseline).

        ``checkpoint_dir`` + ``checkpoint_every=K`` persist the loop
        state (x, y, lam, d, iter) every K iterations through
        :class:`repro.checkpoint.manager.CheckpointManager`;
        ``resume=True`` restores the newest step and continues
        bitwise-compatibly after a kill (the checkpoint refuses to
        resume against a store with a different content fingerprint).
        """
        from repro.engine.streaming import solve_streaming as _solve
        return _solve(self, store, max_iters=max_iters, x0=x0,
                      record=record, overlap=overlap, prefetch=prefetch,
                      device_dtype=device_dtype,
                      checkpoint_dir=checkpoint_dir,
                      checkpoint_every=checkpoint_every, resume=resume,
                      obs=obs)


# ---------------------------------------------------------------------------
# Sparse stacking helpers (paper §7): D_hat = [I; D]
# ---------------------------------------------------------------------------

def sparse_unwrapped_lasso_matrices(D: Array, b: Array, mu: float):
    """Build the stacked system for sparse fitting min mu|x| + f(Dx).

    Returns (D_hat, labels_hat) where D_hat = [I; D] with the identity block
    assigned to a dedicated "node" N+1 (paper eq. 15) and a StackedProx-ready
    layout. For the (N, m_i, n) layout we return flat 2-D arrays; callers
    embed the identity rows on the central node.
    """
    N, mi, n = D.shape
    Dflat = D.reshape(N * mi, n)
    D_hat = jnp.concatenate([jnp.eye(n, dtype=D.dtype), Dflat], axis=0)
    return D_hat


def flat_to_nodes(D2: Array, N: int) -> Array:
    """(m, n) -> (N, m/N, n); m must divide evenly (pad upstream)."""
    m, n = D2.shape
    assert m % N == 0, f"rows {m} not divisible by {N} nodes"
    return D2.reshape(N, m // N, n)
