"""Unwrapped ADMM with transpose reduction — paper Algorithms 1 & 2.

Solves ``min_x rho/2 ||x||^2 + f(Dx)`` (rho=0 for plain ``min f(Dx)``) by
splitting ``y = Dx``:

    x^{k+1} = argmin_x rho/2||x||^2 + tau/2 ||Dx - y^k + lam^k||^2
            = (D^T D + (rho/tau) I)^{-1} D^T (y^k - lam^k)          (global LS)
    y^{k+1} = prox_f(D x^{k+1} + lam^k, 1/tau)                      (separable)
    lam^{k+1} = lam^k + D x^{k+1} - y^{k+1}

The x-update is the transpose-reduction step: only ``d = sum_i D_i^T(y_i -
lam_i)`` crosses the network (an n-vector), and the n x n Gram factor is
computed once at setup from ``sum_i D_i^T D_i`` (paper Alg. 2 lines 2-3).

Data layout: ``D`` is ``(N, m_i, n)`` — N nodes, m_i rows each. N=1 recovers
the single-node Alg. 1. This module is the *reference semantics*; the
multi-device version (``repro.core.distributed``) runs the same math under
``shard_map`` with a psum where this module sums over the node axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.prox import ProxLoss

Array = jax.Array


class ADMMHistory(NamedTuple):
    """Per-iteration telemetry (paper Fig. 2 curves + Theorems 1/2 checks)."""

    objective: Array      # f(Dx^k) (+ rho/2||x||^2)
    primal_res: Array     # ||D x^k - y^k||
    dual_res: Array       # tau * ||D^T (y^k - y^{k-1})||  (Boyd dual residual)
    grad_sq: Array        # ||D^T grad f(D x^k)||^2 if f smooth else nan
    converged_at: Array   # first iteration k meeting Boyd's stopping rule


class ADMMResult(NamedTuple):
    x: Array
    y: Array
    lam: Array
    iters: Array                 # iterations actually informative (stop point)
    history: Optional[ADMMHistory]


@dataclasses.dataclass(frozen=True)
class UnwrappedADMM:
    """Configured solver. ``loss`` acts on y with per-row aux (labels / b)."""

    loss: ProxLoss
    tau: float = 1.0
    rho: float = 0.0              # ridge g(x) = rho/2 ||x||^2 (SVM: rho=1)
    eps_rel: float = 1e-3         # paper §9 stopping constants
    eps_abs: float = 1e-6
    gram_block_rows: int = 1024

    # -- setup (Alg. 2 lines 2-3): one Gram all-reduce + one factorization --
    def setup(self, D: Array) -> Array:
        N, mi, n = D.shape
        G = jax.vmap(lambda Di: gram_lib.gram_chunked(Di, self.gram_block_rows))(
            D
        ).sum(axis=0)
        ridge = self.rho / self.tau
        return gram_lib.gram_factor(G, ridge=ridge)

    # -- one iteration (Alg. 2 lines 5-8) --
    def step(self, L: Array, D: Array, aux: Array, y: Array, lam: Array):
        acc = y.dtype
        # All nodes: d_i = D_i^T (y_i - lam_i); central: x = W sum_i d_i.
        d = jnp.einsum("imn,im->n", D.astype(acc), y - lam)
        x = gram_lib.gram_solve(L, d)
        Dx = jnp.einsum("imn,n->im", D.astype(acc), x)
        y_new = self.loss.prox(Dx + lam, 1.0 / self.tau, aux)
        lam_new = lam + Dx - y_new
        return x, Dx, y_new, lam_new

    def _residuals(self, D, Dx, y_new, y_old, lam_new):
        acc = y_new.dtype
        r = jnp.linalg.norm((Dx - y_new).ravel())
        s = self.tau * jnp.linalg.norm(
            jnp.einsum("imn,im->n", D.astype(acc), y_new - y_old)
        )
        return r, s

    def _tolerances(self, D, Dx, y, lam):
        acc = y.dtype
        m = Dx.size
        n = D.shape[-1]
        eps_pri = jnp.sqrt(m) * self.eps_abs + self.eps_rel * jnp.maximum(
            jnp.linalg.norm(Dx.ravel()), jnp.linalg.norm(y.ravel())
        )
        dual_vec = self.tau * jnp.einsum("imn,im->n", D.astype(acc), lam)
        eps_dual = jnp.sqrt(n) * self.eps_abs + self.eps_rel * jnp.linalg.norm(
            dual_vec
        )
        return eps_pri, eps_dual

    def _objective(self, x, Dx, aux):
        obj = self.loss.value(Dx.ravel(), aux.ravel() if aux is not None else None)
        if self.rho:
            obj = obj + 0.5 * self.rho * jnp.sum(x * x)
        return obj

    # -- fixed-iteration driver with full telemetry (lax.scan) --
    @partial(jax.jit, static_argnames=("self", "iters", "record"))
    def run(
        self,
        D: Array,
        aux: Optional[Array],
        iters: int,
        x0: Optional[Array] = None,
        record: bool = True,
    ) -> ADMMResult:
        N, mi, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        L = self.setup(D)
        if x0 is not None:
            # Warm start (the serving layer's repeated solves): seed the
            # split variable at y = D x0, so the first x-update returns
            # (D^T D + rI)^{-1} D^T D x0 — exactly x0 when rho = 0.
            y = jnp.einsum("imn,n->im", D.astype(acc), x0.astype(acc))
        else:
            y = jnp.zeros((N, mi), acc)
        lam = jnp.zeros((N, mi), acc)
        aux_r = aux.ravel() if aux is not None else None

        def body(carry, _):
            y, lam, k_conv, k = carry
            x, Dx, y_new, lam_new = self.step(L, D, aux, y, lam)
            r, s = self._residuals(D, Dx, y_new, y, lam_new)
            eps_pri, eps_dual = self._tolerances(D, Dx, y_new, lam_new)
            done = (r <= eps_pri) & (s <= eps_dual)
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            obj = self._objective(x, Dx, aux)
            if self.loss.grad is not None:
                # Theorem 2 diagnostic: ||d/dx f(Dx^k)||^2 = ||D^T grad f||^2.
                g = self.loss.grad(Dx.ravel(), aux_r).reshape(Dx.shape)
                gsq = jnp.sum(jnp.einsum("imn,im->n", D.astype(acc), g) ** 2)
            else:
                gsq = jnp.asarray(jnp.nan, acc)
            hist = (obj, r, s, gsq, x)
            return (y_new, lam_new, k_conv, k + 1), hist

        init = (y, lam, jnp.asarray(-1, jnp.int32), jnp.asarray(0, jnp.int32))
        (y, lam, k_conv, _), hist = jax.lax.scan(body, init, None, length=iters)
        objs, rs, ss, gsqs, xs = hist
        x = xs[-1]
        history = (
            ADMMHistory(objs, rs, ss, gsqs, k_conv) if record else None
        )
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ADMMResult(x, y, lam, iters_used, history)

    # -- early-stopping driver (lax.while_loop), deployment path --
    @partial(jax.jit, static_argnames=("self", "max_iters"))
    def solve(
        self, D: Array, aux: Optional[Array], max_iters: int = 500
    ) -> ADMMResult:
        N, mi, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        L = self.setup(D)

        def cond(state):
            y, lam, k, done, _ = state
            return (~done) & (k < max_iters)

        def body(state):
            y, lam, k, _, _ = state
            x, Dx, y_new, lam_new = self.step(L, D, aux, y, lam)
            r, s = self._residuals(D, Dx, y_new, y, lam_new)
            eps_pri, eps_dual = self._tolerances(D, Dx, y_new, lam_new)
            done = (r <= eps_pri) & (s <= eps_dual)
            return (y_new, lam_new, k + 1, done, x)

        y0 = jnp.zeros((N, mi), acc)
        lam0 = jnp.zeros((N, mi), acc)
        x0 = jnp.zeros((n,), acc)
        state = (y0, lam0, jnp.asarray(0, jnp.int32), jnp.asarray(False), x0)
        y, lam, k, done, x = jax.lax.while_loop(cond, body, state)
        return ADMMResult(x, y, lam, k, None)


# ---------------------------------------------------------------------------
# Sparse stacking helpers (paper §7): D_hat = [I; D]
# ---------------------------------------------------------------------------

def sparse_unwrapped_lasso_matrices(D: Array, b: Array, mu: float):
    """Build the stacked system for sparse fitting min mu|x| + f(Dx).

    Returns (D_hat, labels_hat) where D_hat = [I; D] with the identity block
    assigned to a dedicated "node" N+1 (paper eq. 15) and a StackedProx-ready
    layout. For the (N, m_i, n) layout we return flat 2-D arrays; callers
    embed the identity rows on the central node.
    """
    N, mi, n = D.shape
    Dflat = D.reshape(N * mi, n)
    D_hat = jnp.concatenate([jnp.eye(n, dtype=D.dtype), Dflat], axis=0)
    return D_hat


def flat_to_nodes(D2: Array, N: int) -> Array:
    """(m, n) -> (N, m/N, n); m must divide evenly (pad upstream)."""
    m, n = D2.shape
    assert m % N == 0, f"rows {m} not divisible by {N} nodes"
    return D2.reshape(N, m // N, n)
