"""Independent high-precision reference solvers (test/benchmark oracles).

These deliberately use *different algorithms* than the ADMM solvers so that
agreement is meaningful: full-data Newton for logistic, dual coordinate
descent (LIBSVM-style) for SVM, and KKT certificates for lasso.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def newton_logistic(D2: np.ndarray, labels: np.ndarray, iters: int = 60,
                    ridge: float = 0.0) -> np.ndarray:
    """Full-batch damped Newton on sum softplus(-l * Dx) (+ ridge/2 ||x||^2)."""
    D2 = np.asarray(D2, np.float64)
    l = np.asarray(labels, np.float64).ravel()
    m, n = D2.shape
    x = np.zeros(n)
    for _ in range(iters):
        z = D2 @ x
        s = 0.5 * (1.0 + np.tanh(-0.5 * l * z))  # stable sigmoid(-l z)
        g = D2.T @ (-l * s) + ridge * x
        H = (D2 * (s * (1 - s))[:, None]).T @ D2 + (ridge + 1e-10) * np.eye(n)
        step = np.linalg.solve(H, g)
        # Damping for global safety.
        t, z0 = 1.0, np.sum(np.logaddexp(0, -l * z)) + 0.5 * ridge * x @ x
        for _ in range(30):
            xn = x - t * step
            fn = np.sum(np.logaddexp(0, -l * (D2 @ xn))) + 0.5 * ridge * xn @ xn
            if fn <= z0 - 1e-4 * t * (g @ step):
                break
            t *= 0.5
        x = x - t * step
        if np.linalg.norm(t * step) < 1e-12:
            break
    return x


def logistic_objective(D2, labels, x) -> float:
    z = np.asarray(D2, np.float64) @ np.asarray(x, np.float64)
    l = np.asarray(labels, np.float64).ravel()
    return float(np.sum(np.logaddexp(0.0, -l * z)))


def svm_dual_cd(D2: np.ndarray, labels: np.ndarray, C: float,
                passes: int = 400, seed: int = 0) -> np.ndarray:
    """LIBSVM-style dual coordinate descent for 0.5||w||^2 + C h(Dw).

    Dual: min_{0<=alpha<=C} 0.5||D^T L alpha||^2 - alpha^T 1;  w = D^T L alpha.
    """
    D2 = np.asarray(D2, np.float64)
    l = np.asarray(labels, np.float64).ravel()
    m, n = D2.shape
    rng = np.random.default_rng(seed)
    alpha = np.zeros(m)
    w = np.zeros(n)
    qii = np.einsum("ij,ij->i", D2, D2)
    for _ in range(passes):
        order = rng.permutation(m)
        max_pg = 0.0
        for i in order:
            g = l[i] * (D2[i] @ w) - 1.0
            pg = min(g, 0.0) if alpha[i] <= 0 else (max(g, 0.0) if alpha[i] >= C else g)
            max_pg = max(max_pg, abs(pg))
            if qii[i] <= 0:
                continue
            a_new = min(max(alpha[i] - g / qii[i], 0.0), C)
            if a_new != alpha[i]:
                w += (a_new - alpha[i]) * l[i] * D2[i]
                alpha[i] = a_new
        if max_pg < 1e-10:
            break
    return w


def svm_objective(D2, labels, w, C: float) -> float:
    D2 = np.asarray(D2, np.float64)
    l = np.asarray(labels, np.float64).ravel()
    margins = 1.0 - l * (D2 @ np.asarray(w, np.float64))
    return float(0.5 * np.dot(w, w) + C * np.sum(np.maximum(margins, 0.0)))


def lasso_objective(D2, b, x, mu: float) -> float:
    D2 = np.asarray(D2, np.float64)
    r = D2 @ np.asarray(x, np.float64) - np.asarray(b, np.float64).ravel()
    return float(0.5 * r @ r + mu * np.sum(np.abs(x)))


def lasso_kkt_gap(D2, b, x, mu: float) -> Tuple[float, float]:
    """KKT certificate for lasso: returns (inf-norm violation, support err).

    Optimality: ||D^T(Dx-b)||_inf <= mu, and D_j^T(Dx-b) = -mu sign(x_j) on
    the support.
    """
    D2 = np.asarray(D2, np.float64)
    x = np.asarray(x, np.float64)
    r = D2 @ x - np.asarray(b, np.float64).ravel()
    corr = D2.T @ r
    viol = max(float(np.max(np.abs(corr)) - mu), 0.0)
    sup = np.abs(x) > 1e-7
    sup_err = float(np.max(np.abs(corr[sup] + mu * np.sign(x[sup])))) if sup.any() else 0.0
    return viol, sup_err


def default_tau(problem: str, m: int) -> float:
    """Stepsize defaults, following the paper's §9 tuning protocol (tune on a
    reference instance, then scale).

    For *unwrapped* ADMM the y-update is a per-coordinate prox whose scale
    does not depend on m, so tau is m-independent for logistic/SVM
    (calibrated in benchmarks/tau_calibration.py: tau=0.1 converges in ~50
    iters at m=1e3 and m=1e5 alike). The §7-stacked lasso couples x- and
    y-blocks through a Gram with spectrum O(m), so there tau scales with m —
    the same proportional-to-m rule the paper uses for consensus.
    """
    if problem == "logistic":
        return 0.1
    if problem == "svm":
        return 0.5
    if problem == "lasso":
        return 1e-2 * m
    raise ValueError(problem)
