"""Transpose reduction: Gram-matrix computation (paper §4).

The enabling observation of the paper: for tall D (m >> n),
``D^T D = sum_i D_i^T D_i`` is only n x n. Each node builds its local Gram
matrix by streaming row blocks; one all-reduce produces the global Gram.

Three implementations with identical semantics:
  * ``gram``            — one-shot jnp (oracle / small inputs).
  * ``gram_chunked``    — lax.scan over row blocks; bounds live memory to one
                          block, mirrors the HBM->VMEM streaming the Pallas
                          kernel performs, and is what the distributed fitter
                          uses under jit (XLA fuses the block matmuls).
  * ``repro.kernels.gram.ops.gram`` — the Pallas TPU kernel (VMEM accumulator).

Accumulation is always f32 (or f64 if inputs are f64): the Gram sum is a long
reduction over up to ~1e9 rows, so bf16 inputs are up-cast per block.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def blocked_rows(x: Array, block_rows: int) -> Array:
    """Zero-pad rows to a block multiple and reshape to
    (nblocks, block_rows, ...) — the shared scaffold of every streaming
    row-block reduction here (zero rows contribute nothing to the sums,
    so the padding is exact; no masking needed)."""
    m = x.shape[0]
    nblocks = -(-m // block_rows)
    pad = nblocks * block_rows - m
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x.reshape((nblocks, block_rows) + x.shape[1:])


def gram(D: Array) -> Array:
    """D^T D in accumulation precision."""
    Dc = D.astype(_acc_dtype(D.dtype))
    return Dc.T @ Dc


def gram_rhs(D: Array, b: Array) -> Array:
    """D^T b in accumulation precision (the lasso RHS, paper §4)."""
    acc = _acc_dtype(D.dtype)
    return D.astype(acc).T @ b.astype(acc)


@partial(jax.jit, static_argnames=("block_rows",))
def gram_chunked(D: Array, block_rows: int = 1024) -> Array:
    """Streaming D^T D over row blocks of size ``block_rows``.

    Rows are zero-padded up to a block multiple — zero rows contribute nothing
    to the Gram sum, so padding is exact (no masking needed).
    """
    m, n = D.shape
    acc = _acc_dtype(D.dtype)
    Dp = blocked_rows(D, block_rows)

    def body(G, blk):
        blk = blk.astype(acc)
        return G + blk.T @ blk, None

    G0 = jnp.zeros((n, n), acc)
    G, _ = jax.lax.scan(body, G0, Dp)
    return G


@partial(jax.jit, static_argnames=("block_rows",))
def gram_and_rhs_chunked(
    D: Array, b: Array, block_rows: int = 1024
) -> Tuple[Array, Array]:
    """Fused streaming (D^T D, D^T b) — one pass over the data.

    ``b`` may be (m,) — the classic lasso rhs — or (m, r) stacked
    right-hand sides (multi-probe serving); c comes back (n,) or (n, r).
    """
    m, n = D.shape
    acc = _acc_dtype(D.dtype)
    Dp = blocked_rows(D, block_rows)
    bp = blocked_rows(b, block_rows)

    def body(carry, blk):
        G, c = carry
        Db, bb = blk
        Db = Db.astype(acc)
        return (G + Db.T @ Db, c + Db.T @ bb.astype(acc)), None

    init = (jnp.zeros((n, n), acc), jnp.zeros((n,) + b.shape[1:], acc))
    (G, c), _ = jax.lax.scan(body, init, (Dp, bp))
    return G, c


@partial(jax.jit, static_argnames=("block_rows",))
def gram_rhs_chunked(D: Array, b: Array, block_rows: int = 1024) -> Array:
    """Streaming D^T b over row blocks — the rhs-only companion of
    ``gram_chunked``. Unlike the dense ``gram_rhs`` it never materializes
    a full accumulation-precision copy of D: each block is up-cast alone,
    so live memory is one block (the warm-start ``transpose_d`` path of
    the iteration engine)."""
    m, n = D.shape
    acc = _acc_dtype(D.dtype)
    Dp = blocked_rows(D, block_rows)
    bp = blocked_rows(b, block_rows)

    def body(c, blk):
        Db, bb = blk
        return c + Db.astype(acc).T @ bb.astype(acc), None

    c0 = jnp.zeros((n,) + b.shape[1:], acc)
    c, _ = jax.lax.scan(body, c0, (Dp, bp))
    return c


def gram_factor(G: Array, ridge: float = 0.0) -> Array:
    """Cholesky factor of (G + ridge*I).

    The paper stores the explicit inverse W = (sum_i D_i^T D_i)^{-1}; we keep
    the Cholesky factorization instead (DESIGN.md §3) — same asymptotic cost,
    better conditioning. ``ridge`` carries the (rho/tau) term for ridge-
    regularized x-updates (SVM) and the +I block of the sparse stacking.
    """
    n = G.shape[0]
    A = G + ridge * jnp.eye(n, dtype=G.dtype) if ridge else G
    return jnp.linalg.cholesky(A)

def gram_solve(L: Array, rhs: Array) -> Array:
    """Solve (L L^T) x = rhs given the Cholesky factor L."""
    z = jax.scipy.linalg.solve_triangular(L, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(L.T, z, lower=False)
