"""Multi-device transpose-reduction ADMM (paper Alg. 2) under shard_map.

Mapping of the paper's cluster roles onto a TPU mesh (DESIGN.md §3):

  * "node i" = a mesh position along the data axes ('pod','data'). D rows are
    sharded there; y_i, lam_i live entirely on their shard and never move.
  * "send D_i^T(y_i-lam_i) to central server" = one psum of an n-vector per
    iteration (the paper's O(n)-per-node communication claim, C5).
  * "central node computes W = (sum_i W_i)^{-1}" = the n x n Gram psum at
    setup, then a *replicated* Cholesky on every device — on TPU a redundant
    n x n factorization is cheaper than a broadcast round-trip.
  * x-update options: plain LS, ridge (SVM), or composite g(x)=mu|x| solved
    by warm-started proximal-gradient *on the cached Gram factor* — the
    "global subproblem on a single node" idea of §4 applied per-iteration;
    adds zero communication.

Beyond-paper: optional int8 error-feedback compression of the per-iteration
reduction (quantize d_i, all_gather int8 + scales, dequant-sum locally) — a
4x wire-byte reduction; ADMM tolerates it as a perturbed RHS and the error
feedback makes the bias vanish (test_distributed.py asserts parity).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import gram as gram_lib
from repro.core.prox import ProxLoss
# One shared int8 error-feedback implementation for every wire: the
# shard_map psum here and the multi-process cluster transport
# (repro.cluster) quantize with the same blocks/scales —
# repro.cluster.compress is the single canonical module; import the
# quantizers from there, not from here.
from repro.cluster.compress import ef_compress

Array = jax.Array


def compressed_psum(v: Array, axis_names, err: Array) -> Tuple[Array, Array]:
    """Error-feedback int8 all-gather-sum over ``axis_names``.

    Returns (sum, new_error). Wire payload per hop: 1 byte/coord (+ scales)
    instead of 4.
    """
    n = v.shape[0]
    q, scale, new_err = ef_compress(v, err)
    # int8 all-gather over the innermost (largest) data axis...
    ax = axis_names[-1]
    qg = jax.lax.all_gather(q, ax)                # (Nax, nb, block) int8
    sg = jax.lax.all_gather(scale, ax)
    deq = (qg.astype(jnp.float32) * sg).reshape(qg.shape[0], -1)[:, :n]
    total = jnp.sum(deq, axis=0)
    # ...then a plain f32 psum across the remaining (outer/pod) axes.
    if len(axis_names) > 1:
        total = jax.lax.psum(total, tuple(axis_names[:-1]))
    return total, new_err


# ---------------------------------------------------------------------------
# The distributed solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistributedUnwrappedADMM:
    """Paper Alg. 2 under shard_map.

    Attributes:
      loss: separable ProxLoss on y (rows follow D's row sharding).
      tau: ADMM stepsize.
      rho: ridge weight on x (SVM).
      l1_mu: if > 0, composite x-update with g(x) = l1_mu * |x|.
      data_axes: mesh axis names the rows of D are sharded over.
      compress: int8 error-feedback compression of the per-iteration psum.
      inner_iters: prox-gradient iterations for the composite x-update.
      backend / residency: iteration-engine knobs (DESIGN.md §8); the
        engine body runs PER SHARD inside shard_map — the fused one-pass
        kernel streams the local rows, then only the n-vector d crosses
        the network, composing with the int8-compressed reduction.
    """

    loss: ProxLoss
    tau: float = 1.0
    rho: float = 0.0
    l1_mu: float = 0.0
    data_axes: Tuple[str, ...] = ("data",)
    compress: bool = False
    inner_iters: int = 25
    backend: str = "auto"
    residency: Optional[str] = None

    @property
    def engine(self):
        # Lazy for the same circular-import reason as UnwrappedADMM.engine.
        from repro.engine import IterationEngine
        return IterationEngine(loss=self.loss, tau=self.tau,
                               backend=self.backend,
                               residency=self.residency)

    # -- inner composite x-update: argmin mu|x| + tau/2 (x'Gx - 2 d'x) -------
    def _composite_x(self, G: Array, lmax: Array, d: Array, x_warm: Array):
        # one prox-gradient implementation for every topology
        # (repro.exec.base) — traceable, so it runs inside this shard_map
        # body unchanged
        from repro.core.prox import soft_threshold
        from repro.exec.base import composite_x_update
        return composite_x_update(
            G, lmax, d, x_warm, self.tau,
            lambda z, step: soft_threshold(z, step * self.l1_mu),
            self.inner_iters)

    def build(self, mesh: Mesh, m_global: int, n: int, iters: int,
              obs=None):
        """Returns a jitted ``solve(D_global, aux_global) -> (x, history)``.

        D_global: (m_global, n) sharded P(data_axes, None);
        aux_global: (m_global,) sharded P(data_axes).

        ``m_global`` need not divide the shard count: uneven datasets are
        zero-padded to a shard multiple inside the returned function
        (pass HOST arrays in that case — pre-sharding an uneven array
        with ``shard_rows`` would fail before the pad can happen).

        ``obs`` (:class:`repro.obs.Observability`) wraps the RETURNED
        function, never the shard_map body: one span around the whole
        solve, then the per-iteration (objective, primal-res) history is
        streamed to the telemetry sink after the device work completes.
        With ``obs`` disabled the raw jitted function comes back
        untouched — zero overhead.
        """
        axes = self.data_axes
        nshards = 1
        for a in axes:
            nshards *= mesh.shape[a]
        # Uneven datasets are zero-padded to a shard multiple rather than
        # rejected: zero rows are EXACT under the transpose reduction
        # (no Gram, d, or residual contribution — gram.blocked_rows), and
        # with zero aux their iterates stay at zero, so the only telemetry
        # they touch is the objective's constant f(0) term, subtracted in
        # the wrapper below.
        pad = -(-m_global // nshards) * nshards - m_global

        eng = self.engine

        def local_fn(D_loc: Array, aux_loc: Array):
            acc = gram_lib._acc_dtype(D_loc.dtype)
            # -- setup: Gram psum + replicated factor (Alg.2 lines 2-3) --
            G, _ = eng.gram(D_loc)
            G = jax.lax.psum(G, axes)
            ridge = self.rho / self.tau
            use_chol = self.l1_mu == 0.0
            if use_chol:
                L = gram_lib.gram_factor(G, ridge=ridge)
                lmax = jnp.asarray(0.0, acc)
            else:
                L = jnp.zeros((n, n), acc)
                # Power iteration for the inner prox-gradient stepsize.
                v = jnp.ones((n,), acc) / jnp.sqrt(n * 1.0)

                def piter(v, _):
                    w = G @ v
                    return w / jnp.maximum(jnp.linalg.norm(w), 1e-30), None

                v, _ = jax.lax.scan(piter, v, None, length=30)
                lmax = jnp.vdot(v, G @ v)

            m_loc = D_loc.shape[0]
            D_res = eng.prepare(D_loc)
            y = jnp.zeros((m_loc,), acc)
            lam = jnp.zeros((m_loc,), acc)
            err = jnp.zeros((n,), jnp.float32)
            x0 = jnp.zeros((n,), acc)
            # d_loc = D^T(y - lam) rides the carry: the engine's fused body
            # emits the NEXT iteration's reduction in the same data pass
            # that applies the prox (cold start: y = lam = 0 -> d_loc = 0).
            d0 = jnp.zeros((n,), acc)

            def body(carry, _):
                y, lam, err, x_prev, d_loc = carry
                if self.compress:
                    d, err = compressed_psum(d_loc, axes, err)
                else:
                    d = jax.lax.psum(d_loc, axes)
                if use_chol:
                    x = gram_lib.gram_solve(L, d)
                else:
                    x = self._composite_x(G, lmax, d, x_prev)
                # ONE streaming pass over the local shard (Alg. 2 lines 5-8
                # + line 6's reduction input, fused — DESIGN.md §8).
                st = eng.iterate(D_res, aux_loc, y, lam, x, want_dual=False)
                Dx = st.lam - lam + st.y
                # telemetry (global reductions of scalars). The objective
                # is f(Dx) — same as the reference solver's _objective —
                # NOT f(y): mid-run y != Dx (they only meet at
                # convergence), and history must be comparable across
                # solvers at every iteration.
                r_sq = jax.lax.psum(jnp.sum((Dx - st.y) ** 2), axes)
                obj_loc = self.loss.value(Dx, aux_loc)
                obj = jax.lax.psum(obj_loc, axes)
                if self.rho:
                    obj = obj + 0.5 * self.rho * jnp.sum(x * x)
                if self.l1_mu:
                    obj = obj + self.l1_mu * jnp.sum(jnp.abs(x))
                return (st.y, st.lam, err, x, st.d), (obj, jnp.sqrt(r_sq))

            (y, lam, err, x, _), hist = jax.lax.scan(
                body, (y, lam, err, x0, d0), None, length=iters
            )
            return x, hist[0], hist[1]

        in_specs = (P(axes, None), P(axes))
        out_specs = (P(), P(), P())
        from repro.sharding.compat import shard_map
        fn = shard_map(
            local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        if pad == 0:
            solve_fn = jax.jit(fn)
        else:
            # Pad-row objective: iterates of zero rows stay at zero, so
            # their per-iteration contribution is the CONSTANT f(0, aux=0).
            pad_obj = float(self.loss.value(jnp.zeros((pad,)),
                                            jnp.zeros((pad,))))

            @jax.jit
            def padded(D_global: Array, aux_global: Array):
                Dp = jnp.pad(D_global, ((0, pad), (0, 0)))
                ap = jnp.pad(aux_global, (0, pad))
                x, objs, rs = fn(Dp, ap)
                return x, objs - pad_obj, rs

            solve_fn = padded

        if obs is None or not obs.enabled:
            return solve_fn

        def observed(D_global: Array, aux_global: Array):
            with obs.span("distributed_solve", iters=iters,
                          shards=nshards):
                x, objs, rs = solve_fn(D_global, aux_global)
                jax.block_until_ready(x)
            obs.inc("distributed.solves")
            for i, (o, r) in enumerate(zip(jnp.asarray(objs),
                                           jnp.asarray(rs))):
                obs.record(iter=i + 1, objective=float(o),
                           primal_res=float(r), tau=self.tau,
                           rho=self.rho, shards=nshards)
            return x, objs, rs

        return observed


def shard_rows(mesh: Mesh, arr: Array, axes: Sequence[str]) -> Array:
    """Place a host array with rows sharded over the given mesh axes."""
    spec = P(tuple(axes), *([None] * (arr.ndim - 1)))
    return jax.device_put(arr, NamedSharding(mesh, spec))
