"""Consensus ADMM baseline (Boyd et al. 2010) — the method the paper beats.

Global consensus form:  min sum_i f_i(x_i) + g(z)  s.t.  x_i = z.

    x_i^{k+1} = argmin_{x_i} f_i(x_i) + tau/2 ||x_i - z^k + u_i^k||^2   (inner)
    z^{k+1}   = prox_g( mean_i(x_i^{k+1} + u_i^k), 1/(N tau) )
    u_i^{k+1} = u_i^k + x_i^{k+1} - z^{k+1}

The cost structure the paper criticizes lives in the x-update: every node
runs an *iterative inner solver* per outer iteration —

  * lasso:    closed form via a per-node cached factorization of
              (D_i^T D_i + tau I)  (Boyd §6.4; cache cost = N Gram factorizations)
  * logistic: damped Newton with warm start (>= the paper's L-BFGS in
              per-iteration progress, so speedup claims are conservative)
  * SVM:      dual coordinate descent on paper eq. (21) (Appendix A), with
              greedy largest-residual ordering and warm start.

Node layout matches ``unwrapped.py``: D is (N, m_i, n), labels/b is (N, m_i).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.prox import soft_threshold

Array = jax.Array


class ConsensusHistory(NamedTuple):
    objective: Array
    primal_res: Array       # ||x_i - z|| stacked norm (Boyd)
    dual_res: Array         # tau ||z^{k+1} - z^k|| * sqrt(N)
    inner_iters: Array      # inner-solver iterations spent this outer iter
    converged_at: Array


class ConsensusResult(NamedTuple):
    z: Array
    iters: Array
    history: Optional[ConsensusHistory]


def _stopping(x_stack, z, u_stack, tau, z_old, eps_rel, eps_abs):
    N, n = x_stack.shape
    r = jnp.linalg.norm((x_stack - z[None, :]).ravel())
    s = tau * jnp.sqrt(N * 1.0) * jnp.linalg.norm(z - z_old)
    eps_pri = jnp.sqrt(N * n * 1.0) * eps_abs + eps_rel * jnp.maximum(
        jnp.linalg.norm(x_stack.ravel()), jnp.sqrt(N * 1.0) * jnp.linalg.norm(z)
    )
    eps_dual = jnp.sqrt(N * n * 1.0) * eps_abs + eps_rel * tau * jnp.linalg.norm(
        u_stack.ravel()
    )
    return (r <= eps_pri) & (s <= eps_dual), r, s


@dataclasses.dataclass(frozen=True)
class ConsensusLasso:
    """min 0.5||Dx-b||^2 + mu|x| via consensus (Boyd §6.4 / §8.2)."""

    mu: float
    tau: float = 1.0
    eps_rel: float = 1e-3
    eps_abs: float = 1e-6

    @partial(jax.jit, static_argnames=("self", "iters"))
    def run(self, D: Array, b: Array, iters: int) -> ConsensusResult:
        N, mi, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        Dc = D.astype(acc)
        bc = b.astype(acc)
        # Setup: every node factors (D_i^T D_i + tau I) — the consensus
        # counterpart of the single global Gram factorization.
        Gs = jnp.einsum("imn,imk->ink", Dc, Dc)
        Ls = jax.vmap(lambda G: gram_lib.gram_factor(G, ridge=self.tau))(Gs)
        Dtb = jnp.einsum("imn,im->in", Dc, bc)

        def x_update(z, u):
            rhs = Dtb + self.tau * (z[None, :] - u)
            return jax.vmap(gram_lib.gram_solve)(Ls, rhs)

        def body(carry, k):
            z, u, k_conv = carry
            xs = x_update(z, u)
            w = jnp.mean(xs + u, axis=0)
            z_new = soft_threshold(w, self.mu / (self.tau * N))
            u_new = u + xs - z_new[None, :]
            done, r, s = _stopping(
                xs, z_new, u_new, self.tau, z, self.eps_rel, self.eps_abs
            )
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            obj = 0.5 * jnp.sum(
                (jnp.einsum("imn,n->im", Dc, z_new) - bc) ** 2
            ) + self.mu * jnp.sum(jnp.abs(z_new))
            return (z_new, u_new, k_conv), (obj, r, s, jnp.asarray(1))

        z0 = jnp.zeros((n,), acc)
        u0 = jnp.zeros((N, n), acc)
        (z, u, k_conv), hist = jax.lax.scan(
            body, (z0, u0, jnp.asarray(-1, jnp.int32)), jnp.arange(iters)
        )
        objs, rs, ss, ii = hist
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ConsensusResult(
            z, iters_used, ConsensusHistory(objs, rs, ss, ii, k_conv)
        )


@dataclasses.dataclass(frozen=True)
class ConsensusLogistic:
    """min sum log(1+exp(-l .)) (+ mu|x|) via consensus; Newton inner solver."""

    mu: float = 0.0
    tau: float = 1.0
    newton_iters: int = 8
    eps_rel: float = 1e-3
    eps_abs: float = 1e-6

    def _local_newton(self, Di, li, v, x0):
        """argmin_x sum log(1+exp(-l Di x)) + tau/2||x - v||^2, warm-started."""
        n = Di.shape[-1]

        def body(x, _):
            zi = Di @ x
            s = jax.nn.sigmoid(-li * zi)
            grad = Di.T @ (-li * s) + self.tau * (x - v)
            Wd = s * (1.0 - s)
            H = (Di * Wd[:, None]).T @ Di + self.tau * jnp.eye(n, dtype=Di.dtype)
            step = jnp.linalg.solve(H, grad)
            return x - step, None

        x, _ = jax.lax.scan(body, x0, None, length=self.newton_iters)
        return x

    @partial(jax.jit, static_argnames=("self", "iters"))
    def run(self, D: Array, labels: Array, iters: int) -> ConsensusResult:
        N, mi, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        Dc = D.astype(acc)
        lc = labels.astype(acc)

        def body(carry, k):
            z, u, xs, k_conv = carry
            v = z[None, :] - u
            xs = jax.vmap(self._local_newton)(Dc, lc, v, xs)  # warm start: xs
            w = jnp.mean(xs + u, axis=0)
            if self.mu > 0:
                z_new = soft_threshold(w, self.mu / (self.tau * N))
            else:
                z_new = w
            u_new = u + xs - z_new[None, :]
            done, r, s = _stopping(
                xs, z_new, u_new, self.tau, z, self.eps_rel, self.eps_abs
            )
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            zi = jnp.einsum("imn,n->im", Dc, z_new)
            obj = jnp.sum(jax.nn.softplus(-lc * zi)) + self.mu * jnp.sum(
                jnp.abs(z_new)
            )
            return (z_new, u_new, xs, k_conv), (
                obj,
                r,
                s,
                jnp.asarray(self.newton_iters),
            )

        z0 = jnp.zeros((n,), acc)
        u0 = jnp.zeros((N, n), acc)
        xs0 = jnp.zeros((N, n), acc)
        (z, u, xs, k_conv), hist = jax.lax.scan(
            body, (z0, u0, xs0, jnp.asarray(-1, jnp.int32)), jnp.arange(iters)
        )
        objs, rs, ss, ii = hist
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ConsensusResult(
            z, iters_used, ConsensusHistory(objs, rs, ss, ii, k_conv)
        )


@dataclasses.dataclass(frozen=True)
class ConsensusSVM:
    """min 0.5||x||^2 + C h(Dx) via consensus; dual-CD inner solver (App. A).

    Each node solves   min_w ridge/2 ||w||^2 + C h_i(D_i w) + tau/2||w - v||^2
    with ridge = 1/N so the node-sum reproduces the global 0.5||x||^2 exactly
    (the paper's eq. (20) as-written over-counts the ridge N times; see
    DESIGN.md §3). With beta = ridge + tau the dual is paper eq. (21):

        min_{alpha in [0,C]}  1/(2 beta) ||D_i^T L alpha + tau v||^2 - alpha^T 1

    solved by coordinate descent over alpha with greedy (largest projected
    gradient) ordering per pass, warm-started across outer iterations; primal
    recovery w = (D_i^T L alpha + tau v) / beta  (paper App. A).
    """

    C: float = 1.0
    tau: float = 1.0
    cd_passes: int = 4
    eps_rel: float = 1e-3
    eps_abs: float = 1e-6

    @partial(jax.jit, static_argnames=("self", "iters"))
    def run(self, D: Array, labels: Array, iters: int) -> ConsensusResult:
        N, mi, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        Dc = D.astype(acc)
        lc = labels.astype(acc)
        beta = 1.0 / N + self.tau
        row_sq = jnp.sum(Dc * Dc, axis=-1)  # (N, mi): ||a_k||^2 per row

        def local_cd(Di, li, rsq, v, alpha0):
            # Maintain w_acc = D_i^T (l * alpha); CD over coordinates.
            w0 = Di.T @ (li * alpha0)

            def one_pass(state, _):
                alpha, w = state
                # Greedy ordering: projected-gradient magnitude per coord.
                g = (li * (Di @ (w + self.tau * v))) / beta - 1.0
                pg = jnp.where(
                    alpha <= 0.0,
                    jnp.minimum(g, 0.0),
                    jnp.where(alpha >= self.C, jnp.maximum(g, 0.0), g),
                )
                order = jnp.argsort(-jnp.abs(pg))

                def cd_step(state, idx):
                    alpha, w = state
                    ai = alpha[idx]
                    gi = (li[idx] * jnp.dot(Di[idx], w + self.tau * v)) / beta - 1.0
                    qi = rsq[idx] / beta
                    ai_new = jnp.clip(ai - gi / jnp.maximum(qi, 1e-12), 0.0, self.C)
                    dw = (ai_new - ai) * li[idx] * Di[idx]
                    return (alpha.at[idx].set(ai_new), w + dw), None

                (alpha, w), _ = jax.lax.scan(cd_step, (alpha, w), order)
                return (alpha, w), None

            (alpha, w), _ = jax.lax.scan(
                one_pass, (alpha0, w0), None, length=self.cd_passes
            )
            w_primal = (w + self.tau * v) / beta
            return alpha, w_primal

        def body(carry, k):
            z, u, alphas, k_conv = carry
            v = z[None, :] - u
            alphas, xs = jax.vmap(local_cd)(Dc, lc, row_sq, v, alphas)
            z_new = jnp.mean(xs + u, axis=0)
            u_new = u + xs - z_new[None, :]
            done, r, s = _stopping(
                xs, z_new, u_new, self.tau, z, self.eps_rel, self.eps_abs
            )
            k_conv = jnp.where((k_conv < 0) & done, k, k_conv)
            zi = jnp.einsum("imn,n->im", Dc, z_new)
            obj = 0.5 * jnp.sum(z_new * z_new) + self.C * jnp.sum(
                jnp.maximum(1.0 - lc * zi, 0.0)
            )
            return (z_new, u_new, alphas, k_conv), (
                obj,
                r,
                s,
                jnp.asarray(self.cd_passes * mi),
            )

        z0 = jnp.zeros((n,), acc)
        u0 = jnp.zeros((N, n), acc)
        a0 = jnp.zeros((N, mi), acc)
        (z, u, a, k_conv), hist = jax.lax.scan(
            body, (z0, u0, a0, jnp.asarray(-1, jnp.int32)), jnp.arange(iters)
        )
        objs, rs, ss, ii = hist
        iters_used = jnp.where(k_conv >= 0, k_conv + 1, iters)
        return ConsensusResult(
            z, iters_used, ConsensusHistory(objs, rs, ss, ii, k_conv)
        )
