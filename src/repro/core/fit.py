"""High-level model-fitting API — the paper's contribution as one call.

``fit()`` dispatches on (problem, method) through the problem registry
(``repro.service.registry``): solvers self-register under
``@register_problem`` and this module stays a thin, stable entry point.

  problem: "lasso" | "logistic" | "svm" | "sparse_logistic"
           | "ridge" | "elastic_net" | "huber" | "nnls"
  method:  "transpose"  — the paper (unwrapped ADMM w/ transpose reduction,
                          or the §4 direct Gram path for quadratic data terms)
           "consensus"  — the Boyd baseline the paper compares against
                          (lasso / logistic / sparse_logistic / svm)
           "fasta"      — single-node forward-backward from cached Gram
                          (lasso / ridge / elastic_net / nnls)

Single-process emulation takes node-stacked D (N, m_i, n). Multi-device
takes a Mesh and row-sharded global arrays (see repro.core.distributed).
This is also the entry point the LM framework uses for linear-probe /
readout fitting on frozen transformer features (DESIGN.md §4), and the
solver the serving layer (repro.service.server) falls back to for
problems that need the raw data rather than cached sufficient statistics.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

Array = jax.Array


class FitResult(NamedTuple):
    x: Array
    iters: int
    objective_history: Optional[Array]
    method: str
    problem: str


def _flops_per_iter(problem: str, method: str, N: int, mi: int, n: int) -> float:
    """Analytic per-iteration FLOP model (used by the scaling benchmarks to
    report paper-style 'total compute time' at core counts we do not emulate).
    """
    m = N * mi
    if method == "transpose":
        # d = D^T(y-lam): 2mn; Dx: 2mn; solve: 2n^2; prox: ~10m.
        return 4.0 * m * n + 2.0 * n * n + 10.0 * m
    # consensus per outer iter: inner solver dominated.
    if problem == "lasso":
        # cached factor solve per node: 2n^2 + 2 m_i n for rhs
        return N * (2.0 * n * n) + 2.0 * m * n
    if problem in ("logistic", "sparse_logistic"):
        # Newton: per inner iter H build = m_i n^2, solve n^3/3; ~8 inner
        return 8.0 * (m * n * n + N * n**3 / 3.0)
    if problem == "svm":
        # CD pass: O(m_i n) per pass * passes(4) + greedy grad O(m_i n)
        return 8.0 * m * n
    raise ValueError(problem)


def fit(
    problem: str,
    D: Array,                      # (N, m_i, n) node-stacked
    aux: Array,                    # labels or b, (N, m_i)
    method: str = "transpose",
    mu: Optional[float] = None,    # l1 weight (lasso / sparse_logistic / en)
    C: float = 1.0,                # SVM hinge weight
    tau: Optional[float] = None,
    iters: int = 500,
    record: bool = True,
    **params,                      # problem extras: l2=, delta=, x0=, ...
) -> FitResult:
    # Imported lazily: the registry imports solver modules from repro.core,
    # so a module-level import here would be circular.
    from repro.service import registry

    return registry.solve(
        problem, D, aux, method=method,
        mu=mu, C=C, tau=tau, iters=iters, record=record, **params)
