"""High-level model-fitting API — the paper's contribution as one call.

``fit()`` dispatches on (problem, method):

  problem: "lasso" | "logistic" | "svm" | "sparse_logistic"
  method:  "transpose"  — the paper (unwrapped ADMM w/ transpose reduction,
                          or the §4 direct Gram path for lasso)
           "consensus"  — the Boyd baseline the paper compares against
           "fasta"      — single-node forward-backward (lasso only)

Single-process emulation takes node-stacked D (N, m_i, n). Multi-device
takes a Mesh and row-sharded global arrays (see repro.core.distributed).
This is also the entry point the LM framework uses for linear-probe /
readout fitting on frozen transformer features (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import consensus as cons
from repro.core import fasta as fasta_lib
from repro.core import gram as gram_lib
from repro.core import prox as prox_lib
from repro.core.oracles import default_tau
from repro.core.unwrapped import UnwrappedADMM

Array = jax.Array


class FitResult(NamedTuple):
    x: Array
    iters: int
    objective_history: Optional[Array]
    method: str
    problem: str


def _flops_per_iter(problem: str, method: str, N: int, mi: int, n: int) -> float:
    """Analytic per-iteration FLOP model (used by the scaling benchmarks to
    report paper-style 'total compute time' at core counts we do not emulate).
    """
    m = N * mi
    if method == "transpose":
        # d = D^T(y-lam): 2mn; Dx: 2mn; solve: 2n^2; prox: ~10m.
        return 4.0 * m * n + 2.0 * n * n + 10.0 * m
    # consensus per outer iter: inner solver dominated.
    if problem == "lasso":
        # cached factor solve per node: 2n^2 + 2 m_i n for rhs
        return N * (2.0 * n * n) + 2.0 * m * n
    if problem in ("logistic", "sparse_logistic"):
        # Newton: per inner iter H build = m_i n^2, solve n^3/3; ~8 inner
        return 8.0 * (m * n * n + N * n**3 / 3.0)
    if problem == "svm":
        # CD pass: O(m_i n) per pass * passes(4) + greedy grad O(m_i n)
        return 8.0 * m * n
    raise ValueError(problem)


def fit(
    problem: str,
    D: Array,                      # (N, m_i, n) node-stacked
    aux: Array,                    # labels or b, (N, m_i)
    method: str = "transpose",
    mu: Optional[float] = None,    # l1 weight (lasso / sparse_logistic)
    C: float = 1.0,                # SVM hinge weight
    tau: Optional[float] = None,
    iters: int = 500,
    record: bool = True,
) -> FitResult:
    N, mi, n = D.shape
    m = N * mi
    if tau is None and problem in ("lasso", "logistic", "svm", "sparse_logistic"):
        tau = default_tau(
            {"sparse_logistic": "logistic"}.get(problem, problem), m
        )

    if problem == "lasso":
        assert mu is not None
        if method == "transpose" or method == "fasta":
            # §4: direct transpose reduction + single-node FASTA.
            Dflat = D.reshape(m, n)
            G, c = gram_lib.gram_and_rhs_chunked(Dflat, aux.reshape(m))
            res = fasta_lib.transpose_reduction_lasso(G, c, mu, iters=iters)
            return FitResult(res.x, int(res.iters), res.objective, method, problem)
        if method == "consensus":
            r = cons.ConsensusLasso(mu=mu, tau=tau).run(D, aux, iters)
            return FitResult(r.z, int(r.iters), r.history.objective, method, problem)

    if problem == "logistic":
        if method == "transpose":
            r = UnwrappedADMM(loss=prox_lib.make_logistic(), tau=tau).run(
                D, aux, iters, record=record
            )
            hist = r.history.objective if r.history else None
            return FitResult(r.x, int(r.iters), hist, method, problem)
        if method == "consensus":
            r = cons.ConsensusLogistic(tau=tau).run(D, aux, iters)
            return FitResult(r.z, int(r.iters), r.history.objective, method, problem)

    if problem == "sparse_logistic":
        assert mu is not None
        if method == "transpose":
            # §7 stacking [I; D]: identity block rides on a virtual node.
            Dflat = D.reshape(m, n)
            D_hat = jnp.concatenate([jnp.eye(n, dtype=D.dtype), Dflat], 0)[None]
            sp = prox_lib.StackedProx(
                blocks=(prox_lib.make_l1(mu), prox_lib.make_logistic()),
                sizes=(n, m),
            )
            aux_hat = jnp.concatenate([jnp.zeros((n,), aux.dtype), aux.reshape(m)])[
                None
            ]
            r = UnwrappedADMM(loss=sp.as_loss("sparse_logistic"), tau=tau).run(
                D_hat, aux_hat, iters, record=record
            )
            hist = r.history.objective if r.history else None
            return FitResult(r.x, int(r.iters), hist, method, problem)
        if method == "consensus":
            r = cons.ConsensusLogistic(mu=mu, tau=tau).run(D, aux, iters)
            return FitResult(r.z, int(r.iters), r.history.objective, method, problem)

    if problem == "svm":
        if method == "transpose":
            r = UnwrappedADMM(loss=prox_lib.make_hinge(C), tau=tau, rho=1.0).run(
                D, aux, iters, record=record
            )
            hist = r.history.objective if r.history else None
            return FitResult(r.x, int(r.iters), hist, method, problem)
        if method == "consensus":
            r = cons.ConsensusSVM(C=C, tau=tau).run(D, aux, iters)
            return FitResult(r.z, int(r.iters), r.history.objective, method, problem)

    raise ValueError(f"unsupported (problem={problem}, method={method})")
