"""Out-of-core streaming backend — the fused iteration body over a
:class:`~repro.data.store.ShardedMatrixStore` (DESIGN.md §9).

The in-memory engine (``engine.engine``) assumes D is device-resident.
This module removes that assumption: each solver pass walks the store's
row blocks, runs the SAME fused body (``IterationEngine.iterate``) on one
device-resident block at a time, and persists the m-sized iterates
``(y, lam)`` back to host per block — device memory is bounded by one
block regardless of m.

Double-buffering rule: a host prefetch thread stages ``jax.device_put``
of block k+1 (D, aux, and the host-resident y/lam slices) while the
device computes block k; device→host writeback of block k's iterates
trails the compute by one block. With JAX's async dispatch the three
streams (H2D, compute, D2H) overlap, so a sweep costs ~max(transfer,
compute) instead of their sum — ``benchmarks/streaming_bench.py`` writes
the measured overlap efficiency to ``BENCH_streaming.json``.

Host-resident iterate contract: ``y`` and ``lam`` live in caller-owned
(m,) numpy arrays, mutated in place block-by-block each sweep; only the
n-sized reductions (d, w, v) and the stopping-rule scalars stay on the
device between sweeps. Tail-block padding is exact (zero D rows
contribute nothing to any reduction — ``gram.blocked_rows``); the one
non-exact quantity, the objective's value on pad rows, is a constant
(pad iterates stay at zero) subtracted once at setup.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from functools import lru_cache
from typing import Callable, Iterable, Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.data.store import ShardedMatrixStore
from repro.engine.engine import IterationEngine

Array = jax.Array

_ERROR = object()          # sentinel wrapping producer-thread exceptions
_DONE = object()


# ---------------------------------------------------------------------------
# staged iteration: the double-buffer primitive
# ---------------------------------------------------------------------------

def staged(items: Iterable, stage: Callable, depth: int) -> Iterator:
    """Yield ``stage(item)`` for each item, running ``stage`` up to
    ``depth`` items ahead on a host thread. ``depth=0`` degrades to the
    naive synchronous loop (the benchmark baseline)."""
    if depth <= 0:
        for it in items:
            yield stage(it)
        return
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def producer():
        try:
            for it in items:
                if stop.is_set():
                    return
                q.put(stage(it))
        except BaseException as e:           # surface in the consumer
            q.put((_ERROR, e))
            return
        q.put((_DONE, None))

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            got = q.get()
            # identity checks only: `in`/`==` would invoke __eq__ on
            # staged payloads (numpy arrays raise on truth-testing)
            if isinstance(got, tuple) and len(got) == 2 and (
                    got[0] is _ERROR or got[0] is _DONE):
                if got[0] is _ERROR:
                    raise got[1]
                return
            yield got
    finally:
        # Consumer abandoned mid-stream (exception in the step, generator
        # closed): unblock the producer so it exits and its staged device
        # buffers are dropped instead of pinned behind a full queue.
        stop.set()
        while not q.empty():
            try:
                q.get_nowait()
            except queue.Empty:
                break


# ---------------------------------------------------------------------------
# jitted per-block bodies (cached per engine instance)
# ---------------------------------------------------------------------------

def _zero_sweep(n: int, dtype, ycols: int = 1) -> "SweepResult":
    """Fresh (unaliased) zero accumulators — donation-safe carry init.
    ``ycols > 1`` (multinomial) widens the n-vectors to (n, ycols)."""
    shape = (n,) if ycols == 1 else (n, ycols)
    return SweepResult(*(jnp.zeros(shape, dtype) for _ in range(3)),
                       *(jnp.zeros((), dtype) for _ in range(4)))


class SweepResult(NamedTuple):
    """Accumulated over all blocks of one sweep — everything the driver
    needs for the x-update and Boyd's stopping rule, all n-sized or
    scalar (module docstring: nothing m-sized survives a sweep on
    device)."""

    d: Array          # sum_b D_b^T(y_b' - lam_b')
    w: Array          # sum_b D_b^T(y_b' - y_b)
    v: Array          # sum_b D_b^T lam_b'
    r_sq: Array       # ||lam' - lam||^2 = ||Dx - y'||^2
    dx_sq: Array      # ||Dx||^2
    y_sq: Array       # ||y'||^2
    obj: Array        # f(Dx) (pad-corrected by the driver)


@lru_cache(maxsize=64)
def _block_fns(engine: IterationEngine, has_aux: bool,
               want_dual: bool = True, sparse: bool = False):
    """Jitted per-block step / init / gram bodies for one engine config.

    Cached so every sweep reuses the same traced functions (jit's own
    shape cache handles the uniform block shape). The sweep accumulators
    ride THROUGH the step as a donated carry: one dispatch per block
    instead of one per reduction, which is what lets the double-buffered
    pipeline stay dispatch-bound-free (DESIGN.md §9). ``want_dual=False``
    is the lean hot-path body (d-reduction only, no stopping-rule/
    telemetry quantities — the streaming analogue of ``make_step``).
    ``sparse=True`` stages one-block BlockCSR pytrees; the step body is
    the engine's own format dispatch, only the warm-start init differs
    (gather matvec instead of the dense one)."""

    def step(D_b, aux_b, y_b, lam_b, x, acc):
        st = engine.iterate(D_b, aux_b if has_aux else None, y_b, lam_b, x,
                            want_dual=want_dual)
        if not want_dual:
            return st.y, st.lam, acc._replace(d=acc.d + st.d)
        Dx = st.lam - lam_b + st.y
        obj = engine.loss.value(Dx, aux_b if has_aux else None)
        new = SweepResult(
            acc.d + st.d, acc.w + st.w, acc.v + st.v,
            acc.r_sq + jnp.sum((st.lam - lam_b) ** 2),
            acc.dx_sq + jnp.sum(Dx * Dx),
            acc.y_sq + jnp.sum(st.y * st.y), acc.obj + obj)
        return st.y, st.lam, new

    def init(D_b, x0):
        """Warm start: y_b = D_b x0 and its d-contribution (lam = 0)."""
        if sparse:
            from repro.kernels.spgram import ops as spgram_ops
            acc = gram_lib._acc_dtype(D_b.dtype)
            y_b = spgram_ops.matvec(D_b, x0.astype(acc))
            return y_b, spgram_ops.rmatvec(D_b, y_b)
        acc = gram_lib._acc_dtype(D_b.dtype)
        y_b = D_b.astype(acc) @ x0.astype(acc)
        if y_b.ndim > 1:                   # matrix iterates (multinomial)
            return y_b, D_b.astype(acc).T @ y_b
        return y_b, y_b @ D_b.astype(acc)

    def gram(G, D_b):
        Gb, _ = engine.gram(D_b)
        return G + Gb

    return (jax.jit(step, donate_argnums=(2, 3, 5)), jax.jit(init),
            jax.jit(gram, donate_argnums=(0,)))


# Public alias: the cluster worker (repro.cluster.worker) drives the same
# jitted per-block fused body over ITS owned blocks — one implementation
# of the iteration step for the streaming and multi-process paths.
block_step_fns = _block_fns


def store_pad_objective(store: ShardedMatrixStore, loss) -> float:
    """f's value on the tail block's pad rows. Pad iterates stay at
    zero (zero D rows, zero aux), so this is a CONSTANT the driver
    subtracts from each sweep's objective — the only pad quantity that
    is not exactly zero (e.g. logistic: log 2 per pad row). One
    definition for the streaming driver and the cluster coordinator."""
    pad = store.nblocks * store.block_rows - store.m
    if pad == 0:
        return 0.0
    ycols = getattr(loss, "ycols", 1)
    z = jnp.zeros((pad,) if ycols == 1 else (pad, ycols), jnp.float32)
    a = jnp.zeros((pad,), jnp.float32)
    return float(loss.value(z, a if store.has_aux else None))


# ---------------------------------------------------------------------------
# the streaming engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamingEngine:
    """Block-streaming driver around an :class:`IterationEngine`.

    ``prefetch`` is the double-buffer depth (device_put of block k+1
    overlapped with compute on block k); ``prefetch=0`` is the naive
    synchronous baseline the benchmark compares against.
    """

    engine: IterationEngine
    prefetch: int = 2
    device_dtype: Optional[str] = None   # None -> store dtype; else the
    # device-residency dtype (e.g. "float32" for an f64 host store): the
    # cast happens AT STAGING TIME on the host, so the double-buffered
    # path overlaps the conversion with compute — the store keeps the
    # data as collected, the device only ever holds residency-dtype
    # blocks (the engine's residency idea applied at the H2D boundary).

    def _cast(self, a):
        if self.device_dtype is None:
            return a
        if hasattr(a, "astype") and not isinstance(a, np.ndarray):
            # BlockCSR: casts value arrays, indices stay int32
            return a.astype(self.device_dtype)
        if a.dtype == np.dtype(self.device_dtype):
            return a
        return a.astype(self.device_dtype)

    def _stage(self, store: ShardedMatrixStore, y: np.ndarray,
               lam: np.ndarray):
        """Build the prefetch stage: host block k -> device-resident
        (k, D_b, aux_b, y_b, lam_b), tail zero-padded to the uniform
        block shape, residency-cast on the host."""
        br = store.block_rows

        def stage(k):
            D_b, a_b = store.block(k, padded=True)
            sl = store.block_slice(k)
            valid = sl.stop - sl.start
            y_b = np.zeros((br,) + y.shape[1:], y.dtype)
            y_b[:valid] = y[sl]
            lam_b = np.zeros((br,) + lam.shape[1:], lam.dtype)
            lam_b[:valid] = lam[sl]
            return (k, jax.device_put(self._cast(D_b)),
                    jax.device_put(self._cast(a_b))
                    if a_b is not None else None,
                    jax.device_put(y_b), jax.device_put(lam_b))

        return stage

    def residency_dtype(self, store: ShardedMatrixStore):
        """dtype of the blocks the device actually sees."""
        return jnp.dtype(self.device_dtype or store.dtype.name)

    # -- setup: Gram over the store, one block resident at a time ----------
    def gram_from_store(self, store: ShardedMatrixStore) -> Array:
        if store.sparse:
            # Sparse gram is a HOST pass (kernels/spgram/ops.py): the
            # blocks are host arrays already, so nothing is staged to
            # the device — the O(nnz) CSR matmul folds block by block.
            # No residency cast either: device_dtype exists to cut H2D
            # bytes, and quantizing a host-only pass would only degrade G.
            G = None
            for k in range(store.nblocks):
                D_b, _ = store.block(k, padded=True)
                Gb, _ = self.engine.gram(D_b)
                G = Gb if G is None else G + Gb
            return G
        _, _, gram = _block_fns(self.engine, store.has_aux)
        acc = gram_lib._acc_dtype(self.residency_dtype(store))
        G = jnp.zeros((store.n, store.n), acc)
        blocks = staged(range(store.nblocks),
                        lambda k: jax.device_put(self._cast(
                            store.block(k, padded=True)[0])),
                        self.prefetch)
        for D_b in blocks:
            G = gram(G, D_b)
        return G

    # -- warm start: y = D x0 per block, d = D^T y in the same pass --------
    def init_from_x0(self, store: ShardedMatrixStore, x0: Array,
                     y: np.ndarray) -> Array:
        _, init, _ = _block_fns(self.engine, store.has_aux,
                                sparse=store.sparse)
        x0 = jax.device_put(x0)
        d = None
        blocks = staged(range(store.nblocks),
                        lambda k: (k, jax.device_put(self._cast(
                            store.block(k, padded=True)[0]))),
                        self.prefetch)
        for k, D_b in blocks:
            y_b, d_b = init(D_b, x0)
            d = d_b if d is None else d + d_b
            sl = store.block_slice(k)
            y[sl] = np.asarray(y_b)[: sl.stop - sl.start]
        return d

    # -- one full iteration sweep ------------------------------------------
    def sweep(self, store: ShardedMatrixStore, x: Array, y: np.ndarray,
              lam: np.ndarray, overlap: Optional[bool] = None,
              want_dual: bool = True) -> SweepResult:
        """Stream every block through the fused body once: updates the
        host-resident (y, lam) in place and returns the n-sized /scalar
        accumulators. ``overlap=False`` forces the synchronous baseline
        (transfer, wait, compute, wait, write back) regardless of the
        configured prefetch depth. ``want_dual=False`` runs the lean
        hot-path body (d only; the other accumulators come back as their
        zero init)."""
        depth = self.prefetch if overlap in (None, True) else 0
        step, _, _ = _block_fns(self.engine, store.has_aux, want_dual,
                                sparse=store.sparse)
        x = jax.device_put(x)
        facc = gram_lib._acc_dtype(self.residency_dtype(store))
        # one buffer per field: the carry is DONATED into the step, and
        # XLA rejects donating one buffer through two arguments
        acc = _zero_sweep(store.n, facc,
                          getattr(self.engine.loss, "ycols", 1))
        pending = None            # (slice, y_dev, lam_dev): lag-1 writeback

        def writeback(item):
            sl, y_b, lam_b = item
            valid = sl.stop - sl.start
            y[sl] = np.asarray(y_b)[:valid]
            lam[sl] = np.asarray(lam_b)[:valid]

        for k, D_b, a_b, y_b, lam_b in staged(
                range(store.nblocks), self._stage(store, y, lam), depth):
            if depth == 0:
                jax.block_until_ready((D_b, y_b, lam_b))
            y_new, lam_new, acc = step(D_b, a_b, y_b, lam_b, x, acc)
            if depth == 0:
                jax.block_until_ready((y_new, lam_new, acc))
            if pending is not None:
                writeback(pending)
                pending = None
            item = (store.block_slice(k), y_new, lam_new)
            if depth == 0:
                writeback(item)
            else:
                pending = item
        if pending is not None:
            writeback(pending)
        return acc

    # -- pad-objective correction ------------------------------------------
    def pad_objective(self, store: ShardedMatrixStore) -> float:
        """See :func:`store_pad_objective` — shared with the cluster
        coordinator so the two drivers cannot drift."""
        return store_pad_objective(store, self.engine.loss)


# ---------------------------------------------------------------------------
# the out-of-core solve driver (UnwrappedADMM.solve_streaming delegates here)
# ---------------------------------------------------------------------------

def solve_streaming(solver, store: ShardedMatrixStore, max_iters: int = 500,
                    x0: Optional[Array] = None, record: bool = False,
                    overlap: bool = True, prefetch: int = 2,
                    device_dtype: Optional[str] = None,
                    checkpoint_dir: Optional[str] = None,
                    checkpoint_every: int = 0, resume: bool = False,
                    obs=None):
    """Out-of-core unwrapped ADMM over a row-block store.

    Same semantics as ``UnwrappedADMM.solve`` (Boyd stopping rule, warm
    start) but D never needs to be device- or even host-array-resident:
    setup is one Gram sweep, each iteration is one fused sweep, and the
    m-sized iterates live in host numpy buffers. Returns an
    ``ADMMResult`` with ``y``/``lam`` shaped (1, m) (the node-stacked
    convention with N=1); ``history`` is populated when ``record``.

    Long solves survive kills: ``checkpoint_dir`` + ``checkpoint_every
    = K`` persist the full loop state (x, y, lam, d, iter) through
    :class:`repro.checkpoint.manager.CheckpointManager` every K
    iterations (atomic commits — a SIGKILL mid-save leaves the previous
    step intact), and ``resume=True`` restores the newest step and
    continues BITWISE-compatibly: the restored state is exactly the
    live state, so the remaining iterations replay the identical
    op sequence (``tests/test_cluster.py`` asserts bit equality).
    ``record`` history restarts from the resume point. The checkpoint
    is bound to the store's content fingerprint — resuming against
    different data refuses instead of converging somewhere else.

    ``obs`` (an :class:`repro.obs.Observability`) instruments the HOST
    loop only: spans around the Gram setup and each sweep, one telemetry
    JSONL record per iteration. ``None`` is the disabled fast path.

    This is a thin wrapper: the loop itself lives in the shared executor
    driver (``repro.exec``) behind a :class:`~repro.exec.StreamingExecutor`.
    """
    from repro.exec import StreamingExecutor, solve_with_executor

    ex = StreamingExecutor(solver.engine, store, overlap=overlap,
                           prefetch=prefetch, device_dtype=device_dtype)
    return solve_with_executor(
        ex, loss=solver.loss, tau=solver.tau, rho=solver.rho,
        eps_rel=solver.eps_rel, eps_abs=solver.eps_abs,
        max_iters=max_iters, x0=x0, record=record,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        resume=resume, obs=obs)
