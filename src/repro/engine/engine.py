"""The iteration engine — the ONE place solver iteration bodies live.

Every hot path of the repo (``core/unwrapped``, ``core/distributed``,
``service/stats`` ingestion, the benchmarks) dispatches its per-iteration /
per-ingest pass over the data matrix D through this module instead of
inlining einsums. The engine owns three interchangeable backends
(DESIGN.md §8):

  * ``pallas``            — TPU: the fused ``kernels/admm_iter`` kernel.
                            ONE HBM pass over D per iteration (Dx, prox,
                            lam-update and ALL THREE transpose reductions
                            d = D^T(y'-lam'), w = D^T(y'-y), v = D^T lam'
                            while each row panel is VMEM-resident); Gram
                            setup via the fused Gram+RHS kernel in
                            ``kernels/gram``.
  * ``pallas_interpret``  — same kernels in interpreter mode (CPU CI).
  * ``chunked``           — CPU/GPU: a ``lax.scan`` over row blocks with
                            the same one-pass-fused body; each block stays
                            cache-hot between its Dx and D^T uses, halving
                            memory traffic vs the two-pass formulation.
  * ``sparse``            — padded block-CSR data (``data/sparse.BlockCSR``):
                            the same scan shape with O(nnz) per-block work
                            (``kernels/spgram``) — gather-based Dx and
                            gather-based transpose reductions over the
                            per-block local CSC (DESIGN.md §10). Selected
                            by the DATA TYPE: BlockCSR input takes this
                            path under every backend except an explicit
                            ``reference`` (which densifies — the parity
                            oracle).
  * ``reference``         — the textbook two-pass jnp oracle (Dx pass,
                            then a D^T pass); parity baseline.

``auto`` resolves per device (TPU -> pallas, else chunked), then falls
back by capability: Pallas needs a kernel-supported coordinatewise prox
(logistic / hinge / l1 / least_squares / quantile, f32 or bf16 rows);
chunked needs a
coordinatewise prox; everything else lands on reference. bf16 data
residency (``residency="bf16"``) halves iteration HBM bytes again on top
of the fused pass — all accumulation stays f32 in-register regardless.
``residency="auto"`` applies bf16 only where it is a measured win (the
real-TPU pallas backend); on CPU/chunked backends the per-block upcast
dominates the saved bytes (BENCH_engine.json: 0.55x/1.88x), so auto
resolves to None there (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.prox import ProxLoss
from repro.data.sparse import BlockCSR
from repro.engine import autotune
from repro.kernels.admm_iter.ops import admm_iter_full
from repro.kernels.gram import ops as gram_ops
from repro.kernels.spgram import ops as spgram_ops

Array = jax.Array

BACKENDS = ("reference", "chunked", "sparse", "pallas", "pallas_interpret")

# Prox kinds the fused Pallas iteration kernel evaluates in-register.
PALLAS_KINDS = frozenset(
    {"logistic", "hinge", "l1", "least_squares", "quantile"})

# "auto" resolves per backend at prepare()-time: bf16 where the HBM-bytes
# win is real (real-TPU pallas), None on CPU/chunked backends where the
# per-block upcast is a measured slowdown (DESIGN.md §8).
RESIDENCY_DTYPES = {None: None, "bf16": jnp.bfloat16, "auto": "auto"}


class EngineStep(NamedTuple):
    """One fused iteration: updated iterates plus the n-vector reductions
    accumulated in the same pass over D. The w/v differences are formed
    row-wise in-register BEFORE reducing (not by differencing accumulated
    D^T y across iterations, which cancels catastrophically near
    convergence)."""

    y: Array           # y^{k+1} = prox_f(Dx + lam)
    lam: Array         # lam^{k+1} = lam + Dx - y^{k+1}
    d: Array           # D^T(y^{k+1} - lam^{k+1}) — next x-update RHS
    w: Optional[Array]   # D^T(y^{k+1} - y^k) — Boyd dual residual s = tau||w||
    v: Optional[Array]   # D^T lam^{k+1} — dual tolerance needs tau||v||


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "chunked"


def gram_stats(D: Array, b: Optional[Array] = None, *,
               backend: str = "auto",
               block_rows: Optional[int] = None) -> Tuple[Array, Optional[Array]]:
    """Backend-dispatched (D^T D, D^T b) in one streaming pass (paper §4).

    The single Gram entry point for solver setup and service ingestion.
    ``b`` may be None (Gram only), (m,), or (m, r) stacked right-hand
    sides; returns (G, c) with c None iff b is None. ``block_rows``
    bounds the chunked backend's live block (None -> autotuned); the
    Pallas backends tile from the autotuner's VMEM budget instead.
    """
    if isinstance(D, BlockCSR):
        if backend == "reference":
            # parity oracle: densify, then the textbook dense gram
            Dd = D.to_dense()
            if b is None:
                return gram_lib.gram(Dd), None
            return gram_lib.gram(Dd), gram_lib.gram_rhs(Dd, b)
        # HOST-ONLY pass (scipy CSR matmul; see kernels/spgram/ops.py) —
        # sparse setup runs outside jit, like every other store-driven
        # setup pass in the repo.
        return spgram_ops.sparse_gram_rhs(D, b)
    if backend in ("auto", "sparse"):      # "sparse" is data-format-
        backend = default_backend()        # selected; dense input streams
    m, n = D.shape
    if backend in ("pallas", "pallas_interpret") and D.dtype == jnp.float64:
        backend = "chunked"          # Pallas kernels are f32/bf16 only
    if backend in ("pallas", "pallas_interpret"):
        interp = backend == "pallas_interpret"
        rhs = 0 if b is None else (b.shape[1] if b.ndim > 1 else 1)
        bm, bn = autotune.gram_blocks(m, n, D.dtype, rhs=rhs)
        if b is None:
            return gram_ops.gram(D, block_m=bm, block_n=bn,
                                 interpret=interp), None
        return gram_ops.gram_and_rhs(D, b, block_m=bm, block_n=bn,
                                     interpret=interp)
    if backend == "chunked":
        br = block_rows or autotune.chunked_block_rows(m, n, D.dtype)
        if b is None:
            return gram_lib.gram_chunked(D, br), None
        return gram_lib.gram_and_rhs_chunked(D, b, br)
    if backend == "reference":
        if b is None:
            return gram_lib.gram(D), None
        return gram_lib.gram(D), gram_lib.gram_rhs(D, b)
    raise ValueError(f"unknown backend {backend!r}; expected one of "
                     f"{BACKENDS + ('auto',)}")


@dataclasses.dataclass(frozen=True)
class IterationEngine:
    """Per-device fused iteration body for unwrapped ADMM (paper Alg. 2
    lines 5-8 plus both telemetry reductions).

    Operates on flat local data: D (m, n), aux/y/lam (m,), x (n,) — the
    node-stacked solvers flatten, the distributed solver passes its shard.
    Composes under shard_map (the cross-shard psum of ``d`` stays with the
    caller, per Alg. 2 line 6).
    """

    loss: ProxLoss
    tau: float = 1.0
    backend: str = "auto"
    block_m: Optional[int] = None          # None -> autotuned
    residency: Optional[str] = None        # None | "bf16"

    def __post_init__(self):
        if self.backend not in BACKENDS + ("auto",):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.residency not in RESIDENCY_DTYPES:
            raise ValueError(f"unknown residency {self.residency!r}")

    @property
    def delta(self) -> float:
        return 1.0 / self.tau

    # -- backend selection (rules documented in DESIGN.md §8) ---------------
    def resolve(self, dtype=jnp.float32) -> str:
        b = default_backend() if self.backend == "auto" else self.backend
        if b == "sparse":
            # "sparse" is a data-format backend: dense arrays have no
            # sparse body, so a dense resolve lands on the device default
            # (the format dispatch in iterate() picks sparse for BlockCSR
            # under every backend except an explicit reference).
            b = default_backend()
        if b in ("pallas", "pallas_interpret") and (
                self.loss.name not in PALLAS_KINDS
                or jnp.dtype(dtype) == jnp.float64):
            b = "chunked"
        if b == "chunked" and not self.loss.coordinatewise:
            b = "reference"
        return b

    def resolve_residency(self, dtype=jnp.float32) -> Optional[str]:
        """DESIGN.md §8 residency rule: explicit settings are honored
        as-is; ``"auto"`` casts to bf16 only on the real-TPU pallas
        backend — on CPU/chunked (and interpret-mode) backends the
        per-block upcast dominates the saved bytes (measured 0.55x/1.88x
        vs 4.89x in BENCH_engine.json), so auto resolves to None."""
        if self.residency != "auto":
            return self.residency
        return "bf16" if self.resolve(dtype) == "pallas" else None

    # -- data residency -----------------------------------------------------
    def prepare(self, D) -> Array:
        """Cast D ONCE to its iteration-residency dtype (bf16 halves the
        per-iteration HBM bytes; accumulation stays f32 in-register).
        BlockCSR casts its value arrays; indices stay int32."""
        dt = RESIDENCY_DTYPES[self.resolve_residency(D.dtype)]
        if dt is None or D.dtype == dt:
            return D
        return D.astype(dt)

    # -- setup: Gram (+ RHS) in one data pass -------------------------------
    def gram(self, D, b: Optional[Array] = None,
             block_rows: Optional[int] = None):
        backend = self._gram_backend(D.dtype)
        if isinstance(D, BlockCSR) and self.backend == "reference":
            # the densify parity oracle must stay reachable for sparse
            # Gram too (the reference->chunked mapping below is a
            # dense-path preference, not an oracle bypass)
            backend = "reference"
        return gram_stats(D, b, backend=backend, block_rows=block_rows)

    def _gram_backend(self, dtype) -> str:
        b = default_backend() if self.backend == "auto" else self.backend
        return "chunked" if b == "reference" else b

    # -- transpose application: D^T u without a dense upcast ----------------
    def rmatvec(self, D, u: Array) -> Array:
        """D^T u in accumulation precision, backend-dispatched like every
        other pass over D: the dense ``gram_rhs`` up-casts ALL of D to
        accumulation precision at once, which would materialize a full
        f32 copy of a bf16-resident D — the streaming-class backends
        (chunked, pallas, sparse) up-cast one block at a time instead.
        Setup-time and telemetry passes (warm-start d, run()'s grad_sq)
        route here; ``u`` may be (m,) or (m, r)."""
        if isinstance(D, BlockCSR):
            return spgram_ops.rmatvec(D, u)
        b = default_backend() if self.backend == "auto" else self.backend
        if b == "reference":
            return gram_lib.gram_rhs(D, u)
        m, n = D.shape
        br = self.block_m or autotune.chunked_block_rows(m, n, D.dtype)
        return gram_lib.gram_rhs_chunked(D, u, br)

    # -- warm-start init: d from existing iterates, one pass ----------------
    def transpose_d(self, D, y: Array, lam: Array):
        """d = D^T(y - lam) — setup-time only (cold starts get zeros
        without touching D; warm starts pay one column pass). The
        dispatch lives in :meth:`rmatvec` (there is no rhs-only Pallas
        kernel and the scan is setup-time, not per-iteration)."""
        return self.rmatvec(D, y - lam)

    # -- the fused iteration body -------------------------------------------
    def iterate(self, D, aux: Optional[Array], y: Array, lam: Array,
                x: Array, want_dual: bool = True) -> EngineStep:
        """Given x^{k+1}: stream D once, producing y^{k+1}, lam^{k+1} and
        the reduction(s) that drive iteration k+2 and the stopping rule.
        ``D`` is a dense (m, n) array or a :class:`BlockCSR`."""
        if isinstance(D, BlockCSR):
            if self.backend == "reference":
                return self._iterate_reference(D.to_dense(), aux, y, lam,
                                               x, want_dual)
            return self._iterate_sparse(D, aux, y, lam, x, want_dual)
        backend = self.resolve(D.dtype)
        if (backend == "chunked" and self.backend == "auto"
                and D.size * D.dtype.itemsize <= 16 * autotune.CACHE_BUDGET):
            # Small-D auto rule (measured in BENCH_engine.json): once D fits
            # in last-level cache the two-pass reference body re-reads it
            # for free and the scan's block bookkeeping only costs; the
            # one-pass stream wins when D spills. Explicit backend requests
            # are honored as-is.
            backend = "reference"
        if backend in ("pallas", "pallas_interpret"):
            return self._iterate_pallas(D, aux, y, lam, x,
                                        interpret=backend
                                        == "pallas_interpret",
                                        want_dual=want_dual)
        if backend == "chunked":
            return self._iterate_chunked(D, aux, y, lam, x,
                                         want_dual=want_dual)
        return self._iterate_reference(D, aux, y, lam, x,
                                       want_dual=want_dual)

    def _iterate_reference(self, D, aux, y, lam, x, want_dual):
        acc = gram_lib._acc_dtype(D.dtype)
        Df = D.astype(acc)
        Dx = Df @ x.astype(acc)
        y_new = self.loss.prox(Dx + lam, self.delta, aux)
        lam_new = lam + Dx - y_new
        if want_dual:
            if y_new.ndim > 1:
                # matrix iterates (m, K): three stacked multi-RHS products
                DfT = Df.T
                return EngineStep(y_new, lam_new, DfT @ (y_new - lam_new),
                                  DfT @ (y_new - y), DfT @ lam_new)
            dwv = Df.T @ jnp.stack(
                [y_new - lam_new, y_new - y, lam_new], axis=1)
            return EngineStep(y_new, lam_new, dwv[:, 0], dwv[:, 1],
                              dwv[:, 2])
        return EngineStep(y_new, lam_new, Df.T @ (y_new - lam_new),
                          None, None)

    def _iterate_chunked(self, D, aux, y, lam, x, want_dual):
        m, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        br = self.block_m or autotune.chunked_block_rows(m, n, D.dtype)
        xc = x.astype(acc)
        blocks = [gram_lib.blocked_rows(D, br),
                  gram_lib.blocked_rows(y, br),
                  gram_lib.blocked_rows(lam, br)]
        if aux is not None:
            blocks.append(gram_lib.blocked_rows(aux, br))

        def body(carry, blk):
            d, w, v = carry
            Db, yb, lb = blk[0].astype(acc), blk[1], blk[2]
            ab = blk[3] if aux is not None else None
            Dx = Db @ xc
            y_b = self.loss.prox(Dx + lb, self.delta, ab)
            l_b = lb + Dx - y_b
            d = d + (y_b - l_b) @ Db
            if want_dual:
                w = w + (y_b - yb) @ Db
                v = v + l_b @ Db
            return (d, w, v), (y_b, l_b)

        zero = jnp.zeros((n,), acc)
        (d, w, v), (ys, ls) = jax.lax.scan(
            body, (zero, zero, zero), tuple(blocks))
        return EngineStep(ys.reshape(-1)[:m], ls.reshape(-1)[:m], d,
                          w if want_dual else None,
                          v if want_dual else None)

    def _iterate_sparse(self, D: BlockCSR, aux, y, lam, x, want_dual):
        """O(nnz) fused body: lax.scan over the static-shaped block-CSR
        blocks, gather-based Dx and gather-based d/w/v over each block's
        local CSC (kernels/spgram, DESIGN.md §10)."""
        y_new, lam_new, d, w, v = spgram_ops.sparse_admm_iter_full(
            D, aux, y, lam, x, loss=self.loss, delta=self.delta,
            want_dual=want_dual)
        return EngineStep(y_new, lam_new, d, w, v)

    def _iterate_pallas(self, D, aux, y, lam, x, interpret, want_dual):
        m, n = D.shape
        bm = self.block_m or autotune.iter_block_m(m, n, D.dtype)
        aux_arr = aux if aux is not None else jnp.zeros_like(y)
        y_new, lam_new, d, w, v = admm_iter_full(
            D, aux_arr, y, lam, x, kind=self.loss.name,
            delta=self.loss.kernel_delta_scale * self.delta,
            block_m=bm, interpret=interpret,
            param=self.loss.kernel_param)
        return EngineStep(y_new, lam_new, d, w if want_dual else None,
                          v if want_dual else None)

    # -- host-loop step with buffer donation --------------------------------
    def make_step(self, D: Array, aux: Optional[Array], L: Array):
        """Jitted ``step(y, lam, d) -> (y', lam', d', x)`` closing over the
        prepared data and Gram factor, with the (y, lam) iterate pair
        DONATED — host-driven loops (serving, benchmarks) update in place
        instead of allocating fresh iterate buffers every call."""
        Dres = self.prepare(D)

        @partial(jax.jit, donate_argnums=(0, 1))
        def step(y, lam, d):
            x = gram_lib.gram_solve(L, d)
            st = self.iterate(Dres, aux, y, lam, x, want_dual=False)
            return st.y, st.lam, st.d, x

        return step
