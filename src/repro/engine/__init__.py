"""repro.engine — the per-iteration hot path of every solver (DESIGN.md §8).

Public surface:
  * :class:`IterationEngine` — fused one-pass iteration body with
    reference / chunked / pallas backends and bf16 data residency;
  * :func:`gram_stats` — backend-dispatched one-pass (D^T D, D^T b);
  * :mod:`repro.engine.autotune` — the (m, n, dtype)-keyed block-size
    model shared by every engine call site.
"""
from repro.engine.engine import (
    BACKENDS,
    PALLAS_KINDS,
    EngineStep,
    IterationEngine,
    default_backend,
    gram_stats,
)
from repro.engine import autotune
from repro.engine.streaming import (
    StreamingEngine,
    SweepResult,
    solve_streaming,
)

__all__ = [
    "BACKENDS",
    "PALLAS_KINDS",
    "EngineStep",
    "IterationEngine",
    "StreamingEngine",
    "SweepResult",
    "default_backend",
    "gram_stats",
    "solve_streaming",
    "autotune",
]
