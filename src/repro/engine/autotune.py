"""Block-size autotuner for the iteration engine (DESIGN.md §8).

Model-driven, not search-driven: block shapes are picked from the VMEM /
cache budget math below and memoized per ``(m, n, dtype)`` so every caller
of the engine (solvers, service ingest, benchmarks) agrees on the shapes
without re-deriving them. The cache is a plain dict — inspectable in tests
and overridable by pinning an entry before the first resolve.

Budget math (see DESIGN.md §7 for the kernel-side derivation):

  * Pallas fused iteration: the live set per grid step is the (bm, n) D
    panel (double-buffered by the pipeline), the (1, n) x row, three
    (1, n) f32 accumulators (d, w, v), and five (bm, 1) vector blocks
    (y, lam, aux in; y', lam' out), also double-buffered. With dsize =
    bytes per D element:
        2*bm*n*dsize + 4*n*4 + 10*bm*4  <=  VMEM_BUDGET.
  * Pallas Gram / Gram+RHS: 2*bm*(bn_i + bn_j)*dsize streamed D panels +
    bn*bn*4 resident accumulator, plus for the fused RHS the (bn, rpad)
    resident C block and the double-buffered (bm, rpad) f32 B stream.
  * chunked (lax.scan) backend: the same streaming shape on CPU/GPU; the
    budget stands in for the last-level-cache slice a core can keep hot,
    so one block of D plus its vectors stays resident between the Dx and
    D^T passes of the fused body.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

# ~16 MB physical VMEM per TPU core; leave headroom for the pipeline's
# own scratch and semaphores.
VMEM_BUDGET = 8 * 1024 * 1024
# Last-level cache slice assumed hot per chunked-backend stream on CPU/GPU.
CACHE_BUDGET = 2 * 1024 * 1024
# Default per-block DEVICE-memory budget for the out-of-core streaming
# path (DESIGN.md §9): covers the two in-flight D blocks (double buffer).
STREAM_BUDGET = 256 * 1024 * 1024

# (kind, m, n, dtype_name) -> chosen block size(s); pin to override.
CACHE: Dict[Tuple, Tuple] = {}


def _dsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _sublane(dtype) -> int:
    """Minimum second-to-last-dim tile for the dtype (f32: 8, bf16: 16)."""
    return {4: 8, 2: 16, 1: 32}.get(_dsize(dtype), 8)


def _clamp_multiple(value: int, mult: int, lo: int, hi: int) -> int:
    v = max(lo, min(hi, value))
    return max(mult, (v // mult) * mult)


def _row_cap(m: int, mult: int) -> int:
    """Never pick a row block taller than m rounded up to the tile size —
    taller blocks only add zero-padding work."""
    return -(-m // mult) * mult


def iter_block_m(m: int, n: int, dtype) -> int:
    """Row-panel height for the fused Pallas iteration kernel."""
    key = ("iter", int(m), int(n), jnp.dtype(dtype).name)
    if key not in CACHE:
        dsize = _dsize(dtype)
        # 2*bm*n*dsize (double-buffered panel) + 10*bm*4 (five vector
        # blocks, double-buffered) + 4*n*4 (x + d/w/v accumulators)
        # <= budget, solved for bm.
        bm = (VMEM_BUDGET - 4 * n * 4) // (2 * n * dsize + 40)
        sub = _sublane(dtype)
        cap = _row_cap(m, sub)
        CACHE[key] = (_clamp_multiple(bm, sub, min(128, cap), min(4096, cap)),)
    return CACHE[key][0]


def gram_blocks(m: int, n: int, dtype, rhs: int = 0) -> Tuple[int, int]:
    """(block_m, block_n) for the Gram / fused Gram+RHS kernels.

    ``rhs`` is the stacked right-hand-side count (0 = Gram only); its
    lane-padded B stream and resident C block are budgeted so wide
    multi-RHS ingests shrink bm instead of blowing the VMEM budget.
    """
    rpad = -(-max(rhs, 1) // 128) * 128 if rhs else 0
    key = ("gram", int(m), int(n), jnp.dtype(dtype).name, rpad)
    if key not in CACHE:
        dsize = _dsize(dtype)
        # Lane-aligned output tile first: bn >= 256 keeps the kernel
        # MXU-bound (arithmetic intensity ~ bn FLOP/byte), but never wider
        # than the (padded) feature count.
        bn = _clamp_multiple(n, 128, 128, 512)
        bn = min(bn, 512)
        # Then the tallest row panel that fits beside the resident bn x bn
        # accumulator (+ bn x rpad C block), counting the double-buffered
        # D panels (2 inputs) and the double-buffered f32 B stream.
        resident = bn * bn * 4 + bn * rpad * 4
        per_row = 4 * bn * dsize + 2 * rpad * 4
        bm = (VMEM_BUDGET - resident) // per_row
        sub = _sublane(dtype)
        cap = _row_cap(m, sub)
        CACHE[key] = (_clamp_multiple(bm, sub, min(128, cap), min(2048, cap)),
                      bn)
    return CACHE[key]


def streaming_block_rows(m: int, n: int, dtype,
                         budget_bytes: int = None) -> int:
    """Store block height for the out-of-core streaming path (DESIGN.md
    §9): the tallest block whose worst-case in-flight set fits the
    device-memory budget. At the default prefetch depth of 2 the
    pipeline can hold FOUR D blocks at once (one computing, two staged
    in the queue, one mid-``device_put`` in the producer), plus the
    per-row vector traffic."""
    budget = int(budget_bytes) if budget_bytes else STREAM_BUDGET
    key = ("stream", int(m), int(n), jnp.dtype(dtype).name, budget)
    if key not in CACHE:
        dsize = _dsize(dtype)
        rows = budget // max(1, 4 * n * dsize + 6 * 4)
        cap = _row_cap(m, 8)
        # prefer >= 128-row blocks, but honor a tight budget (huge n /
        # small budget) down to the 8-row tile floor rather than
        # silently overshooting the caller's device memory
        lo = min(128, cap) if rows >= 128 else 8
        CACHE[key] = (_clamp_multiple(rows, 8, lo, cap),)
    return CACHE[key][0]


def sparse_block_m(m: int, n: int, kp: int, dtype) -> int:
    """Row-block height for the padded block-CSR path (DESIGN.md §10).

    nnz-budgeted, not (m x n)-budgeted: a block's live bytes are its CSR
    slice ``bm * kp * (4 + dsize)`` plus the same nonzeros again in the
    local-CSC companion (padding slack rides in the 2x), plus the five
    (bm,) iterate vectors — so the block height scales with 1/density
    and the cache budget covers ~1/density more rows than the dense
    chunked stream. Tall floor (1024): the local CSC pads each column to
    the block's max per-column count, and that Poisson slack shrinks as
    blocks grow.
    """
    kp = max(int(kp), 1)
    key = ("sparse", int(m), int(n), kp, jnp.dtype(dtype).name)
    if key not in CACHE:
        dsize = _dsize(dtype)
        rows = CACHE_BUDGET // max(1, 2 * kp * (4 + dsize) + 20)
        cap = _row_cap(m, 8)
        CACHE[key] = (_clamp_multiple(rows, 8, min(1024, cap),
                                      min(16384, cap)),)
    return CACHE[key][0]


def chunked_block_rows(m: int, n: int, dtype) -> int:
    """Row-block length for the lax.scan streaming backend (CPU/GPU)."""
    key = ("chunked", int(m), int(n), jnp.dtype(dtype).name)
    if key not in CACHE:
        dsize = _dsize(dtype)
        rows = CACHE_BUDGET // max(1, n * dsize)
        cap = _row_cap(m, 8)
        CACHE[key] = (_clamp_multiple(rows, 8, min(128, cap), min(8192, cap)),)
    return CACHE[key][0]
