"""Train / serve step builders (the functions the dry-run lowers).

train_step: loss -> grads -> optimizer update, with optional gradient
accumulation over microbatches (lax.scan; peak activation memory is one
microbatch). serve_step: one decode token against the KV/state caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.decode import decode_step
from repro.models.model import loss_fn
from repro.optim.optimizers import make_optimizer


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def make_train_step(cfg: ModelConfig, optimizer=None, *, microbatches: int = 1,
                    attn_impl: str = "xla", clip_norm: float = 1.0):
    if optimizer is None:
        optimizer = make_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, attn_impl=attn_impl),
                has_aux=True,
            )(params)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            # mrope positions are (3, B, S): microbatch along axis 1.
            mb = {}
            for k, v in batch.items():
                if k == "positions" and v.ndim == 3:
                    # (3,B,S) -> (mb, 3, B/mb, S)
                    mb[k] = v.reshape(
                        v.shape[0], microbatches, -1, v.shape[-1]
                    ).swapaxes(0, 1)
                else:
                    mb[k] = split(v)

            def one(carry, microbatch):
                g_acc, l_acc, a_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, microbatch,
                                      attn_impl=attn_impl),
                    has_aux=True,
                )(params)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + metrics["aux"]), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                one, (g0, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"ce": loss, "aux": aux / microbatches}

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out_metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, tokens, pos):
        return decode_step(params, cfg, caches, tokens=tokens, pos=pos)
    return serve_step
