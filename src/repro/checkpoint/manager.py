"""Fault-tolerant checkpointing: atomic, manifest-verified, background-
writable, elastic-restorable.

Layout per step:
  <dir>/step_<n>.tmp/      (written)
  <dir>/step_<n>/          (atomic rename commit)
      manifest.json        (tree structure, shapes, dtypes, crc32 per leaf,
                            data-pipeline state, mesh shape at save time)
      leaf_<i>.npy

Guarantees used by the fault-tolerance tests:
  * a SIGKILL at any instant leaves either a complete committed step or an
    uncommitted .tmp (ignored on restore) — never a torn checkpoint;
  * restore is exact (bitwise) for same-mesh restarts;
  * ELASTIC restore: arrays are saved unsharded (gathered); a restart may
    re-place them on a different mesh/DP size, so scaling the node count
    up/down between runs only changes placement, not values.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._bg: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             background: bool = False):
        """Serialize ``tree`` (params/opt_state/etc.) at ``step``."""
        leaves, treedef = _flatten_with_paths(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # gather to host
        if background:
            if self._bg is not None:
                self._bg.join()
            self._bg = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef, extra))
            self._bg.start()
        else:
            self._write(step, host_leaves, treedef, extra)

    def wait(self):
        if self._bg is not None:
            self._bg.join()
            self._bg = None

    def _write(self, step, host_leaves, treedef, extra):
        # unique tmp per writer: concurrent saves of the same step (e.g. a
        # background periodic save racing a foreground final save) must not
        # clobber each other's staging dir; rename commit stays atomic.
        import os as _os
        tmp = self.dir / f"step_{step:08d}.tmp{_os.getpid()}_{threading.get_ident()}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            for p in tmp.iterdir():
                p.unlink()
            tmp.rmdir()
        tmp.mkdir()
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": [],
        }
        for i, leaf in enumerate(host_leaves):
            path = tmp / f"leaf_{i}.npy"
            np.save(path, leaf)
            manifest["leaves"].append({
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(leaf).tobytes()),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():  # overwrite-safe (same step already committed)
            for p in tmp.iterdir():
                p.unlink()
            tmp.rmdir()
            return
        try:
            tmp.rename(final)  # atomic commit
        except OSError:
            pass  # lost the race to an identical commit — fine
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            d = self.dir / f"step_{s:08d}"
            for p in d.iterdir():
                p.unlink()
            d.rmdir()

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and ".tmp" not in p.name:
                if (p / "manifest.json").exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None, fallback: bool = False
                ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``. ``shardings``
        (optional pytree of NamedSharding) re-places leaves on an arbitrary
        mesh — the elastic-restart path. ``fallback=True`` walks back to
        the previous committed step when the newest one fails its crc /
        manifest check (disk rot on the most recent write must not strand
        a crash-recovering coordinator when older intact steps exist);
        an explicit ``step`` disables the walk-back."""
        if step is None and fallback:
            last_err: Optional[Exception] = None
            for s in reversed(self.all_steps()):
                try:
                    return self._restore_step(tree_like, s, shardings)
                except (IOError, OSError, ValueError, KeyError) as e:
                    last_err = e
            if last_err is not None:
                raise IOError(
                    f"every checkpoint step failed to restore; newest "
                    f"error: {last_err}") from last_err
            raise AssertionError("no checkpoint found")
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        return self._restore_step(tree_like, step, shardings)

    def _restore_step(self, tree_like: Any, step: int,
                      shardings: Any = None) -> Tuple[Any, Dict]:
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves_like, treedef = _flatten_with_paths(tree_like)
        assert len(leaves_like) == len(manifest["leaves"]), \
            f"tree mismatch: {len(leaves_like)} vs {len(manifest['leaves'])}"
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        out = []
        for i, (meta, sh) in enumerate(zip(manifest["leaves"], shard_leaves)):
            arr = np.load(d / f"leaf_{i}.npy")
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in leaf {i} "
                              f"(crc {crc} != {meta['crc32']})")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
