"""Summarize an observability run directory (DESIGN.md §12, §16).

``python -m repro.launch.obs_report RUNDIR [--json]``
``python -m repro.launch.obs_report INCIDENT.json``

Reads the artifacts an ``--obs-dir`` run writes — ``metrics.json``
(registry snapshot), ``telemetry.jsonl`` (one record per solver
iteration), ``trace.json`` (Chrome-trace spans) — and prints the
operator's questions back as tables: counter totals, latency
percentiles per histogram (p50/p90/p99, aggregated across label sets so
a cluster's per-worker block-step series also report cluster-wide),
bytes per iteration by message type, and span hotspots (where the wall
time went). ``--json`` emits the same summary as one JSON document.

Service mode (automatic): when the metrics snapshot carries ``service.*``
series — the run dir belongs to a :class:`FitFrontend` — the report adds
the serving view: terminal-status mix, warm/cold latency split,
degrade-why breakdown, and a per-tenant admission table.  Flight-
recorder incident dumps under ``RUNDIR/incidents/`` are summarized too,
and pointing the CLI at one incident file pretty-prints it.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.obs import (
    METRICS_FILE,
    TELEMETRY_FILE,
    TRACE_FILE,
    load_incident,
    load_trace,
    merged_histogram,
    read_jsonl,
    span_hotspots,
    summarize_histogram,
)


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
    return "\n".join([line(header), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])


# -- metrics.json -----------------------------------------------------------

def summarize_metrics(snap: dict) -> dict:
    counters = sorted(
        ({"name": e["name"], "labels": e.get("labels", {}),
          "value": e["value"]} for e in snap.get("counters", [])),
        key=lambda e: (e["name"], sorted(e["labels"].items())))
    hists: Dict[str, List[dict]] = {}
    for e in snap.get("histograms", []):
        hists.setdefault(e["name"], []).append(e)
    out_h = []
    for name in sorted(hists):
        entries = hists[name]
        # seconds-valued histograms report in ms
        scale = 1e3 if name.endswith("_s") else 1.0
        unit = "ms" if scale == 1e3 else ""
        for e in sorted(entries,
                        key=lambda e: sorted(e.get("labels", {}).items())):
            out_h.append({"name": name + _fmt_labels(e.get("labels", {})),
                          "unit": unit,
                          **summarize_histogram(e, scale=scale)})
        if len(entries) > 1:   # cluster-wide view across label sets
            agg = merged_histogram(entries).to_snapshot()
            out_h.append({"name": name + "{ALL}", "unit": unit,
                          **summarize_histogram(agg, scale=scale)})
    return {"counters": counters, "histograms": out_h,
            "gauges": snap.get("gauges", [])}


# -- service view (frontend run dirs) ---------------------------------------

def _labeled_sum(counters: List[dict], name: str,
                 label: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for e in counters:
        if e["name"] == name and label in e.get("labels", {}):
            key = e["labels"][label]
            out[key] = out.get(key, 0) + e["value"]
    return out


def summarize_service(snap: dict) -> Optional[dict]:
    """The serving view of a metrics snapshot; None when the snapshot
    has no ``service.*`` series (a solver run, not a frontend run)."""
    counters = snap.get("counters", [])
    if not any(e["name"].startswith("service.") for e in counters):
        return None

    def total(name: str) -> float:
        return sum(e["value"] for e in counters if e["name"] == name)

    seen = _labeled_sum(counters, "service.fit_seen", "tenant")
    admitted = _labeled_sum(counters, "admission.admitted", "tenant")
    rej_tenant = _labeled_sum(counters, "admission.rejected", "tenant")
    tenants = sorted(set(seen) | set(admitted) | set(rej_tenant))
    per_tenant = [{"tenant": t,
                   "fit_seen": int(seen.get(t, 0)),
                   "admitted": int(admitted.get(t, 0)),
                   "rejected": int(rej_tenant.get(t, 0))}
                  for t in tenants]

    latency = {}
    for e in snap.get("histograms", []):
        if e["name"] == "server.fit_latency_s":
            kind = e.get("labels", {}).get("kind", "?")
            latency[kind] = summarize_histogram(e, scale=1e3)
        elif e["name"] in ("service.queue_wait_s",
                           "service.dispatch_wait_s"):
            latency[e["name"].split(".", 1)[1]] = summarize_histogram(
                e, scale=1e3)

    return {
        "status_mix": {k: int(v) for k, v in sorted(_labeled_sum(
            counters, "service.responses", "status").items())},
        "degrade_why": {k: int(v) for k, v in sorted(_labeled_sum(
            counters, "service.degraded", "why").items())},
        "reject_reason": {k: int(v) for k, v in sorted(_labeled_sum(
            counters, "admission.rejected", "reason").items())},
        "per_tenant": per_tenant,
        "latency_ms": latency,
        "breaker_trips": int(total("service.breaker_trips")),
        "severed": int(total("service.severed")),
        "undeliverable": int(total("service.undeliverable")),
    }


# -- flight-recorder incidents ----------------------------------------------

def summarize_incident(path: str) -> dict:
    """One incident dump -> a summary dict (used for both the per-file
    CLI mode and the run-dir listing)."""
    doc = load_incident(path)
    events = doc.get("events", [])
    by_kind: Dict[str, int] = {}
    for e in events:
        k = str(e.get("kind", "?"))
        by_kind[k] = by_kind.get(k, 0) + 1
    statuses = [e for e in events if e.get("kind") == "respond"]
    return {
        "path": path,
        "reason": doc.get("reason"),
        "t_wall": doc.get("t_wall"),
        "window_s": doc.get("window_s"),
        "process": doc.get("process"),
        "trigger": doc.get("trigger"),
        "events": len(events),
        "events_by_kind": dict(sorted(by_kind.items())),
        "last_status_transitions": [
            {k: e.get(k) for k in ("status", "tenant", "rid",
                                   "latency_s", "trace_id")
             if e.get(k) is not None}
            for e in statuses[-8:]],
    }


def print_incident(summary: dict):
    print(f"== flight-recorder incident: {summary['path']} ==")
    print(f"reason: {summary['reason']}   window: {summary['window_s']}s"
          f"   events: {summary['events']}")
    proc = summary.get("process") or {}
    if proc:
        print(f"process: {proc.get('name')} (pid {proc.get('pid')})")
    trig = summary.get("trigger") or {}
    if trig:
        print("trigger: " + "  ".join(f"{k}={v}" for k, v in trig.items()))
    if summary["events_by_kind"]:
        print("\nevents by kind:")
        print(_table([[k, str(v)] for k, v in
                      summary["events_by_kind"].items()],
                     ["kind", "count"]))
    last = summary.get("last_status_transitions") or []
    if last:
        print("\nlast status transitions:")
        print(_table(
            [[str(e.get("status", "-")), str(e.get("tenant", "-")),
              str(e.get("rid", "-")), _fmt(e.get("latency_s")),
              str(e.get("trace_id", "-"))] for e in last],
            ["status", "tenant", "rid", "latency_s", "trace_id"]))


# -- telemetry.jsonl --------------------------------------------------------

def summarize_telemetry(records: List[dict]) -> Optional[dict]:
    iters = [r for r in records if "iter" in r]
    if not iters:
        return None
    last = iters[-1]
    by_type: Dict[str, int] = {}
    for r in iters:
        for key in ("tx_bytes", "rx_bytes"):
            for t, v in (r.get(key) or {}).items():
                by_type[f"{key}.{t}"] = by_type.get(f"{key}.{t}", 0) + v
    n = len(iters)
    out = {
        "iterations": n,
        "final": {k: last.get(k) for k in
                  ("iter", "objective", "primal_res", "dual_res")},
        "bytes_per_iter_by_type": {t: round(v / n, 1)
                                   for t, v in sorted(by_type.items())},
    }
    iter_s = [r["iter_s"] for r in iters if r.get("iter_s") is not None]
    if iter_s:
        out["mean_iter_s"] = round(sum(iter_s) / len(iter_s), 6)
    return out


# -- report -----------------------------------------------------------------

def build_report(rundir: str) -> dict:
    report: dict = {"rundir": rundir}
    mpath = os.path.join(rundir, METRICS_FILE)
    if os.path.exists(mpath):
        with open(mpath) as f:
            snap = json.load(f)
        report["metrics"] = summarize_metrics(snap)
        service = summarize_service(snap)
        if service is not None:
            report["service"] = service
    tpath = os.path.join(rundir, TELEMETRY_FILE)
    if os.path.exists(tpath):
        report["telemetry"] = summarize_telemetry(read_jsonl(tpath))
    trpath = os.path.join(rundir, TRACE_FILE)
    if os.path.exists(trpath):
        report["hotspots"] = span_hotspots(load_trace(trpath))
    incidents = sorted(glob.glob(os.path.join(rundir, "incidents",
                                              "incident-*.json")))
    if incidents:
        report["incidents"] = [summarize_incident(p) for p in incidents]
    return report


def print_report(report: dict, top: int = 15):
    print(f"== obs report: {report['rundir']} ==")
    svc = report.get("service")
    if svc:
        print("\nservice status mix:")
        print(_table([[s, str(v)] for s, v in svc["status_mix"].items()],
                     ["status", "count"]))
        if svc["degrade_why"]:
            print("\ndegraded responses by cause:")
            print(_table([[w, str(v)] for w, v in
                          svc["degrade_why"].items()], ["why", "count"]))
        if svc["reject_reason"]:
            print("\nrejections by reason:")
            print(_table([[w, str(v)] for w, v in
                          svc["reject_reason"].items()],
                         ["reason", "count"]))
        if svc["per_tenant"]:
            print("\nper-tenant admission:")
            print(_table(
                [[t["tenant"], str(t["fit_seen"]), str(t["admitted"]),
                  str(t["rejected"])] for t in svc["per_tenant"]],
                ["tenant", "fit_seen", "admitted", "rejected"]))
        if svc["latency_ms"]:
            print("\nservice latency (ms):")
            print(_table(
                [[k, _fmt(h["count"]), _fmt(h["mean"]), _fmt(h["p50"]),
                  _fmt(h["p90"]), _fmt(h["p99"]), _fmt(h["max"])]
                 for k, h in sorted(svc["latency_ms"].items())],
                ["series", "count", "mean", "p50", "p90", "p99", "max"]))
        print(f"\nbreaker trips: {svc['breaker_trips']}   "
              f"severed conns: {svc['severed']}   "
              f"undeliverable: {svc['undeliverable']}")
    incidents = report.get("incidents")
    if incidents:
        print(f"\nflight-recorder incidents ({len(incidents)}):")
        print(_table(
            [[os.path.basename(i["path"]), str(i["reason"]),
              str(i["events"])] for i in incidents],
            ["file", "reason", "events"]))
    tel = report.get("telemetry")
    if tel:
        fin = tel["final"]
        print(f"\niterations: {tel['iterations']}"
              + (f"  (mean {tel['mean_iter_s']*1e3:.2f} ms/iter)"
                 if tel.get("mean_iter_s") is not None else ""))
        print("final: " + "  ".join(
            f"{k}={_fmt(fin[k])}" for k in fin if fin[k] is not None))
        if tel["bytes_per_iter_by_type"]:
            print("\nbytes/iter by message type:")
            print(_table([[t, f"{v:.1f}"] for t, v in
                          tel["bytes_per_iter_by_type"].items()],
                         ["message", "bytes/iter"]))
    met = report.get("metrics")
    if met:
        if met["counters"]:
            print("\ncounters:")
            print(_table(
                [[e["name"] + _fmt_labels(e["labels"]), _fmt(e["value"])]
                 for e in met["counters"]], ["counter", "value"]))
        if met["histograms"]:
            print("\nlatency histograms:")
            print(_table(
                [[h["name"], h["unit"], _fmt(h["count"]), _fmt(h["mean"]),
                  _fmt(h["p50"]), _fmt(h["p90"]), _fmt(h["p99"]),
                  _fmt(h["max"])] for h in met["histograms"]],
                ["histogram", "unit", "count", "mean", "p50", "p90",
                 "p99", "max"]))
    hot = report.get("hotspots")
    if hot:
        print(f"\nspan hotspots (top {top}):")
        print(_table(
            [[h["name"], _fmt(h["count"]), _fmt(h["total_ms"]),
              _fmt(h["mean_ms"])] for h in hot[:top]],
            ["span", "count", "total_ms", "mean_ms"]))
    if not (tel or met or hot or report.get("service")
            or report.get("incidents")):
        print("(no observability artifacts found — was the run launched "
              "with --obs-dir?)")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize an --obs-dir run directory (or pretty-"
                    "print one flight-recorder incident file)")
    ap.add_argument("rundir", help="run directory holding trace.json / "
                                   "metrics.json / telemetry.jsonl, or a "
                                   "flight-recorder incident-*.json file")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as one JSON document")
    ap.add_argument("--top", type=int, default=15,
                    help="span-hotspot rows to print")
    args = ap.parse_args(argv)
    if os.path.isfile(args.rundir):
        summary = summarize_incident(args.rundir)
        if args.json:
            print(json.dumps(summary, indent=2))
        else:
            print_incident(summary)
        return summary
    report = build_report(args.rundir)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print_report(report, top=args.top)
    return report


if __name__ == "__main__":
    main()
