"""Summarize an observability run directory (DESIGN.md §12).

``python -m repro.launch.obs_report RUNDIR [--json]``

Reads the three artifacts an ``--obs-dir`` run writes —
``metrics.json`` (registry snapshot), ``telemetry.jsonl`` (one record
per solver iteration), ``trace.json`` (Chrome-trace spans) — and prints
the operator's questions back as tables: counter totals, latency
percentiles per histogram (p50/p90/p99, aggregated across label sets so
a cluster's per-worker block-step series also report cluster-wide),
bytes per iteration by message type, and span hotspots (where the wall
time went). ``--json`` emits the same summary as one JSON document.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.obs import (
    METRICS_FILE,
    TELEMETRY_FILE,
    TRACE_FILE,
    load_trace,
    merged_histogram,
    read_jsonl,
    span_hotspots,
    summarize_histogram,
)


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
    return "\n".join([line(header), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])


# -- metrics.json -----------------------------------------------------------

def summarize_metrics(snap: dict) -> dict:
    counters = sorted(
        ({"name": e["name"], "labels": e.get("labels", {}),
          "value": e["value"]} for e in snap.get("counters", [])),
        key=lambda e: (e["name"], sorted(e["labels"].items())))
    hists: Dict[str, List[dict]] = {}
    for e in snap.get("histograms", []):
        hists.setdefault(e["name"], []).append(e)
    out_h = []
    for name in sorted(hists):
        entries = hists[name]
        # seconds-valued histograms report in ms
        scale = 1e3 if name.endswith("_s") else 1.0
        unit = "ms" if scale == 1e3 else ""
        for e in sorted(entries,
                        key=lambda e: sorted(e.get("labels", {}).items())):
            out_h.append({"name": name + _fmt_labels(e.get("labels", {})),
                          "unit": unit,
                          **summarize_histogram(e, scale=scale)})
        if len(entries) > 1:   # cluster-wide view across label sets
            agg = merged_histogram(entries).to_snapshot()
            out_h.append({"name": name + "{ALL}", "unit": unit,
                          **summarize_histogram(agg, scale=scale)})
    return {"counters": counters, "histograms": out_h,
            "gauges": snap.get("gauges", [])}


# -- telemetry.jsonl --------------------------------------------------------

def summarize_telemetry(records: List[dict]) -> Optional[dict]:
    iters = [r for r in records if "iter" in r]
    if not iters:
        return None
    last = iters[-1]
    by_type: Dict[str, int] = {}
    for r in iters:
        for key in ("tx_bytes", "rx_bytes"):
            for t, v in (r.get(key) or {}).items():
                by_type[f"{key}.{t}"] = by_type.get(f"{key}.{t}", 0) + v
    n = len(iters)
    out = {
        "iterations": n,
        "final": {k: last.get(k) for k in
                  ("iter", "objective", "primal_res", "dual_res")},
        "bytes_per_iter_by_type": {t: round(v / n, 1)
                                   for t, v in sorted(by_type.items())},
    }
    iter_s = [r["iter_s"] for r in iters if r.get("iter_s") is not None]
    if iter_s:
        out["mean_iter_s"] = round(sum(iter_s) / len(iter_s), 6)
    return out


# -- report -----------------------------------------------------------------

def build_report(rundir: str) -> dict:
    report: dict = {"rundir": rundir}
    mpath = os.path.join(rundir, METRICS_FILE)
    if os.path.exists(mpath):
        with open(mpath) as f:
            report["metrics"] = summarize_metrics(json.load(f))
    tpath = os.path.join(rundir, TELEMETRY_FILE)
    if os.path.exists(tpath):
        report["telemetry"] = summarize_telemetry(read_jsonl(tpath))
    trpath = os.path.join(rundir, TRACE_FILE)
    if os.path.exists(trpath):
        report["hotspots"] = span_hotspots(load_trace(trpath))
    return report


def print_report(report: dict, top: int = 15):
    print(f"== obs report: {report['rundir']} ==")
    tel = report.get("telemetry")
    if tel:
        fin = tel["final"]
        print(f"\niterations: {tel['iterations']}"
              + (f"  (mean {tel['mean_iter_s']*1e3:.2f} ms/iter)"
                 if tel.get("mean_iter_s") is not None else ""))
        print("final: " + "  ".join(
            f"{k}={_fmt(fin[k])}" for k in fin if fin[k] is not None))
        if tel["bytes_per_iter_by_type"]:
            print("\nbytes/iter by message type:")
            print(_table([[t, f"{v:.1f}"] for t, v in
                          tel["bytes_per_iter_by_type"].items()],
                         ["message", "bytes/iter"]))
    met = report.get("metrics")
    if met:
        if met["counters"]:
            print("\ncounters:")
            print(_table(
                [[e["name"] + _fmt_labels(e["labels"]), _fmt(e["value"])]
                 for e in met["counters"]], ["counter", "value"]))
        if met["histograms"]:
            print("\nlatency histograms:")
            print(_table(
                [[h["name"], h["unit"], _fmt(h["count"]), _fmt(h["mean"]),
                  _fmt(h["p50"]), _fmt(h["p90"]), _fmt(h["p99"]),
                  _fmt(h["max"])] for h in met["histograms"]],
                ["histogram", "unit", "count", "mean", "p50", "p90",
                 "p99", "max"]))
    hot = report.get("hotspots")
    if hot:
        print(f"\nspan hotspots (top {top}):")
        print(_table(
            [[h["name"], _fmt(h["count"]), _fmt(h["total_ms"]),
              _fmt(h["mean_ms"])] for h in hot[:top]],
            ["span", "count", "total_ms", "mean_ms"]))
    if not (tel or met or hot):
        print("(no observability artifacts found — was the run launched "
              "with --obs-dir?)")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize an --obs-dir run directory")
    ap.add_argument("rundir", help="directory holding trace.json / "
                                   "metrics.json / telemetry.jsonl")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as one JSON document")
    ap.add_argument("--top", type=int, default=15,
                    help="span-hotspot rows to print")
    args = ap.parse_args(argv)
    report = build_report(args.rundir)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print_report(report, top=args.top)
    return report


if __name__ == "__main__":
    main()
