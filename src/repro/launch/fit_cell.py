"""Dry-run cells for the PAPER'S OWN workload: distributed transpose-
reduction ADMM at production scale, lowered on the production mesh.

Cells (rows sharded over every mesh axis — each chip is a paper 'node'):
  star_f32   GSC-II scale: m=950,272,000 rows x n=307 features, f32
             (the paper's 1.8 TB Table-1 dataset; 4.56 GB/chip)
  star_bf16  beyond-paper: bf16 data residency, f32 Gram/solve accumulation
             (halves the memory-bound iteration term; DESIGN.md §3 numerics)
  fig1_bf16  Fig-1 scale: m=368,640,000 x n=2000, bf16 (5.8 GB/chip)

Two programs are lowered per cell:
  setup: G = psum_i(D_i^T D_i); Cholesky factor          (one-off)
  iter:  d = psum_i(D_i^T (y_i - lam_i)); x = solve(L,d);
         y,lam = fused prox update                        (per ADMM iteration)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import gram as gram_lib
from repro.core.prox import make_logistic
from repro.sharding.compat import shard_map

CELLS = {
    "star_f32": dict(m=950_272_000, n=307, dtype=jnp.float32),
    "star_bf16": dict(m=950_272_000, n=307, dtype=jnp.bfloat16),
    "fig1_bf16": dict(m=368_640_000, n=2000, dtype=jnp.bfloat16),
}


def build_fit_cell(name: str, mesh, tau: float = 0.1):
    spec = CELLS[name]
    m, n, dtype = spec["m"], spec["n"], spec["dtype"]
    axes = tuple(mesh.axis_names)            # every chip is a 'node'
    nshards = mesh.size
    assert m % nshards == 0
    loss = make_logistic()

    def setup_local(D_loc):
        # one-shot Gram here (not the scan-chunked form) so the dry-run's
        # cost_analysis counts the FLOPs; production uses the Pallas kernel
        # with identical semantics (f32 accumulation).
        G = gram_lib.gram(D_loc)
        G = jax.lax.psum(G, axes)
        return gram_lib.gram_factor(G)

    def iter_local(D_loc, aux_loc, y, lam, L):
        """Baseline Alg.2 iteration: TWO streaming passes over D
        (d = D^T(y-lam), then Dx)."""
        acc = jnp.float32
        d = jax.lax.psum(D_loc.astype(acc).T @ (y - lam), axes)
        x = gram_lib.gram_solve(L, d)
        Dx = D_loc.astype(acc) @ x
        y_new = loss.prox(Dx + lam, 1.0 / tau, aux_loc)
        lam_new = lam + Dx - y_new
        obj = jax.lax.psum(loss.value(y_new, aux_loc), axes)
        return x, y_new, lam_new, obj

    def fused_iter_local(D_loc, aux_loc, y, lam, x, n_blocks: int = 8):
        """§Perf beyond-paper: ONE pass over D per iteration.

        Reorder Alg. 2 around the row-block stream: for each tile D_b
        (loaded once), compute Dx_b with the incoming x, the y_b/lam_b
        prox updates, and accumulate d_b = D_b^T (y_b - lam_b) — then one
        psum + solve produce the NEXT x. Identical iterates, half the HBM
        traffic of the 2-pass baseline (the memory term IS the bottleneck).
        Blocks are a python loop so cost_analysis counts every pass.
        """
        acc = jnp.float32
        m_loc = D_loc.shape[0]
        bs = m_loc // n_blocks
        d = jnp.zeros((n,), acc)
        y_out, lam_out = [], []
        obj = jnp.zeros((), acc)
        for b in range(n_blocks):
            # static slices: alias into D (no copy), unlike dynamic_slice
            Db = D_loc[b * bs:(b + 1) * bs].astype(acc)
            yb = y[b * bs:(b + 1) * bs]
            lb = lam[b * bs:(b + 1) * bs]
            ab = aux_loc[b * bs:(b + 1) * bs]
            Dx_b = Db @ x
            y_b = loss.prox(Dx_b + lb, 1.0 / tau, ab)
            l_b = lb + Dx_b - y_b
            d = d + Db.T @ (y_b - l_b)
            obj = obj + loss.value(y_b, ab)
            y_out.append(y_b)
            lam_out.append(l_b)
        d = jax.lax.psum(d, axes)
        obj = jax.lax.psum(obj, axes)
        return (d, jnp.concatenate(y_out), jnp.concatenate(lam_out), obj)

    setup = shard_map(
        setup_local, mesh=mesh,
        in_specs=(P(axes, None),), out_specs=P(), check_vma=False)
    one_iter = shard_map(
        iter_local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(axes), P(axes), P()),
        out_specs=(P(), P(axes), P(axes), P()), check_vma=False)
    fused_iter = shard_map(
        fused_iter_local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(axes), P(axes), P()),
        out_specs=(P(), P(axes), P(axes), P()), check_vma=False)

    ns_rows = NamedSharding(mesh, P(axes, None))
    ns_vec = NamedSharding(mesh, P(axes))
    ns_rep = NamedSharding(mesh, P())
    D_in = jax.ShapeDtypeStruct((m, n), dtype, sharding=ns_rows)
    aux_in = jax.ShapeDtypeStruct((m,), jnp.float32, sharding=ns_vec)
    y_in = jax.ShapeDtypeStruct((m,), jnp.float32, sharding=ns_vec)
    L_in = jax.ShapeDtypeStruct((n, n), jnp.float32, sharding=ns_rep)
    x_in = jax.ShapeDtypeStruct((n,), jnp.float32, sharding=ns_rep)
    return {
        "setup": (jax.jit(setup), (D_in,)),
        "iter": (jax.jit(one_iter, donate_argnums=(2, 3)),
                 (D_in, aux_in, y_in, y_in, L_in)),
        "fused_iter": (jax.jit(fused_iter, donate_argnums=(2, 3)),
                       (D_in, aux_in, y_in, y_in, x_in)),
    }
