import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder CPU devices, prove the sharding config is coherent, and
extract memory / cost / collective-traffic analysis for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]

Nothing is executed on devices: inputs are ShapeDtypeStructs; only
.lower().compile() runs. The two XLA_FLAGS lines above MUST stay the first
statements in this module (jax locks the device count at first init).
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding import compat

import repro.configs as configs_lib
from repro.launch.input_specs import SHAPES, abstract_params, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.decode import decode_step
from repro.models.decode import prefill as prefill_fn
from repro.optim.optimizers import make_optimizer
from repro.roofline.hlo import parse_collectives, roofline_terms
from repro.runtime.steps import make_serve_step, make_train_step
from repro.sharding import specs as spec_lib
from repro.sharding.util import DP, filter_spec

ARCHES = [
    "arctic-480b", "olmoe-1b-7b", "rwkv6-1.6b", "qwen3-14b",
    "command-r-35b", "phi3-medium-14b", "qwen3-8b",
    "seamless-m4t-large-v2", "qwen2-vl-72b", "recurrentgemma-9b",
]


def _ns(mesh, spec):
    return NamedSharding(mesh, filter_spec(spec, mesh.axis_names))


def _with_shardings(mesh, tree, spec_tree):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=_ns(mesh, sp)),
        tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def build_cell(arch: str, shape: str, mesh, *, microbatches: int = 1,
               cfg_override=None):
    """Returns (fn, example_args) ready for jit(...).lower(*args)."""
    cfg = cfg_override if cfg_override is not None else configs_lib.get(arch)
    if SHAPES[shape]["kind"] != "train" and cfg.parallelism != "tp":
        # serving always uses TP: decode batches do not shard over 256+ ways
        cfg = dataclasses.replace(cfg, parallelism="tp")
    spec = input_specs(cfg, shape)
    params_abs = abstract_params(cfg)
    if spec["kind"] == "decode":
        # serving checkpoints are bf16 (deployment dtype; halves weight HBM)
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape,
                jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype),
            params_abs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    pspec = spec_lib.param_spec(params_abs, cfg.parallelism)
    if (cfg.fsdp or cfg.parallelism == "fsdp") and spec["kind"] == "train":
        # ZeRO-3/FSDP: params also sharded over DP (all-gathered per layer)
        pspec = spec_lib.zero1_spec(pspec, params_abs, mesh,
                                    axes=cfg.dp_axes)
    params_in = _with_shardings(mesh, params_abs, pspec)

    if spec["kind"] == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospec = jax.tree.map(
            lambda _: P(), opt_abs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # ZeRO-1: state sharded over DP on top of the param's TP sharding.
        ospec = {
            k: spec_lib.zero1_spec(
                spec_lib.param_spec(v, cfg.parallelism), v, mesh,
                axes=cfg.dp_axes)
            for k, v in opt_abs.items()
        }
        opt_in = _with_shardings(mesh, opt_abs, ospec)
        bspec = spec_lib.batch_spec(spec["batch"], mesh, axes=cfg.dp_axes)
        batch_in = _with_shardings(mesh, spec["batch"], bspec)
        step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(mesh, P()))
        fn = make_train_step(cfg, opt, microbatches=microbatches)
        jfn = jax.jit(fn, donate_argnums=(0, 1))
        return jfn, (params_in, opt_in, batch_in, step_in)

    if spec["kind"] == "prefill":
        s_max = spec["s_max"]
        bspec = spec_lib.batch_spec(spec["batch"], mesh)
        batch_in = _with_shardings(mesh, spec["batch"], bspec)

        def fn(params, batch):
            return prefill_fn(params, cfg, s_max=s_max, **batch)

        return jax.jit(fn), (params_in, batch_in)

    # decode
    caches_abs = spec["caches"]
    cspec = spec_lib.cache_spec(caches_abs, mesh)
    caches_in = _with_shardings(mesh, caches_abs, cspec)
    tokens_in = jax.ShapeDtypeStruct(
        spec["tokens"].shape, spec["tokens"].dtype,
        sharding=_ns(mesh, spec_lib.divisible_spec(
            P(DP), spec["tokens"].shape, mesh)))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(mesh, P()))
    fn = make_serve_step(cfg)
    return jax.jit(fn, donate_argnums=(1,)), \
        (params_in, caches_in, tokens_in, pos_in)


def _cost_tuple(compiled):
    cost = compat.cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.wire_bytes), float(coll.operand_bytes),
            coll.by_kind(), len(coll.ops))


def extract_costs(arch: str, shape: str, mesh, *, microbatches: int = 1):
    """FLOPs/bytes/collective traffic by L-extrapolation.

    XLA's cost model counts while-loop bodies ONCE (trip counts unknown), so
    the full scanned lowering undercounts by ~num_layers x. We therefore
    lower small UNROLLED variants (scan_layers=False, unroll_inner=True —
    numerically identical control-flow changes) at L = unit and L = 2*unit
    layers, and extrapolate: total = non_layer + (L/unit) * delta. Hybrid
    patterns use the pattern length as the unit, plus a remainder lowering.
    """
    cfg = configs_lib.get(arch)
    unit = len(cfg.pattern) if cfg.family == "griffin" and cfg.pattern else 1
    L = cfg.num_layers
    rem = L % unit

    def reduced(nl):
        kw = dict(num_layers=nl, scan_layers=False, unroll_inner=True)
        if cfg.family == "encdec":
            kw["encoder_layers"] = nl
        return dataclasses.replace(cfg, **kw)

    def lower_cost(c):
        # ALWAYS microbatches=1 here: the grad-accumulation lax.scan would
        # hide (mb-1)/mb of the per-step cost from cost_analysis. Per-step
        # flops/bytes are microbatch-invariant; the full-L compile keeps the
        # real microbatch count for the memory analysis.
        jfn, args = build_cell(arch, shape, mesh, microbatches=1,
                               cfg_override=c)
        return _cost_tuple(jfn.lower(*args).compile())

    c1 = lower_cost(reduced(unit))
    c2 = lower_cost(reduced(2 * unit))
    delta = tuple(b - a for a, b in zip(c1[:4], c2[:4]))
    n_units = L // unit
    total = [a - d + n_units * d for a, d in zip(c1[:4], delta)]
    if rem:
        crem = lower_cost(reduced(2 * unit + rem))
        delta_rem = tuple(b - a for a, b in zip(c2[:4], crem[:4]))
        total = [t + dr for t, dr in zip(total, delta_rem)]
    return {"flops": total[0], "hbm_bytes": total[1],
            "wire_bytes": total[2], "operand_bytes": total[3],
            "per_unit": {"flops": delta[0], "hbm_bytes": delta[1],
                         "wire_bytes": delta[2]},
            "non_layer": {"flops": c1[0] - delta[0],
                          "hbm_bytes": c1[1] - delta[1],
                          "wire_bytes": c1[2] - delta[2]},
            "collective_by_kind_unit2": c2[4]}


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: Path,
             microbatches: int = 1, tag: str = "",
             skip_full: bool = False, skip_cost: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = configs_lib.get(arch)
    t0 = time.time()
    with compat.use_mesh(mesh):
        if skip_full:
            mem = None
            t_lower = t_compile = 0.0
        else:
            jfn, args = build_cell(arch, shape, mesh,
                                   microbatches=microbatches)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
        if skip_cost:
            costs = {"flops": 0.0, "hbm_bytes": 0.0, "wire_bytes": 0.0,
                     "operand_bytes": 0.0, "per_unit": {}, "non_layer": {},
                     "collective_by_kind_unit2": {}}
        else:
            costs = extract_costs(arch, shape, mesh,
                                  microbatches=microbatches)

    flops = costs["flops"]
    hbm_bytes = costs["hbm_bytes"]
    terms = roofline_terms(flops, hbm_bytes, costs["wire_bytes"])
    model_flops = 6.0 * cfg.active_param_count() \
        * SHAPES[shape]["batch"] * SHAPES[shape]["seq"]
    if SHAPES[shape]["kind"] == "decode":
        model_flops = 6.0 * cfg.active_param_count() * SHAPES[shape]["batch"]
    if SHAPES[shape]["kind"] == "prefill":
        model_flops = 2.0 * cfg.active_param_count() \
            * SHAPES[shape]["batch"] * SHAPES[shape]["seq"]
    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": flops,
            "hbm_bytes": hbm_bytes,
            "collective_wire_bytes": costs["wire_bytes"],
            "collective_operand_bytes": costs["operand_bytes"],
            "collective_by_kind_unit2": costs["collective_by_kind_unit2"],
            "per_unit": costs["per_unit"],
            "non_layer": costs["non_layer"],
        },
        "roofline": terms,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flop_ratio": (model_flops / n_chips) / flops if flops else 0.0,
    }
    if mem is not None:
        result["per_device"].update({
            "peak_memory_bytes": int(mem.temp_size_in_bytes
                                     + mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     - mem.alias_size_in_bytes),
            "argument_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
        })
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape}__{result['mesh']}{tag}.json"
    (out_dir / name).write_text(json.dumps(result, indent=2))
    return result


def run_fit_cell(name: str, *, multi_pod: bool, out_dir: Path, tag: str = ""):
    from repro.launch.fit_cell import CELLS, build_fit_cell
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = CELLS[name]
    with compat.use_mesh(mesh):
        built = build_fit_cell(name, mesh)
        result = {"cell": f"admm_{name}", "m": spec["m"], "n": spec["n"],
                  "dtype": str(spec["dtype"].__name__),
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "chips": mesh.size, "status": "ok"}
        for phase, (jfn, args_) in built.items():
            t0 = time.time()
            compiled = jfn.lower(*args_).compile()
            mem = compiled.memory_analysis()
            cost = compat.cost_analysis(compiled)
            coll = parse_collectives(compiled.as_text())
            flops = float(cost.get("flops", 0.0))
            hbm = float(cost.get("bytes accessed", 0.0))
            terms = roofline_terms(flops, hbm, coll.wire_bytes)
            result[phase] = {
                "compile_s": round(time.time() - t0, 1),
                "flops": flops, "hbm_bytes": hbm,
                "collective_wire_bytes": coll.wire_bytes,
                "collective_by_kind": coll.by_kind(),
                "peak_memory_bytes": int(mem.temp_size_in_bytes
                                         + mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         - mem.alias_size_in_bytes),
                "roofline": terms,
            }
            t = terms
            print(f"[OK] admm_{name}:{phase} x {result['mesh']}: "
                  f"bottleneck={t['bottleneck']} "
                  f"compute={t['compute_s']*1e3:.2f}ms "
                  f"mem={t['memory_s']*1e3:.2f}ms "
                  f"coll={t['collective_s']*1e3:.3f}ms "
                  f"peak={result[phase]['peak_memory_bytes']/2**30:.2f}GiB",
                  flush=True)
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"admm_{name}__{result['mesh']}{tag}.json").write_text(
            json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--cost-only", action="store_true",
                    help="skip the full-L compile (roofline terms only)")
    ap.add_argument("--no-cost", action="store_true",
                    help="full-L compile proof only (multi-pod pass)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fit-cell", default="",
                    help="ADMM fit cell: star_f32|star_bf16|fig1_bf16")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. sp_collectives=False)")
    args = ap.parse_args()
    if args.set:
        import repro.configs as _c
        _orig_get = _c.get

        def _patched(name):
            cfg = _orig_get(name)
            kv = {}
            for item in args.set:
                k, v = item.split("=", 1)
                cur = getattr(cfg, k)
                if isinstance(cur, bool):
                    v = v.lower() in ("1", "true", "yes")
                elif isinstance(cur, int):
                    v = int(v)
                elif isinstance(cur, float):
                    v = float(v)
                kv[k] = v
            return dataclasses.replace(cfg, **kv)

        _c.get = _patched
        configs_lib.get = _patched
    out_dir = Path(args.out)

    if args.fit_cell:
        run_fit_cell(args.fit_cell, multi_pod=args.multi_pod,
                     out_dir=out_dir, tag=args.tag)
        return

    cells = []
    if args.all:
        for arch in ARCHES:
            cfg = configs_lib.get(arch)
            for shape in SHAPES:
                if shape in cfg.skip_shapes:
                    continue
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                             microbatches=args.microbatches, tag=args.tag,
                             skip_full=args.cost_only,
                             skip_cost=args.no_cost)
                t = r["roofline"]
                print(f"[OK] {label}: compile={r['compile_s']}s "
                      f"bottleneck={t['bottleneck']} "
                      f"compute={t['compute_s']:.4f}s "
                      f"mem={t['memory_s']:.4f}s "
                      f"coll={t['collective_s']:.4f}s "
                      f"peak_mem={r['per_device'].get('peak_memory_bytes', 0)/2**30:.2f}GiB "
                      f"useful={r['useful_flop_ratio']:.2f}",
                      flush=True)
            except Exception as e:
                failures += 1
                out_dir.mkdir(parents=True, exist_ok=True)
                name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}{args.tag}.FAILED.json"
                (out_dir / name).write_text(json.dumps(
                    {"arch": arch, "shape": shape, "status": "failed",
                     "error": traceback.format_exc()}, indent=2))
                print(f"[FAIL] {label}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
