"""Production mesh definition.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required by the dry-run contract).

Topology: TPU v5e pods of 256 chips arranged (data=16, model=16); the
multi-pod mesh prepends a 'pod' axis (DCN) for 2 pods = 512 chips. The
'model' axis carries TP/EP (ICI-local); ('pod','data') carry DP and the
ADMM row-sharding.
"""
from __future__ import annotations

from repro.sharding import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (4,2) on 8 host devices)."""
    return compat.make_mesh(tuple(shape), tuple(axes))
