"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the local device(s) (use --smoke for reduced configs on
CPU; the production mesh path is exercised by dryrun.py). Integrates the
full fault-tolerance loop: deterministic data pipeline, periodic atomic
checkpoints (background thread), resume-from-latest, and failure injection
for the restart tests.
"""
from __future__ import annotations

import argparse
import os
import signal
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs_lib
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models.model import init_params
from repro.optim.optimizers import make_optimizer
from repro.runtime.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--die-at-step", type=int, default=-1,
                    help="failure injection: SIGKILL self at this step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = configs_lib.get_smoke(args.arch) if args.smoke \
        else configs_lib.get(args.arch)
    opt = make_optimizer(cfg.optimizer, lr=args.lr,
                         total_steps=max(args.steps, 2))
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=args.batch,
                         seq_len=args.seq, seed=args.seed,
                         frontend=cfg.frontend, d_model=cfg.d_model,
                         mrope=cfg.mrope)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    if ckpt and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start = extra["step"] + 1
        print(f"[resume] restored step {extra['step']}, continuing at {start}",
              flush=True)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step, (params, opt_state), extra={"step": step},
                      background=True)
        if args.die_at_step == step:
            print(f"[failure-injection] SIGKILL at step {step}", flush=True)
            if ckpt:
                ckpt.wait()
            os.kill(os.getpid(), signal.SIGKILL)
    if ckpt:
        ckpt.wait()  # drain any background save before the final one
        if ckpt.latest_step() != args.steps - 1:
            ckpt.save(args.steps - 1, (params, opt_state),
                      extra={"step": args.steps - 1})
        ckpt.wait()
    print(f"[done] final loss {losses[-1]:.4f} (first {losses[0]:.4f})",
          flush=True)
    return losses


if __name__ == "__main__":
    main()
