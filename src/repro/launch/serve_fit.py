"""Fit-serving launcher: batched multi-problem serving from cached stats.

``python -m repro.launch.serve_fit --rows 20000 --features 128
     --requests 64 --problem ridge [--window 16] [--mu-path]``

Registers a synthetic dataset once (ONE Gram pass), then drives a stream of
fit requests — fresh linear-probe label vectors, or a lasso mu-path with
``--mu-path`` — through the micro-batching FitServer, and reports latency
against the naive per-request lower bound plus the server's cost counters.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fit import fit
from repro.service import FitRequest, FitServer
from repro.service.batching import lasso_mu_path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="ridge",
                    choices=["ridge", "lasso", "elastic_net", "nnls"])
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--mu-path", action="store_true",
                    help="serve a lasso regularization path instead of "
                         "fresh-label probes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    m, n = args.rows, args.features
    D = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(m), jnp.float32)

    srv = FitServer(window=args.window)
    t0 = time.time()
    fp = srv.register_dataset(D, b)
    jax.block_until_ready(srv.stats_for(fp).G)
    print(f"registered {m:,} x {n} dataset in {time.time()-t0:.2f}s "
          f"(fingerprint {fp[:12]}..., ONE Gram pass)", flush=True)

    if args.mu_path:
        mus = jnp.logspace(-2, 1, args.requests)
        t0 = time.time()
        X = lasso_mu_path(srv.stats_for(fp).G, srv.stats_for(fp).c, mus,
                          iters=args.iters)
        jax.block_until_ready(X)
        dt = time.time() - t0
        nnz = (np.abs(np.asarray(X)) > 1e-5).sum(axis=1)
        print(f"lasso mu-path: {args.requests} solves sharing one Gram in "
              f"{dt:.2f}s ({dt/args.requests*1e3:.1f} ms/solve); "
              f"support {nnz.max()} -> {nnz.min()} along the path")
        return

    reqs = [
        FitRequest(problem=args.problem, fingerprint=fp,
                   b=rng.standard_normal(m).astype(np.float32),
                   mu=args.mu, iters=args.iters)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    resp = srv.serve(reqs)
    dt = time.time() - t0
    assert len(resp) == args.requests

    # naive lower bound: one request through the one-shot fit() path
    t0 = time.time()
    fit(args.problem, D.reshape(1, m, n), reqs[0].b.reshape(1, m),
        mu=args.mu, iters=args.iters)
    t_single = time.time() - t0

    print(f"served {args.requests} {args.problem} requests in {dt:.2f}s "
          f"({dt/args.requests*1e3:.1f} ms/request, window={args.window})")
    print(f"one-shot fit() of a single request: {t_single:.2f}s -> naive "
          f"serial estimate {t_single*args.requests:.1f}s, "
          f"speedup ~{t_single*args.requests/max(dt, 1e-9):.0f}x")
    print("counters:", srv.counters.snapshot())


if __name__ == "__main__":
    main()
