"""Fit-serving launcher: batched multi-problem serving from cached stats.

``python -m repro.launch.serve_fit --rows 20000 --features 128
     --requests 64 --problem ridge [--window 16] [--mu-path]``

Registers a synthetic dataset once (ONE Gram pass), then drives a stream of
fit requests — fresh linear-probe label vectors, or a lasso mu-path with
``--mu-path`` — through the micro-batching FitServer, and reports latency
against the naive per-request lower bound plus the server's cost counters.

``--port`` switches to the NETWORKED multi-tenant service (DESIGN.md
§15): a :class:`~repro.service.frontend.FitFrontend` over TCP with
admission control (``--max-queue``, ``--tenant-quota``), per-request
deadlines (``--deadline-s``), and optional seeded chaos against the
cold-solve backend (``--chaos-seed``). With ``--requests N`` it drives
N fits from two loopback tenants and prints the terminal-status mix +
latency; with ``--requests 0`` it serves until interrupted.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fit import fit
from repro.service import FitRequest, FitServer
from repro.service.batching import lasso_mu_path


def _serve_networked(args):
    from repro.cluster.chaos import FaultEvent, FaultInjector
    from repro.service.frontend import (
        SERVICE_DATA_PLANE,
        FitFrontend,
        FitServiceClient,
    )

    rng = np.random.default_rng(args.seed)
    m, n = args.rows, args.features
    D = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)

    chaos = None
    if args.chaos_seed is not None:
        crng = np.random.default_rng(args.chaos_seed)
        points = sorted(int(p) for p in crng.integers(
            2, max(3, args.requests or 64), size=3))
        chaos = FaultInjector(
            [FaultEvent(p, "svc", "slow", 1500.0) for p in points],
            data_plane=SERVICE_DATA_PLANE)
        print(f"chaos: slow cold backend at request seq {points} "
              f"(seed {args.chaos_seed})")

    obs = None
    if args.obs_dir is not None:
        from repro.obs import Observability
        obs = Observability(dir=args.obs_dir, process_name="frontend")

    fe = FitFrontend(window=args.window, max_queue=args.max_queue,
                     tenant_rate=args.tenant_quota,
                     default_deadline_s=args.deadline_s,
                     cold_budget_s=min(2.0, args.deadline_s),
                     port=args.port, chaos=chaos, obs=obs,
                     scrape_port=args.scrape_port)
    host, port = fe.address
    print(f"fit service listening on {host}:{port} "
          f"(max_queue={args.max_queue}, "
          f"tenant_quota={args.tenant_quota}, "
          f"deadline_s={args.deadline_s})", flush=True)
    if fe.scrape is not None:
        print(f"scrape endpoint: {fe.scrape.url('/metrics')}  "
              f"(also /metrics.json /healthz /slo)", flush=True)
    try:
        with FitServiceClient(fe.address, tenant="launcher") as setup:
            t0 = time.time()
            fp = setup.register(D, b)
            print(f"registered {m:,} x {n} dataset in "
                  f"{time.time()-t0:.2f}s (fingerprint {fp[:12]}...)",
                  flush=True)
        if not args.requests:
            print("serving until interrupted (Ctrl-C)...", flush=True)
            try:
                while True:
                    time.sleep(1.0)
            except KeyboardInterrupt:
                return
        lat = []
        statuses: dict = {}
        t_run = time.time()
        with FitServiceClient(fe.address, tenant="t0") as c0, \
                FitServiceClient(fe.address, tenant="t1") as c1:
            for i in range(args.requests):
                c = (c0, c1)[i % 2]
                problem = (args.problem if i % 3 else "logistic")
                t0 = time.time()
                kw = ({"mu": args.mu} if problem != "logistic" else {})
                r = c.fit(problem, fp, iters=args.iters,
                          deadline_s=args.deadline_s, timeout=120.0,
                          **kw)
                lat.append(time.time() - t0)
                statuses[r["status"]] = statuses.get(r["status"], 0) + 1
        dt = time.time() - t_run
        lat_ms = np.asarray(lat) * 1e3
        print(f"drove {args.requests} requests from 2 tenants in "
              f"{dt:.2f}s: statuses {statuses}; latency p50 "
              f"{np.percentile(lat_ms, 50):.1f} ms, p99 "
              f"{np.percentile(lat_ms, 99):.1f} ms")
        print("service counts:", fe.status_counts())
        print("zero lost requests:", fe.zero_lost_requests())
        slo = fe.slo_snapshot()
        print("slo:", {o["name"]: (o["ok"], o.get("burn_rate"))
                       for o in slo["objectives"]})
    finally:
        fe.close()
        if obs is not None:
            obs.finish()
            print(f"observability artifacts in {args.obs_dir}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="ridge",
                    choices=["ridge", "lasso", "elastic_net", "nnls"])
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--mu-path", action="store_true",
                    help="serve a lasso regularization path instead of "
                         "fresh-label probes")
    ap.add_argument("--seed", type=int, default=0)
    net = ap.add_argument_group("networked service (--port)")
    net.add_argument("--port", type=int, default=None,
                     help="serve over TCP on this port (0 = OS-assigned) "
                          "instead of driving the in-process server")
    net.add_argument("--max-queue", type=int, default=256,
                     help="bounded admission queue; beyond it requests "
                          "are answered status=rejected with a "
                          "retry-after hint")
    net.add_argument("--tenant-quota", type=float, default=None,
                     help="per-tenant token-bucket rate (requests/s); "
                          "default unmetered")
    net.add_argument("--deadline-s", type=float, default=30.0,
                     help="default per-request deadline; expired "
                          "requests are answered status=deadline")
    net.add_argument("--chaos-seed", type=int, default=None,
                     help="seed slow-cold-backend faults so the degrade "
                          "path (status=degraded from cached stats) is "
                          "observable")
    net.add_argument("--scrape-port", type=int, default=None,
                     help="expose /metrics (Prometheus text), /healthz "
                          "and /slo on this port (0 = OS-assigned)")
    net.add_argument("--obs-dir", default=None,
                     help="write metrics.json / trace.json / "
                          "telemetry.jsonl + flight-recorder incidents "
                          "into this run directory")
    args = ap.parse_args(argv)

    if args.port is not None:
        return _serve_networked(args)

    rng = np.random.default_rng(args.seed)
    m, n = args.rows, args.features
    D = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(m), jnp.float32)

    srv = FitServer(window=args.window)
    t0 = time.time()
    fp = srv.register_dataset(D, b)
    jax.block_until_ready(srv.stats_for(fp).G)
    print(f"registered {m:,} x {n} dataset in {time.time()-t0:.2f}s "
          f"(fingerprint {fp[:12]}..., ONE Gram pass)", flush=True)

    if args.mu_path:
        mus = jnp.logspace(-2, 1, args.requests)
        t0 = time.time()
        X = lasso_mu_path(srv.stats_for(fp).G, srv.stats_for(fp).c, mus,
                          iters=args.iters)
        jax.block_until_ready(X)
        dt = time.time() - t0
        nnz = (np.abs(np.asarray(X)) > 1e-5).sum(axis=1)
        print(f"lasso mu-path: {args.requests} solves sharing one Gram in "
              f"{dt:.2f}s ({dt/args.requests*1e3:.1f} ms/solve); "
              f"support {nnz.max()} -> {nnz.min()} along the path")
        return

    reqs = [
        FitRequest(problem=args.problem, fingerprint=fp,
                   b=rng.standard_normal(m).astype(np.float32),
                   mu=args.mu, iters=args.iters)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    resp = srv.serve(reqs)
    dt = time.time() - t0
    assert len(resp) == args.requests

    # naive lower bound: one request through the one-shot fit() path
    t0 = time.time()
    fit(args.problem, D.reshape(1, m, n), reqs[0].b.reshape(1, m),
        mu=args.mu, iters=args.iters)
    t_single = time.time() - t0

    print(f"served {args.requests} {args.problem} requests in {dt:.2f}s "
          f"({dt/args.requests*1e3:.1f} ms/request, window={args.window})")
    print(f"one-shot fit() of a single request: {t_single:.2f}s -> naive "
          f"serial estimate {t_single*args.requests:.1f}s, "
          f"speedup ~{t_single*args.requests/max(dt, 1e-9):.0f}x")
    print("counters:", srv.counters.snapshot())


if __name__ == "__main__":
    main()
