"""Distributed GLM fitting launcher — THE PAPER'S end-to-end driver.

``python -m repro.launch.fit --problem logistic --method transpose
     --nodes 8 --rows-per-node 50000 --features 200 [--heterogeneous]``

ONE topology knob selects where the solve runs (DESIGN.md §14):

``--executor local``      — in-memory single-process solve (default);
``--executor streaming``  — the out-of-core path (DESIGN.md §9): data is
    staged into a ``ShardedMatrixStore`` (host RAM, or memory-mapped
    under ``--store-dir``) sized by ``--device-budget-mb``, and the solve
    streams row blocks through the fused engine body — the paper's 5 Tb
    regime, where D never fits the accelerator;
``--executor shard_map``  — row-shard D over all local devices via
    shard_map and the transpose-reduction all-reduce (paper Alg. 2);
``--executor cluster``    — the solve over ``--workers N`` worker
    PROCESSES (DESIGN.md §11): each worker owns a set of row blocks and
    ships only n-length reductions per iteration, with heartbeats, block
    reassignment on worker death, and optional int8-compressed tree
    reduction (``--cluster-compress``) or bounded-staleness quorum
    aggregation (``--cluster-staleness S``). Lasso here is the paper-§4
    regression path: ONE distributed stats reduction, then a local FASTA
    solve — no per-iteration communication at all.

All four are the SAME shared driver over different SolveExecutor
backends (``repro.exec``). The old ``--streaming`` / ``--multi-device`` /
``--cluster N`` selector flags still work as deprecated aliases.

``--density p`` generates the data SPARSE (Bernoulli(p) pattern) and —
with the default ``--sparse-format blockcsr`` — runs the whole pipeline
through the padded block-CSR path (DESIGN.md §10): O(nnz) iterations,
O(nnz) Gram setup, nnz-scaled stores. ``--sparse-format dense``
densifies the same data and runs the dense path (the comparison knob).
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fit import FitResult, fit as fit_glm
from repro.core.oracles import (
    lasso_kkt_gap,
    logistic_objective,
    svm_objective,
)
from repro.core.prox import make_hinge, make_logistic
from repro.data import synthetic
from repro.obs import Observability


def _admm_params(problem):
    """(loss, rho, tau, spec) for the separable-loss ADMM paths — ONE
    table for the streaming, multi-device AND cluster branches, so a
    calibration change cannot leave them inconsistent. ``spec`` is the
    picklable form cluster workers rebuild the same loss from
    (``repro.cluster.worker.make_loss``)."""
    if problem == "logistic":
        return make_logistic(), 0.0, 0.1, {"name": "logistic"}
    C = 1.0                                    # svm
    return make_hinge(C), 1.0, 0.5, {"name": "hinge", "C": C}


def _fit_streaming(args, D, aux, mu, obs=None):
    """Out-of-core fit: stage into a block store, stream the solve.
    ``D`` may be dense node-stacked or a BlockCSR (nnz-scaled store)."""
    from repro.core.unwrapped import UnwrappedADMM
    from repro.data.sparse import BlockCSR
    from repro.data.store import ShardedMatrixStore
    from repro.engine import autotune
    from repro.service.stats import SufficientStats

    if isinstance(D, BlockCSR):
        # Honor the device budget like the dense branch: the pipeline
        # holds up to 4 blocks in flight (DESIGN.md §9), so re-block
        # when 4x the current per-block bytes exceeds it.
        budget = args.device_budget_mb * 2 ** 20
        per_block = D.nbytes // max(D.nblocks, 1)
        if 4 * per_block > budget:
            bytes_per_row = max(per_block // D.block_m, 1)
            D = D.reblock(max(8, budget // (4 * bytes_per_row)))
        store = ShardedMatrixStore.from_sparse(D, np.asarray(aux))
    else:
        n = D.shape[-1]
        m = D.reshape(-1, n).shape[0]
        br = autotune.streaming_block_rows(
            m, n, D.dtype, budget_bytes=args.device_budget_mb * 2 ** 20)
        store = ShardedMatrixStore.from_arrays(
            np.asarray(D.reshape(-1, n)), np.asarray(aux.reshape(-1)),
            block_rows=br)
    if args.store_dir:
        store = ShardedMatrixStore.open(store.save(args.store_dir))
    print(f"store: {store} (budget {args.device_budget_mb} MiB "
          f"-> {store.nblocks} blocks)", flush=True)
    if args.problem == "lasso":
        # quadratic data term: one streaming stats pass, then the cached-
        # Gram FASTA solve — no iteration ever touches the rows again.
        from repro.core.fasta import transpose_reduction_lasso
        stats = SufficientStats.from_store(store)
        fr = transpose_reduction_lasso(stats.G, stats.c, mu,
                                       iters=args.iters)
        return FitResult(fr.x, int(fr.iters), fr.objective, "transpose",
                         "lasso")
    if args.problem not in ("logistic", "svm"):
        raise SystemExit(f"--executor streaming does not support "
                         f"{args.problem!r} "
                         f"(needs a separable ProxLoss on Dx)")
    loss, rho, tau, _ = _admm_params(args.problem)
    solver = UnwrappedADMM(loss=loss, tau=tau, rho=rho)
    res = solver.solve_streaming(store, max_iters=args.iters, record=True,
                                 checkpoint_dir=args.checkpoint_dir,
                                 checkpoint_every=args.checkpoint_every,
                                 resume=args.resume, obs=obs)
    return FitResult(res.x, int(res.iters), res.history.objective,
                     "transpose", args.problem)


def _fit_cluster(args, D, aux, mu):
    """Multi-process fit: stage a shared block store, spawn workers,
    solve through the cluster coordinator (DESIGN.md §11)."""
    from repro.cluster.chaos import ChaosSchedule
    from repro.cluster.coordinator import (
        ClusterConfig,
        DegradePolicy,
        cluster_solve,
        cluster_stats,
    )

    chaos = None
    if args.chaos_spec:
        chaos = ChaosSchedule.parse(args.chaos_spec)
    elif args.chaos_seed is not None:
        # scale the default fault mix down so small clusters keep a
        # survivor (generate refuses kills+stops >= n_workers)
        chaos = ChaosSchedule.generate(args.chaos_seed,
                                       n_workers=args.cluster,
                                       iters=args.iters,
                                       kills=1 if args.cluster > 1 else 0,
                                       stops=1 if args.cluster > 2 else 0)
    degrade = None
    if args.min_quorum is not None or args.iter_deadline is not None:
        degrade = DegradePolicy(
            min_quorum=(args.min_quorum if args.min_quorum is not None
                        else 0.25),
            iter_deadline_s=(args.iter_deadline
                             if args.iter_deadline is not None else 60.0),
        )
    cfg = ClusterConfig(
        n_workers=args.cluster,
        compress=args.cluster_compress,
        staleness=args.cluster_staleness,
        quorum=0.5 if args.cluster_staleness else 1.0,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
        obs_dir=args.obs_dir,   # the coordinator owns the run directory
        chaos=chaos,
        degrade=degrade,
        # faults are survivable only if killed workers come back
        reconnect={"retries": 8} if chaos is not None else None,
    )
    if chaos is not None:
        print(f"chaos: seed={chaos.seed} spec={chaos.to_spec()!r}",
              flush=True)
    if args.problem == "lasso":
        from repro.core.fasta import transpose_reduction_lasso
        stats, telemetry = cluster_stats(D, aux, store_dir=args.store_dir,
                                         config=cfg)
        wire = sum(telemetry["workers"].get("sent_bytes", {}).values())
        print(f"cluster stats: {stats.rows} rows over {args.cluster} "
              f"workers, {wire} worker-tx bytes total", flush=True)
        fr = transpose_reduction_lasso(stats.G, stats.c, mu,
                                       iters=args.iters)
        return FitResult(fr.x, int(fr.iters), fr.objective, "transpose",
                         "lasso")
    if args.problem not in ("logistic", "svm"):
        raise SystemExit(f"--executor cluster does not support "
                         f"{args.problem!r} "
                         f"(needs a separable ProxLoss on Dx)")
    _, rho, tau, spec = _admm_params(args.problem)
    res = cluster_solve(D, aux, spec, tau=tau, rho=rho,
                        max_iters=args.iters, store_dir=args.store_dir,
                        config=cfg)
    t = res.telemetry
    print(f"cluster: {t['workers_alive']}/{t['workers_spawned']} workers "
          f"alive, {len(t['deaths'])} deaths, "
          f"{t['blocks_reassigned']} blocks reassigned, "
          f"{t['reduction_rx_bytes_per_iter']:.0f} reduction B/iter "
          f"at the coordinator "
          f"({t['payload_bytes_per_nvec']} B payload per n-vector)",
          flush=True)
    rec = t.get("recovery") or {}
    if t.get("status") != "converged" or t.get("joins") or rec.get("events"):
        print(f"cluster status: {t.get('status')} — "
              f"{t.get('joins', 0)} joins, "
              f"{t.get('blocks_rebalanced', 0)} blocks rebalanced, "
              f"{len(rec.get('events', []))} recovery events "
              f"(time-to-recover "
              f"{rec.get('time_to_recover_s') or 0.0:.2f}s, "
              f"{rec.get('iterations_retried', 0)} iterations retried), "
              f"{t.get('degraded_rounds', 0)} degraded rounds",
              flush=True)
    hist = (jnp.asarray(res.history["objective"])
            if res.history else None)
    return FitResult(jnp.asarray(res.x), int(res.iters), hist,
                     "transpose", args.problem)


def _fit_sparse(args, bcsr, aux, mu, obs=None):
    """In-memory sparse fit over the block-CSR engine backend."""
    from repro.core.unwrapped import UnwrappedADMM
    from repro.service.stats import SufficientStats

    if args.method != "transpose":
        raise SystemExit("--density blockcsr supports --method transpose "
                         "only (consensus is a dense-data path)")
    print(f"sparse: {bcsr}", flush=True)
    if args.problem == "lasso":
        from repro.core.fasta import transpose_reduction_lasso
        stats = SufficientStats.from_data(bcsr, aux)
        fr = transpose_reduction_lasso(stats.G, stats.c, mu,
                                       iters=args.iters)
        return FitResult(fr.x, int(fr.iters), fr.objective, "transpose",
                         "lasso")
    if args.problem not in ("logistic", "svm"):
        raise SystemExit(f"--density does not support {args.problem!r} "
                         f"(needs a separable ProxLoss on Dx)")
    loss, rho, tau, _ = _admm_params(args.problem)
    solver = UnwrappedADMM(loss=loss, tau=tau, rho=rho)
    res = solver.run(bcsr, aux, iters=args.iters, obs=obs)
    return FitResult(res.x, int(res.iters), res.history.objective,
                     "transpose", args.problem)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="logistic",
                    choices=["lasso", "logistic", "svm", "sparse_logistic"])
    ap.add_argument("--method", default="transpose",
                    choices=["transpose", "consensus"])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rows-per-node", type=int, default=5000)
    ap.add_argument("--features", type=int, default=200)
    ap.add_argument("--heterogeneous", action="store_true")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--mu", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--executor", default=None,
                    choices=["local", "streaming", "shard_map", "cluster"],
                    help="solve topology: in-memory local (default), "
                         "out-of-core streaming, multi-device shard_map, "
                         "or multi-process cluster (--workers N) — all "
                         "the same driver over repro.exec backends")
    ap.add_argument("--workers", type=int, default=2, metavar="N",
                    help="worker processes for --executor cluster")
    ap.add_argument("--multi-device", action="store_true",
                    help="deprecated alias for --executor shard_map")
    ap.add_argument("--streaming", action="store_true",
                    help="deprecated alias for --executor streaming")
    ap.add_argument("--device-budget-mb", type=int, default=256,
                    help="per-block device-memory budget for "
                         "--executor streaming")
    ap.add_argument("--store-dir", default=None,
                    help="persist the block store here (memory-mapped "
                         "reopen) instead of holding it in host RAM")
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="deprecated alias for --executor cluster "
                         "--workers N")
    ap.add_argument("--cluster-compress", action="store_true",
                    help="int8 error-feedback compression on every "
                         "reduce hop (with --cluster)")
    ap.add_argument("--cluster-staleness", type=int, default=0,
                    metavar="S",
                    help="bounded-staleness quorum aggregation: proceed "
                         "on a quorum, tolerate reductions up to S "
                         "iterations old (0 = strict synchronous)")
    ap.add_argument("--chaos-seed", type=int, default=None, metavar="S",
                    help="with --cluster: inject a seeded, deterministic "
                         "fault schedule (worker kills/hangs, wire "
                         "delays/drops, a mid-solve join) generated from "
                         "this seed (DESIGN.md §13)")
    ap.add_argument("--chaos-spec", default=None, metavar="SPEC",
                    help="with --cluster: explicit fault schedule, e.g. "
                         "'kill@13:w2,delay@5:w0:80,join@9:w4' — "
                         "overrides --chaos-seed")
    ap.add_argument("--min-quorum", type=float, default=None, metavar="F",
                    help="graceful degradation: fraction of workers that "
                         "must stay reachable before the solve returns "
                         "best-so-far with status=degraded")
    ap.add_argument("--iter-deadline", type=float, default=None,
                    metavar="SEC",
                    help="graceful degradation: per-iteration collection "
                         "deadline; expired rounds are retried, then the "
                         "quorum is relaxed / the solve degrades")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist solver state here every "
                         "--checkpoint-every iterations (streaming and "
                         "cluster paths)")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint in "
                         "--checkpoint-dir")
    ap.add_argument("--density", type=float, default=None,
                    help="generate SPARSE data with this Bernoulli "
                         "density (0 < p <= 1); omit for dense")
    ap.add_argument("--sparse-format", default="blockcsr",
                    choices=["blockcsr", "dense"],
                    help="with --density: run the padded block-CSR path "
                         "(O(nnz) per pass) or densify for comparison")
    ap.add_argument("--obs-dir", default=None,
                    help="write observability artifacts here: trace.json "
                         "(Perfetto), metrics.json, telemetry.jsonl "
                         "(summarize with repro.launch.obs_report)")
    args = ap.parse_args(argv)

    # one topology knob; the old selector flags resolve into it with a
    # deprecation warning (their tuning companions are still honored)
    if args.executor is None:
        if args.cluster:
            warnings.warn("--cluster N is deprecated; use --executor "
                          "cluster --workers N", DeprecationWarning,
                          stacklevel=2)
            args.executor = "cluster"
        elif args.streaming:
            warnings.warn("--streaming is deprecated; use --executor "
                          "streaming", DeprecationWarning, stacklevel=2)
            args.executor = "streaming"
        elif args.multi_device:
            warnings.warn("--multi-device is deprecated; use --executor "
                          "shard_map", DeprecationWarning, stacklevel=2)
            args.executor = "shard_map"
        else:
            args.executor = "local"
    if args.executor == "cluster" and not args.cluster:
        args.cluster = args.workers

    key = jax.random.PRNGKey(args.seed)
    N, mi, n = args.nodes, args.rows_per_node, args.features
    het = 1.0 if args.heterogeneous else 0.0
    t0 = time.time()
    sparse_input = False
    if args.density is not None:
        from repro.data import sparse as sparse_data
        m = N * mi
        if args.problem == "lasso":
            prob = sparse_data.sparse_lasso_problem(args.seed, m, n,
                                                    args.density)
            D, aux = prob.D, prob.b
            mu = args.mu if args.mu is not None else float(prob.mu)
        else:
            prob = sparse_data.sparse_classification_problem(
                args.seed, m, n, args.density)
            D, aux = prob.D, prob.labels
            mu = args.mu if args.mu is not None else 1.0
        if args.sparse_format == "dense":
            D = D.to_dense().reshape(N, mi, n)
            aux = aux.reshape(N, mi)
        else:
            sparse_input = True
        gib = (D.nbytes if sparse_input else N * mi * n * 4) / 2 ** 30
        print(f"data: {m} rows x {n} features at density "
              f"{args.density} -> {args.sparse_format} "
              f"({gib:.3f} GiB) in {time.time()-t0:.1f}s", flush=True)
    else:
        if args.problem == "lasso":
            prob = synthetic.lasso_problem(key, N, mi, n, heterogeneity=het)
            D, aux = prob.D, prob.b
            mu = args.mu if args.mu is not None else float(prob.mu)
        else:
            prob = synthetic.classification_problem(key, N, mi, n,
                                                    heterogeneity=het)
            D, aux = prob.D, prob.labels
            mu = args.mu if args.mu is not None else 1.0
        t_data = time.time() - t0
        print(f"data: {N} nodes x {mi} rows x {n} features "
              f"({N*mi*n*4/2**30:.2f} GiB) in {t_data:.1f}s", flush=True)

    # one Observability bundle per run: the cluster path hands the run
    # directory to the coordinator instead (it owns the merged trace),
    # so this process's bundle stays disabled there
    obs = Observability(
        dir=args.obs_dir if args.executor != "cluster" else None,
        process_name="fit")
    t0 = time.time()
    if args.executor == "cluster":
        if sparse_input:
            raise SystemExit("--executor cluster currently takes dense "
                             "data (use --sparse-format dense)")
        res = _fit_cluster(args, D, aux, mu)
    elif sparse_input and args.executor != "streaming":
        res = _fit_sparse(args, D, aux, mu, obs=obs)
    elif args.executor == "streaming":
        res = _fit_streaming(args, D, aux, mu, obs=obs)
    elif args.executor == "shard_map" and args.method == "transpose" \
            and args.problem in ("logistic", "svm"):
        # the shard_map SolveExecutor under the shared driver: the same
        # stopping rule / telemetry as every other topology, devices
        # discovered from the default mesh
        from repro.engine import IterationEngine
        from repro.exec import ShardMapExecutor, solve_with_executor
        loss, rho, tau, _ = _admm_params(args.problem)
        m = N * mi
        ex = ShardMapExecutor(IterationEngine(loss=loss, tau=tau),
                              np.asarray(D.reshape(m, n)),
                              aux=np.asarray(aux.reshape(m)))
        r = solve_with_executor(ex, loss=loss, tau=tau, rho=rho,
                                max_iters=args.iters, record=True,
                                obs=obs)
        res = FitResult(r.x, int(r.iters), r.history.objective,
                        "transpose", args.problem)
    else:
        with obs.span("fit_glm", problem=args.problem,
                      method=args.method):
            res = fit_glm(args.problem, D, aux, method=args.method,
                          mu=mu if args.problem.startswith(("lasso", "sparse"))
                          else None, iters=args.iters)
        if obs.enabled and getattr(res.objective, "ndim", None) == 1:
            for i, o in enumerate(np.asarray(res.objective)):
                obs.record(iter=i + 1, objective=float(o))
    dt = time.time() - t0
    obs.finish()
    if args.obs_dir:
        print(f"obs: wrote {args.obs_dir} (trace.json / metrics.json / "
              "telemetry.jsonl)", flush=True)
    print(f"[{args.method}] {args.problem}: {res.iters} iters in {dt:.1f}s",
          flush=True)

    x = np.asarray(res.x)
    a2 = np.asarray(aux).reshape(-1)
    if sparse_input:
        # O(nnz) diagnostics: everything below needs only Dx / D^T r.
        from repro.kernels.spgram import ops as spgram_ops
        Dx = np.asarray(spgram_ops.matvec(D, jnp.asarray(x)))
        if args.problem == "lasso":
            grad = np.asarray(spgram_ops.rmatvec(
                D, jnp.asarray(Dx - a2)))
            on = np.abs(x) > 1e-7
            viol = max(float(np.abs(grad[on] + mu * np.sign(x[on])).max())
                       if on.any() else 0.0,
                       float(np.maximum(np.abs(grad[~on]) - mu, 0).max())
                       if (~on).any() else 0.0)
            print(f"KKT violation: {viol:.2e}, support: {int(on.sum())}")
        elif args.problem == "logistic":
            obj = float(np.sum(np.logaddexp(0.0, -a2 * Dx)))
            acc = float(np.mean(np.sign(Dx) == a2))
            print(f"objective: {obj:.2f}, train acc: {acc:.4f}")
        else:
            obj = float(np.sum(np.maximum(1.0 - a2 * Dx, 0.0))
                        + 0.5 * np.sum(x * x))
            print(f"objective: {obj:.2f}")
        return res
    D2 = np.asarray(D.reshape(-1, n))
    if args.problem == "lasso":
        viol, sup = lasso_kkt_gap(D2, a2, x, mu)
        print(f"KKT violation: {viol:.2e}, support err: {sup:.2e}")
    elif args.problem in ("logistic", "sparse_logistic"):
        obj = logistic_objective(D2, a2, x)
        acc = float(np.mean(np.sign(D2 @ x) == a2))
        print(f"objective: {obj:.2f}, train acc: {acc:.4f}")
    else:
        print(f"objective: {svm_objective(D2, a2, x, 1.0):.2f}")
    return res


if __name__ == "__main__":
    main()
