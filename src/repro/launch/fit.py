"""Distributed GLM fitting launcher — THE PAPER'S end-to-end driver.

``python -m repro.launch.fit --problem logistic --method transpose
     --nodes 8 --rows-per-node 50000 --features 200 [--heterogeneous]``

This is the paper's kind of end-to-end run (fit a linear model over a large
distributed corpus); the multi-device path row-shards D over all local
devices via shard_map and the transpose-reduction all-reduce.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fit import FitResult, fit as fit_glm
from repro.core.distributed import DistributedUnwrappedADMM, shard_rows
from repro.core.oracles import (
    lasso_kkt_gap,
    logistic_objective,
    svm_objective,
)
from repro.core.prox import make_hinge, make_logistic
from repro.data import synthetic
from repro.sharding import compat


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="logistic",
                    choices=["lasso", "logistic", "svm", "sparse_logistic"])
    ap.add_argument("--method", default="transpose",
                    choices=["transpose", "consensus"])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rows-per-node", type=int, default=5000)
    ap.add_argument("--features", type=int, default=200)
    ap.add_argument("--heterogeneous", action="store_true")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--mu", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--multi-device", action="store_true",
                    help="shard rows over all local jax devices")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    N, mi, n = args.nodes, args.rows_per_node, args.features
    het = 1.0 if args.heterogeneous else 0.0
    t0 = time.time()
    if args.problem == "lasso":
        prob = synthetic.lasso_problem(key, N, mi, n, heterogeneity=het)
        D, aux = prob.D, prob.b
        mu = args.mu if args.mu is not None else float(prob.mu)
    else:
        prob = synthetic.classification_problem(key, N, mi, n,
                                                heterogeneity=het)
        D, aux = prob.D, prob.labels
        mu = args.mu if args.mu is not None else 1.0
    t_data = time.time() - t0
    print(f"data: {N} nodes x {mi} rows x {n} features "
          f"({N*mi*n*4/2**30:.2f} GiB) in {t_data:.1f}s", flush=True)

    t0 = time.time()
    if args.multi_device and args.method == "transpose" \
            and args.problem in ("logistic", "svm"):
        ndev = len(jax.devices())
        mesh = compat.make_mesh((ndev,), ("data",))
        loss = make_logistic() if args.problem == "logistic" \
            else make_hinge(1.0)
        rho = 1.0 if args.problem == "svm" else 0.0
        tau = 0.1 if args.problem == "logistic" else 0.5
        solver = DistributedUnwrappedADMM(
            loss=loss, tau=tau, rho=rho, data_axes=("data",))
        m = N * mi
        solve = solver.build(mesh, m, n, iters=args.iters)
        Dg = shard_rows(mesh, D.reshape(m, n), ("data",))
        ag = shard_rows(mesh, aux.reshape(m), ("data",))
        x, objs, _ = solve(Dg, ag)
        res = FitResult(x, args.iters, objs, "transpose",
                                args.problem)
    else:
        res = fit_glm(args.problem, D, aux, method=args.method,
                          mu=mu if args.problem.startswith(("lasso", "sparse"))
                          else None, iters=args.iters)
    dt = time.time() - t0
    print(f"[{args.method}] {args.problem}: {res.iters} iters in {dt:.1f}s",
          flush=True)

    D2 = np.asarray(D.reshape(-1, n))
    a2 = np.asarray(aux.reshape(-1))
    x = np.asarray(res.x)
    if args.problem == "lasso":
        viol, sup = lasso_kkt_gap(D2, a2, x, mu)
        print(f"KKT violation: {viol:.2e}, support err: {sup:.2e}")
    elif args.problem in ("logistic", "sparse_logistic"):
        obj = logistic_objective(D2, a2, x)
        acc = float(np.mean(np.sign(D2 @ x) == a2))
        print(f"objective: {obj:.2f}, train acc: {acc:.4f}")
    else:
        print(f"objective: {svm_objective(D2, a2, x, 1.0):.2f}")
    return res


if __name__ == "__main__":
    main()
