"""Serving launcher: batched prefill + decode on the local device(s).

``python -m repro.launch.serve --arch rwkv6-1.6b --smoke --batch 4
     --prompt-len 32 --gen 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs_lib
from repro.models.decode import decode_step, prefill
from repro.models.model import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = configs_lib.get_smoke(args.arch) if args.smoke \
        else configs_lib.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    s_max = S + args.gen
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02

    step_jit = jax.jit(
        lambda p, c, t, pos: decode_step(p, cfg, c, tokens=t, pos=pos))
    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, t: prefill(p, cfg, tokens=t, s_max=s_max, **kw))(
        params, tokens)
    out = [jnp.argmax(logits, -1)]
    t_prefill = time.time() - t0
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(S + i, jnp.int32)
        logits, caches = step_jit(params, caches, out[-1], pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            out.append(jax.random.categorical(sub,
                                              logits / args.temperature, -1))
        else:
            out.append(jnp.argmax(logits, -1))
    gen = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"prefill {B}x{S}: {t_prefill:.2f}s; "
          f"decode {args.gen-1} steps: {dt:.2f}s "
          f"({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()
