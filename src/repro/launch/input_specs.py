"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape) cell.

Shapes (LM-family, per assignment):
  train_4k    : seq 4096,    global_batch 256   -> train_step
  prefill_32k : seq 32768,   global_batch 32    -> prefill
  decode_32k  : cache 32768, global_batch 128   -> serve_step (1 new token)
  long_500k   : state 524288, global_batch 1    -> serve_step (sub-quadratic
                families only; skips recorded per-config in skip_shapes)

Modality frontends are STUBS per the assignment: [vlm] cells get precomputed
patch embeddings + 3-stream M-RoPE position ids; [audio] cells get frame
embeddings for the encoder. No device memory is allocated here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.decode import init_caches
from repro.models.model import init_params

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _train_or_prefill_inputs(cfg: ModelConfig, B: int, S: int, *,
                             with_labels: bool) -> Dict[str, Any]:
    batch: Dict[str, Any] = {}
    i32 = jnp.int32
    if cfg.frontend == "vision":
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["positions"] = sds((3, B, S), i32)
        if with_labels:
            batch["labels"] = sds((B, S), i32)
    elif cfg.frontend == "audio" or cfg.family == "encdec":
        # encoder frames stub at the same length as the decoder tokens
        batch["enc_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((B, S), i32)
        if with_labels:
            batch["labels"] = sds((B, S), i32)
    else:
        batch["tokens"] = sds((B, S), i32)
        if with_labels:
            batch["labels"] = sds((B, S), i32)
    return batch


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Returns {"kind": train|prefill|decode, ...ShapeDtypeStructs...}."""
    meta = SHAPES[shape_name]
    B, S = meta["batch"], meta["seq"]
    kind = meta["kind"]
    if shape_name in cfg.skip_shapes:
        raise ValueError(f"{cfg.name} skips {shape_name} "
                         f"(see DESIGN.md §Arch-applicability)")
    if kind == "train":
        return {"kind": "train",
                "batch": _train_or_prefill_inputs(cfg, B, S,
                                                  with_labels=True)}
    if kind == "prefill":
        return {"kind": "prefill",
                "batch": _train_or_prefill_inputs(cfg, B, S,
                                                  with_labels=False),
                "s_max": S}
    if kind == "decode":
        # one new token against a seq-long cache/state
        s_enc = 4096 if cfg.family == "encdec" else 0
        caches = jax.eval_shape(
            lambda: init_caches(cfg, B, S, s_enc=s_enc, dtype=jnp.bfloat16))
        return {"kind": "decode",
                "tokens": sds((B,), jnp.int32),
                "pos": sds((), jnp.int32),
                "caches": caches}
    raise ValueError(kind)


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    key = jax.random.PRNGKey(seed)
    return jax.eval_shape(lambda: init_params(cfg, key))
