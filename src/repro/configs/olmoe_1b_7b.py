"""olmoe-1b-7b [moe] — 16L d2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 [arXiv:2409.02060]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1024, vocab_size=50304,
        num_experts=64, experts_per_token=8, moe_d_ff=1024,
        capacity_factor=1.25, qk_norm=True, moe_impl="a2a",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=64, moe_d_ff=64, vocab_size=256,
        num_experts=8, experts_per_token=4,
    )
