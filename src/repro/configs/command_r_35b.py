"""command-r-35b [dense] — 40L d8192 64H (GQA kv=8) d_ff=22528 vocab=256000,
no-bias [hf:CohereForAI/c4ai-command-r-v01]. kv_repeat=2 aligns 16 kv heads
to 16-way TP (vLLM-style replication)."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22528, vocab_size=256000,
        rope_theta=8e6, kv_repeat=2,
        fsdp=True, parallelism="fsdp",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256, kv_repeat=2,
    )
