"""arctic-480b [moe] — 35L d7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

Memory plan: adam m/v at 480B params is ~3.8 TB f32 and does NOT fit
256 x 16 GB; this config uses Adafactor (factored second moment) per
DESIGN.md §6. kv_repeat=1: the GQA group is 56/8=7, so no valid repeat
aligns 16-way TP — GSPMD pads the kv-head dim (a known imbalance, see
EXPERIMENTS.md §Perf notes).
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=4864, vocab_size=32000,
        num_experts=128, experts_per_token=2, moe_d_ff=4864,
        moe_dense_residual=True, capacity_factor=1.25,
        kv_repeat=1, optimizer="adafactor",
        fsdp=True, moe_impl="a2a",
        skip_shapes=("long_500k",),   # pure full attention
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=8, num_kv_heads=8,
        head_dim=8, d_ff=96, moe_d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, optimizer="adamw",
    )
