"""seamless-m4t-large-v2 [audio] — enc-dec backbone: 24L encoder + 24L
decoder, d1024 16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596];
padded to 256208 (next multiple of 16) so the vocab-parallel lm_head and
embedding shard evenly on 16-way TP — standard Megatron-style vocab padding
(the 2 pad rows are never produced by the tokenizer stub).
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S_frames, d)."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
        num_kv_heads=16, head_dim=64, d_ff=8192, vocab_size=256208,
        cross_attention=True, frontend="audio",
        parallelism="fsdp",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    )
