"""phi3-medium-14b [dense] — 40L d5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE SwiGLU GQA [arXiv:2404.14219]. kv_repeat=2 -> 20 kv heads
(GQA group 2); 40 q / 20 kv over 16-way TP still pads (see §Perf notes)."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        head_dim=128, d_ff=17920, vocab_size=100352,
        kv_repeat=2, parallelism="fsdp",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=80, num_heads=5, num_kv_heads=5,
        head_dim=16, d_ff=128, vocab_size=256, kv_repeat=1,
    )
