"""Assigned-architecture registry: ``get(name)`` -> full ModelConfig,
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests.

Paper-side (GLM) configs live in repro/configs/glm.py.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "arctic_480b",
    "olmoe_1b_7b",
    "rwkv6_1p6b",
    "qwen3_14b",
    "command_r_35b",
    "phi3_medium_14b",
    "qwen3_8b",
    "seamless_m4t_large_v2",
    "qwen2_vl_72b",
    "recurrentgemma_9b",
)

# external ids (with dashes) -> module names
ALIASES = {i.replace("_", "-").replace("-1p6b", "-1.6b"): i for i in ARCH_IDS}


def _module(name: str):
    key = name.replace("-", "_").replace("1.6b", "1p6b").replace(".", "p")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str):
    return _module(name).config()


def get_smoke(name: str):
    return _module(name).smoke_config()
