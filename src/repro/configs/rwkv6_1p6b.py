"""rwkv6-1.6b [ssm] — Finch: 24L d2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay [arXiv:2404.05892]. O(1) decode state =>
runs the long_500k cell."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="rwkv6",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        rwkv_head_dim=64, rwkv_lora_rank=32, wkv_chunk=16,
        parallelism="fsdp",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, rwkv_head_dim=16, rwkv_lora_rank=4,
        wkv_chunk=4,
    )
