"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention 2:1 [arXiv:2402.19427]. Pattern
(rec, rec, attn_local) with window 2048; O(1)+window decode state => runs
the long_500k cell. kv_repeat=16 replicates the MQA head across TP16."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="griffin",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        pattern=("rec", "rec", "attn_local"), window_size=2048,
        lru_width=4096, conv_width=4, kv_repeat=16,
        parallelism="fsdp",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=256, lru_width=64,
        window_size=8, kv_repeat=4,
    )
