"""qwen2-vl-72b [vlm] — 80L d8192 64H (GQA kv=8) d_ff=29568 vocab=152064,
M-RoPE (t/h/w sections 16/24/24), dynamic resolution [arXiv:2409.12191].
Vision frontend is a STUB: input_specs() provides patch embeddings +
3-stream M-RoPE position ids. kv_repeat=2 aligns kv heads to TP16."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=29568, vocab_size=152064,
        mrope=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
        kv_repeat=2, frontend="vision",
        fsdp=True, parallelism="fsdp",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, kv_repeat=1,
        mrope_sections=(2, 3, 3),
    )
