"""Parameter / optimizer-state / batch PartitionSpec rules.

Megatron-style TP on 'model' (attention heads, FFN hidden, experts, vocab),
DP on ('pod','data'), and ZeRO-1: optimizer state additionally sharded over
the DP axes along the first divisible unsharded dim.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.sharding.util import DP, filter_spec

# Base (unstacked) spec per leaf name; leading dims (scan L, expert E pre-
# existing in shapes below) are part of the listed spec where relevant.
_BASE = {
    # embeddings / head: shard vocab-or-feature on 'model'
    "embed": P(None, "model"),
    "lm_head": P(None, "model"),
    "final_norm": P(),
    "enc_norm": P(),
    # attention
    "wq": P(None, "model"),
    "wk": P(None, "model"),
    "wv": P(None, "model"),
    "wo": P("model", None),
    "q_norm": P(),
    "k_norm": P(),
    # mlp
    "w1": P(None, "model"),
    "w3": P(None, "model"),
    "w2": P("model", None),
    # moe (E, d, ff) — experts on 'model' (EP)
    "router": P(),
    "we1": P("model", None, None),
    "we3": P("model", None, None),
    "we2": P("model", None, None),
    # rwkv time-mix / channel-mix
    "wr": P(None, "model"),
    "wg": P(None, "model"),
    "maa_base": P(),
    "maa_w1": P(),
    "maa_w2": P(),
    "decay_base": P(),
    "decay_w1": P(),
    "decay_w2": P(),
    "bonus": P(),
    "gn_scale": P(),
    "gn_bias": P(),
    "mu_k": P(),
    "mu_r": P(),
    # griffin
    "w_gate": P(None, "model"),
    "w_x": P(None, "model"),
    "conv_w": P(None, "model"),
    "conv_b": P("model"),
    "lru_lambda": P("model"),
    "w_a": P(None, "model"),
    "w_i": P(None, "model"),
    "w_out": P("model", None),
    # norms
    "ln1": P(),
    "ln2": P(),
    "ln_x": P(),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, DictKey):
            return str(entry.key)
    return ""


def param_spec(params, parallelism: str = "tp") -> Any:
    """PartitionSpec pytree matching ``params`` (handles stacked L dims by
    left-padding the base spec with None). parallelism="fsdp" strips the
    'model' (TP) entries — params are then sharded over the DP axes by
    zero1_spec instead (§Perf H3)."""

    def per_leaf(path, leaf):
        name = _leaf_name(path)
        base = _BASE.get(name, P())
        if parallelism == "fsdp":
            base = P(*(None if e == "model" else e for e in base))
        pad = leaf.ndim - len(base)
        assert pad >= 0, (name, leaf.shape, base)
        return P(*([None] * pad), *base)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def zero1_spec(pspec_tree, params, mesh: Mesh, axes=DP) -> Any:
    """Optimizer-state spec: param spec + DP sharding on the first unsharded
    dim whose size divides the DP axis product (ZeRO-1)."""
    dp_axes = tuple(a for a in axes if a in mesh.axis_names)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def per_leaf(spec, leaf):
        if dp_size <= 1:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
            if e is None and dim % dp_size == 0:
                entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                return P(*entries)
        return P(*entries)

    return jax.tree.map(per_leaf, pspec_tree, params)


def axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size


def divisible_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis names whose mesh size does not divide the dim (explicit
    input shardings must tile evenly; e.g. batch=1 long-context decode, or
    8 kv heads on 16-way TP)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for e, dim in zip(entries, shape):
        out.append(e if dim % axis_size(mesh, e) == 0 else None)
    return P(*out)


def batch_spec(batch_shapes: Dict[str, Any], mesh: Optional[Mesh] = None,
               axes=DP) -> Dict[str, P]:
    """Inputs: batch dim on the DP axes (all mesh axes under fsdp
    parallelism). mrope positions (3,B,S) shard dim 1."""
    out = {}
    for k, v in batch_shapes.items():
        nd = v.ndim if hasattr(v, "ndim") else len(v.shape)
        if k == "positions" and nd == 3:
            spec = P(None, axes, None)
        else:
            spec = P(axes, *([None] * (nd - 1)))
        if mesh is not None:
            spec = divisible_spec(spec, v.shape, mesh)
        out[k] = spec
    return out


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh.axis_names)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def cache_spec(caches, mesh: Optional[Mesh] = None) -> Any:
    """KV/state caches: dim0 is L (replicated), batch on DP, heads/channels
    on 'model'. When the kv-head count does not divide the TP size (GQA-8 on
    TP16 without kv_repeat), the sharding falls back to the head_dim axis;
    non-divisible batch (long-context batch=1) falls back to replication.
    """

    def per_leaf(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v", "xk", "xv"):       # (L,B,S,Hkv,hd)
            spec = P(None, DP, None, "model", None)
            if mesh is not None and leaf.shape[3] % axis_size(
                    mesh, "model") != 0:
                spec = P(None, DP, None, None, "model")  # shard head_dim
        elif name == "S":                         # (L,B,H,hd,hd)
            spec = P(None, DP, "model", None, None)
        elif name in ("tmix_x", "cmix_x"):        # (L,B,d)
            spec = P(None, DP, None)
        elif name == "h":                         # (L,B,lw)
            spec = P(None, DP, "model")
        elif name == "conv":                      # (L,B,W-1,lw)
            spec = P(None, DP, None, "model")
        else:
            spec = P(*([None] * leaf.ndim))
        if mesh is not None:
            spec = divisible_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(per_leaf, caches)
