"""JAX version compatibility for the mesh / shard_map APIs.

The repo is written against the modern explicit-mesh surface
(``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map``). Older jaxlibs
(<= 0.4.x) predate all four; this module is the single place that knows
both spellings so every other file can stay on the modern one:

  * :func:`make_mesh`     — Auto axis_types when the installed JAX has them.
  * :func:`use_mesh`      — context manager; ``jax.set_mesh`` or the legacy
                            ``with mesh:`` thread-resources context.
  * :func:`current_mesh`  — the ambient (abstract or physical) mesh, or an
                            empty mesh when none is active. Always has
                            ``.empty`` / ``.axis_names`` / ``.shape``.
  * :func:`shard_map`     — ``jax.shard_map`` or the experimental one, with
                            the check_vma/check_rep kwarg rename papered over.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_GET_ABSTRACT = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for sharding constraints.

    Modern JAX: ``jax.set_mesh(mesh)``. Legacy: ``Mesh`` is itself a context
    manager that installs the physical mesh in thread resources — which is
    exactly where :func:`current_mesh` looks on those versions.
    """
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def current_mesh():
    """The ambient mesh, or an empty mesh object when none is active.

    The return value is only inspected (``.empty``, ``.axis_names``,
    ``.shape``) or handed to :func:`shard_map`; both the AbstractMesh of
    modern JAX and the legacy physical Mesh satisfy that contract.
    """
    if _HAS_GET_ABSTRACT:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _src_mesh  # legacy thread-resources env
    return _src_mesh.thread_resources.env.physical_mesh


def axis_size(axis) -> int:
    """``jax.lax.axis_size`` inside shard_map bodies, on any JAX version.

    Legacy fallback: ``psum(1, axis)`` — on a Python-scalar constant this
    hits the no-communication fast path and returns the static axis size.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version.

    Modern JAX returns a dict; 0.4.x returned a one-element list of
    per-program dicts (empty when analysis is unavailable).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the check_vma (new) / check_rep (old) rename."""
    if _HAS_JAX_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
