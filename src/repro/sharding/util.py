"""Mesh-aware sharding helpers.

Model code annotates activations with *logical* PartitionSpecs; ``shard()``
applies them only when a mesh is in context and silently drops axis names the
current mesh does not have — so the same model code runs unsharded in unit
tests, 2-D sharded on one pod, and 3-D sharded multi-pod.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import current_mesh

# Logical data-parallel axes in priority order; ('pod','data') on the
# multi-pod mesh collapses to ('data',) on a single pod.
DP = ("pod", "data")
MODEL = "model"


def _filter_entry(entry, axis_names):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in axis_names else None
    # tuple of axes
    kept = tuple(a for a in entry if a in axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def filter_spec(spec: P, axis_names) -> P:
    return P(*(_filter_entry(e, axis_names) for e in spec))


def shard(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint(x, P(*entries)) if a mesh is active."""
    mesh = current_mesh()
    if mesh is None or mesh.empty or not mesh.axis_names:
        return x
    spec = filter_spec(P(*entries), mesh.axis_names)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, *entries) -> NamedSharding:
    return NamedSharding(mesh, filter_spec(P(*entries), mesh.axis_names))
