"""Explicit all-to-all Expert Parallelism for the MoE FFN (§Perf, MoE cells).

The baseline sort-based dispatch (moe.py) leaves resharding to GSPMD, which
lowers the cross-shard gather/scatter into huge all-reduces/all-gathers
(~60 GiB wire per arctic layer — see EXPERIMENTS.md §Perf). This module is
the production path: a DeepSeek-/GShard-style two-hop dispatch under
shard_map where tokens travel point-to-point:

  1. tokens are FULLY sharded over ('data','model'): each device routes its
     own T_dev tokens; experts are sharded over 'model' (E_loc per rank);
  2. token copies are packed into per-destination-rank capacity buffers
     (Csend slots each) and exchanged with ONE all_to_all over 'model'
     (intra-ICI-row; nothing crosses the data/pod axes);
  3. each rank runs its local experts as dense (E_loc, C_loc, d) GEMMs;
  4. a reverse all_to_all returns outputs in the SAME buffer layout, so the
     source rank combines them with its saved slot mapping and top-k weights.

Wire per layer per device ~= 2 x Csend x M x d x dtype  (the two a2a hops)
 = 2 x (T_dev·k·cf) x d — independent of E, and ~30x less than the GSPMD
baseline for arctic. Dropping beyond capacity matches the baseline's
capacity-factor semantics (two-stage: per-destination and per-expert).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import mlp
from repro.models.moe import route_topk

Array = jax.Array


def moe_ffn_a2a_local(params, cfg: ModelConfig, x_loc: Array, *,
                      axis: str = "model",
                      send_cf: float = None,
                      recv_cf: float = None) -> Tuple[Array, Array]:
    """Local (shard_map) body. x_loc: (T_dev, d). Experts of ``params`` are
    the LOCAL shard (E_loc, d, ffm). Returns (out (T_dev, d), aux)."""
    T, d = x_loc.shape
    if send_cf is None:
        send_cf = cfg.capacity_factor
    if recv_cf is None:
        recv_cf = max(1.25 * cfg.capacity_factor, 1.5)
    from repro.sharding.compat import axis_size
    M = axis_size(axis)
    E = cfg.num_experts
    k = cfg.experts_per_token
    E_loc = E // M
    cdt = cfg.compute_dtype

    logits = x_loc.astype(jnp.float32) @ params["router"]
    topw, topi, aux = route_topk(logits, k)
    aux = jax.lax.pmean(aux, axis)

    # ---- stage 1: pack per-destination-rank capacity buffers -------------
    dest = topi.reshape(-1) // E_loc                     # (T*k,) rank id
    e_local = (topi.reshape(-1) % E_loc).astype(jnp.int32)
    w_flat = topw.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(dest, stable=True)
    dest_s, e_s, w_s, t_s = dest[order], e_local[order], w_flat[order], \
        t_flat[order]
    counts = jnp.bincount(dest_s, length=M)
    starts = jnp.cumsum(counts) - counts
    rank_slot = jnp.arange(T * k, dtype=jnp.int32) - starts[dest_s]
    Csend = int(max(1, round(T * k / M * send_cf)))
    keep = rank_slot < Csend
    slot = jnp.where(keep, dest_s * Csend + rank_slot, M * Csend)

    grid_tok = jnp.full((M * Csend,), T, jnp.int32).at[slot].set(
        t_s, mode="drop")
    grid_e = jnp.full((M * Csend,), E_loc, jnp.int32).at[slot].set(
        e_s, mode="drop")
    grid_w = jnp.zeros((M * Csend,), jnp.float32).at[slot].set(
        w_s, mode="drop")

    x_pad = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], 0)
    buf_x = x_pad[grid_tok].reshape(M, Csend, d)
    buf_e = grid_e.reshape(M, Csend)

    # ---- hop 1: tokens to the ranks that own their experts ---------------
    recv_x = jax.lax.all_to_all(buf_x, axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(buf_e[..., None], axis, 0, 0,
                                tiled=False)[..., 0]

    # ---- local second-stage dispatch to E_loc experts --------------------
    R = M * Csend
    rx = recv_x.reshape(R, d)
    re = recv_e.reshape(R)                                # E_loc = invalid
    order2 = jnp.argsort(re, stable=True)
    re_s = re[order2]
    counts2 = jnp.bincount(re_s, length=E_loc + 1)   # last bin: pad slots
    starts2 = jnp.cumsum(counts2) - counts2          # exclusive
    rank2 = jnp.arange(R, dtype=jnp.int32) - starts2[re_s]
    C_loc = int(max(1, round(R / max(E_loc, 1) * recv_cf)))
    keep2 = (re_s < E_loc) & (rank2 < C_loc)
    slot2 = jnp.where(keep2, re_s * C_loc + rank2, E_loc * C_loc)
    src2 = order2  # position in the a2a buffer

    grid2 = jnp.full((E_loc * C_loc,), R, jnp.int32).at[slot2].set(
        src2, mode="drop")
    rx_pad = jnp.concatenate([rx, jnp.zeros((1, d), rx.dtype)], 0)
    expert_in = rx_pad[grid2].reshape(E_loc, C_loc, d)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, params["we1"].astype(cdt))
    ) * jnp.einsum("ecd,edf->ecf", expert_in, params["we3"].astype(cdt))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["we2"].astype(cdt))

    # scatter expert outputs back to buffer order, reverse hop
    out_buf = jnp.zeros((R + 1, d), cdt).at[grid2].add(
        expert_out.reshape(E_loc * C_loc, d))[:R]
    back = jax.lax.all_to_all(out_buf.reshape(M, Csend, d), axis, 0, 0,
                              tiled=False)

    # combine at source with the saved slot mapping + top-k weights
    contrib = back.reshape(M * Csend, d) * grid_w[:, None].astype(cdt)
    out = jnp.zeros((T + 1, d), cdt).at[grid_tok].add(contrib)[:T]

    if cfg.moe_dense_residual:
        out = out + mlp(params["dense"], x_loc, cdt)
    return out, aux


def moe_ffn_a2a(params, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """Global wrapper: shard_map the a2a EP body over the active mesh.
    x: (B, S, d) with batch on the DP axes; tokens get fully sharded by
    additionally splitting S over 'model'. Falls back to the GSPMD path
    when no mesh (unit tests) or S does not divide."""
    from jax.sharding import PartitionSpec as P
    from repro.models.moe import moe_ffn
    from repro.sharding.compat import current_mesh
    mesh = current_mesh()
    B, S, d = x.shape
    if (mesh is None or mesh.empty or "model" not in mesh.axis_names
            or S % mesh.shape["model"] != 0):
        return moe_ffn(params, cfg, x)
    dp = tuple(a for a in cfg.dp_axes if a in mesh.axis_names
               and a != "model")
    all_axes = tuple(a for a in mesh.axis_names)

    pspec = {
        "router": P(),
        "we1": P("model", None, None),
        "we3": P("model", None, None),
        "we2": P("model", None, None),
    }
    if cfg.moe_dense_residual:
        pspec["dense"] = {"w1": P(), "w3": P(), "w2": P()}

    def body(p, xl):
        Bl, Sl, _ = xl.shape
        out, aux = moe_ffn_a2a_local(p, cfg, xl.reshape(Bl * Sl, d))
        aux = jax.lax.pmean(aux, tuple(a for a in all_axes if a != "model"))
        return out.reshape(Bl, Sl, d), aux

    from repro.sharding.compat import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P(dp, "model", None)),
        out_specs=(P(dp, "model", None), P()),
        check_vma=False,
    )
    return fn(params, x)
