"""Serving path: prefill (build caches) + single-token decode steps.

Cache layouts (per homogeneous segment, leading L axis, scan-carried):
  attn / moe / cross : k,v (L,B,Smax,Hkv_eff,hd) — rotated keys cached
                       cross adds xk,xv (L,B,Senc,Hkv_eff,hd), built once
  attn_local         : ring buffers k,v (L,B,window,Hkv_eff,hd); a slot s at
                       step pos holds position p = pos - ((pos - s) % window)
                       (validity derived, nothing stored)
  rwkv               : S (L,B,H,hd,hd), tmix_x/cmix_x (L,B,d) — O(1) state,
                       which is what makes long_500k runnable for this family
  rec (RG-LRU)       : h (L,B,lw), conv tail (L,B,W-1,lw)

Sharding: cache batch on ('pod','data'), kv-heads/state channels on 'model'.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.ops import chunked_attention_xla
from repro.models import griffin as griffin_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.config import ModelConfig
from repro.models.layers import _project_qkv, attention_decode, rmsnorm, mlp
from repro.models import moe as moe_lib
from repro.models.model import (
    embed_tokens,
    layer_kinds,
    segment_structure,
)
from repro.sharding.util import DP, shard

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, kind: str, count: int, B: int, s_max: int,
               s_enc: int = 0, dtype=jnp.bfloat16) -> Dict[str, Array]:
    hd = cfg.head_dim
    Hkv = cfg.kv_heads_eff
    if kind in ("attn", "moe"):
        shape = (count, B, s_max, Hkv, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "cross":
        shape = (count, B, s_max, Hkv, hd)
        xshape = (count, B, s_enc, Hkv, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype)}
    if kind == "attn_local":
        w = min(cfg.window_size, s_max)
        shape = (count, B, w, Hkv, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_dim
        rhd = cfg.rwkv_head_dim
        return {
            "S": jnp.zeros((count, B, H, rhd, rhd), jnp.float32),
            "tmix_x": jnp.zeros((count, B, cfg.d_model), jnp.float32),
            "cmix_x": jnp.zeros((count, B, cfg.d_model), jnp.float32),
        }
    if kind == "rec":
        return {
            "h": jnp.zeros((count, B, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros((count, B, cfg.conv_width - 1, cfg.lru_width),
                              jnp.float32),
        }
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, B: int, s_max: int, s_enc: int = 0,
                dtype=jnp.bfloat16):
    return [
        init_cache(cfg, kind, count, B, s_max, s_enc, dtype)
        for kind, count in segment_structure(layer_kinds(cfg))
    ]


# ---------------------------------------------------------------------------
# Per-layer decode step
# ---------------------------------------------------------------------------

def _local_attn_decode(params, cfg: ModelConfig, x, cache_k, cache_v, pos):
    """Ring-buffer windowed decode. cache_k/v: (B, W, Hkv, hd)."""
    B = x.shape[0]
    W = cache_k.shape[1]
    hd = cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    slot = jnp.mod(pos, W)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    # slot s holds position p = pos - ((pos - s) mod W); valid iff p >= 0.
    s_idx = jnp.arange(W)
    p_slot = pos - jnp.mod(pos - s_idx, W)
    valid = p_slot >= 0
    Hkv = cfg.kv_heads_eff
    rep = cfg.num_heads // Hkv
    qg = q.reshape(B, 1, Hkv, rep, hd).astype(jnp.float32)
    s = jnp.einsum("bqhrd,bshd->bhrqs", qg, cache_k.astype(jnp.float32))
    s = s / jnp.sqrt(1.0 * hd)
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqs,bshd->bqhrd", p, cache_v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(cfg.compute_dtype)
    out = o @ params["wo"].astype(cfg.compute_dtype)
    return out, cache_k, cache_v


def _block_step(params, cfg: ModelConfig, kind: str, x: Array,
                cache: Dict[str, Array], pos) -> Tuple[Array, Dict]:
    """x: (B, 1, d) -> (x', cache'). cache holds ONE layer (no L axis)."""
    eps = cfg.norm_eps
    cdt = cfg.compute_dtype
    new_cache = dict(cache)
    if kind in ("attn", "moe", "cross", "attn_local"):
        h_in = rmsnorm(x, params["ln1"], eps)
        if kind == "attn_local":
            h, ck, cv = _local_attn_decode(
                params["attn"], cfg, h_in, cache["k"], cache["v"], pos)
        else:
            h, ck, cv = attention_decode(
                params["attn"], cfg, h_in, cache["k"], cache["v"], pos)
        new_cache["k"], new_cache["v"] = ck, cv
        x = x + h
        if kind == "cross":
            xa = params["xattn"]
            B = x.shape[0]
            hd = cfg.head_dim
            q = (rmsnorm(x, params["ln_x"], eps) @ xa["wq"].astype(cdt))
            q = q.reshape(B, 1, cfg.num_heads, hd)
            Hkv = cfg.kv_heads_eff
            rep = cfg.num_heads // Hkv
            qg = q.reshape(B, 1, Hkv, rep, hd).astype(jnp.float32)
            s = jnp.einsum("bqhrd,bshd->bhrqs", qg,
                           cache["xk"].astype(jnp.float32))
            s = s / jnp.sqrt(1.0 * hd)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhrqs,bshd->bqhrd", p,
                           cache["xv"].astype(jnp.float32))
            o = o.reshape(B, 1, cfg.num_heads * hd).astype(cdt)
            x = x + o @ xa["wo"].astype(cdt)
        ff_in = rmsnorm(x, params["ln2"], eps)
        if kind == "moe":
            h, _ = moe_lib.moe_ffn(params["moe"], cfg, ff_in)
        else:
            h = mlp(params["mlp"], ff_in, cdt)
        x = x + h
    elif kind == "rwkv":
        xt = rmsnorm(x[:, 0], params["ln1"], eps)
        h, last_t, S = rwkv_lib.time_mix_step(
            params["tmix"], cfg, xt, cache["tmix_x"], cache["S"])
        x = x + h[:, None]
        xc = rmsnorm(x[:, 0], params["ln2"], eps)
        h, last_c = rwkv_lib.channel_mix_step(
            params["cmix"], cfg, xc, cache["cmix_x"])
        x = x + h[:, None]
        new_cache.update(S=S, tmix_x=last_t, cmix_x=last_c)
    elif kind == "rec":
        h, (hl, tail) = griffin_lib.recurrent_block_step(
            params["rec"], cfg, rmsnorm(x[:, 0], params["ln1"], eps),
            (cache["h"], cache["conv"]))
        x = x + h[:, None]
        h = mlp(params["mlp"], rmsnorm(x, params["ln2"], eps), cdt)
        x = x + h
        new_cache.update(h=hl, conv=tail)
    else:
        raise ValueError(kind)
    return x, new_cache


def decode_step(params, cfg: ModelConfig, caches, *, tokens: Array,
                pos) -> Tuple[Array, list]:
    """tokens: (B,) int32; pos: scalar int32 position. -> (logits (B,V), caches)."""
    x = embed_tokens(params, cfg, tokens[:, None])
    x = shard(x, DP, None, "model")
    seg_meta = segment_structure(layer_kinds(cfg))
    new_caches = []
    for (kind, count), stacked, cache in zip(seg_meta, params["blocks"],
                                             caches):
        def body(xc, layer, _kind=kind):
            lp, lc = layer
            xo, nc = _block_step(lp, cfg, _kind, xc, lc, pos)
            return shard(xo, DP, None, "model"), nc

        if cfg.scan_layers and count > 1:
            x, nc = jax.lax.scan(body, x, (stacked, cache))
        else:
            ncs = []
            for li in range(count):
                lp = jax.tree.map(lambda a: a[li], stacked)
                lc = jax.tree.map(lambda a: a[li], cache)
                x, c1 = body(x, (lp, lc))
                ncs.append(c1)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        new_caches.append(nc)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (h[:, 0].astype(jnp.float32)
              @ head.astype(jnp.float32))
    logits = shard(logits, DP, "model")
    return logits, new_caches


# ---------------------------------------------------------------------------
# Prefill: forward that also fills the attention caches
# ---------------------------------------------------------------------------

def _block_prefill(params, cfg: ModelConfig, kind: str, x: Array,
                   positions, s_max: int, enc_out=None,
                   attn_impl: str = "xla", cache_dtype=jnp.bfloat16):
    """Full-sequence block that also returns this layer's cache content."""
    eps = cfg.norm_eps
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    cache: Dict[str, Array] = {}
    if kind in ("attn", "moe", "cross", "attn_local"):
        h_in = rmsnorm(x, params["ln1"], eps)
        q, k, v = _project_qkv(params["attn"], cfg, h_in, positions)
        window = cfg.window_size if kind == "attn_local" else 0
        o = chunked_attention_xla(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window,
            unroll=cfg.unroll_inner)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
        x = x + o @ params["attn"]["wo"].astype(cdt)
        if kind == "attn_local":
            W = min(cfg.window_size, s_max)
            # Ring layout: slot = pos % W for the last W positions.
            last_pos = positions[..., -W:] if S >= W else positions
            kw = k[:, -W:] if S >= W else k
            vw = v[:, -W:] if S >= W else v
            slots = jnp.mod(jnp.arange(S)[-W:] if S >= W else jnp.arange(S), W)
            ck = jnp.zeros((B, W, cfg.kv_heads_eff, cfg.head_dim), cache_dtype)
            cv = jnp.zeros_like(ck)
            ck = ck.at[:, slots].set(kw.astype(ck.dtype))
            cv = cv.at[:, slots].set(vw.astype(cv.dtype))
            cache["k"], cache["v"] = ck, cv
        else:
            pad = s_max - S
            cache["k"] = jnp.pad(
                k.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(
                v.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kind == "cross":
            xa = params["xattn"]
            Se = enc_out.shape[1]
            hd = cfg.head_dim
            xk = (enc_out @ xa["wk"].astype(cdt)).reshape(
                B, Se, cfg.kv_heads_eff, hd)
            xv = (enc_out @ xa["wv"].astype(cdt)).reshape(
                B, Se, cfg.kv_heads_eff, hd)
            hq = (rmsnorm(x, params["ln_x"], eps) @ xa["wq"].astype(cdt))
            hq = hq.reshape(B, S, cfg.num_heads, hd)
            o = chunked_attention_xla(
                hq.transpose(0, 2, 1, 3), xk.transpose(0, 2, 1, 3),
                xv.transpose(0, 2, 1, 3), causal=False,
                unroll=cfg.unroll_inner)
            o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
            x = x + o @ xa["wo"].astype(cdt)
            cache["xk"] = xk.astype(cache_dtype)
            cache["xv"] = xv.astype(cache_dtype)
        ff_in = rmsnorm(x, params["ln2"], eps)
        if kind == "moe":
            if cfg.moe_impl == "a2a":
                from repro.models.moe_a2a import moe_ffn_a2a
                h, _ = moe_ffn_a2a(params["moe"], cfg, ff_in)
            else:
                h, _ = moe_lib.moe_ffn(params["moe"], cfg, ff_in)
        else:
            h = mlp(params["mlp"], ff_in, cdt)
        x = x + h
    elif kind == "rwkv":
        h, (last_t, S_final) = rwkv_lib.time_mix(
            params["tmix"], cfg, rmsnorm(x, params["ln1"], eps))
        x = x + h
        hc, last_c = rwkv_lib.channel_mix(params["cmix"], cfg,
                                          rmsnorm(x, params["ln2"], eps))
        x = x + hc
        cache["S"] = S_final
        cache["tmix_x"] = last_t
        cache["cmix_x"] = last_c
    elif kind == "rec":
        h, (hl, tail) = griffin_lib.recurrent_block(
            params["rec"], cfg, rmsnorm(x, params["ln1"], eps))
        x = x + h
        x = x + mlp(params["mlp"], rmsnorm(x, params["ln2"], eps), cdt)
        cache["h"] = hl.astype(jnp.float32)
        cache["conv"] = tail.astype(jnp.float32)
    else:
        raise ValueError(kind)
    return x, cache


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, enc_embeds=None, s_max: int,
            attn_impl: str = "xla", cache_dtype=jnp.bfloat16):
    """Run the prompt, return (last-token logits (B,V), caches)."""
    if embeds is None:
        embeds = embed_tokens(params, cfg, tokens)
    B, S, d = embeds.shape
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions = jnp.broadcast_to(base, (3, B, S)) if cfg.mrope else base
    x = shard(embeds, DP, None, "model")

    enc_out = None
    if cfg.encoder_layers:
        from repro.models.model import _run_stack  # encoder has no cache
        Be, Se, _ = enc_embeds.shape
        enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (Be, Se))
        enc_x = shard(enc_embeds.astype(cfg.compute_dtype), DP, None, "model")
        enc_x, _ = _run_stack(
            params["enc_blocks"],
            segment_structure(layer_kinds(cfg, "encoder")),
            cfg, enc_x, enc_pos, causal=False, attn_impl=attn_impl)
        enc_out = rmsnorm(enc_x, params["enc_norm"], cfg.norm_eps)

    seg_meta = segment_structure(layer_kinds(cfg))
    caches = []
    for (kind, count), stacked in zip(seg_meta, params["blocks"]):
        def body(xc, layer_params, _kind=kind):
            xo, c = _block_prefill(layer_params, cfg, _kind, xc, positions,
                                   s_max, enc_out=enc_out,
                                   attn_impl=attn_impl,
                                   cache_dtype=cache_dtype)
            return shard(xo, DP, None, "model"), c

        if cfg.scan_layers and count > 1:
            x, cache = jax.lax.scan(body, x, stacked)
        else:
            cs = []
            for li in range(count):
                lp = jax.tree.map(lambda a: a[li], stacked)
                x, c1 = body(x, lp)
                cs.append(c1)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
        caches.append(cache)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = h[:, -1].astype(jnp.float32) @ head.astype(jnp.float32)
    return shard(logits, DP, "model"), caches
