"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free, data-dependent decay.

Time-mix: per-head matrix-valued state S in R^{hd x hd} evolving as
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with per-channel data-dependent decay w_t in (0,1) produced by a low-rank MLP
(ddlerp token-shift mixing for r/k/v/g/w as in the paper).

The sequence form is CHUNKED (GLA-style): within a chunk of length Lc the
intra-chunk part is a masked score contraction with exact per-channel decay
factors exp(cum_{t-1} - cum_s) (exponent always <= 0 — numerically safe; the
naive k/P_s form overflows), and the inter-chunk part flows through the
carried state. lax.scan over chunks => O(S/Lc) sequential steps on TPU with
dense MXU work inside, O(1) state for 500k-token decode (the long_500k cell).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array

# Per-step log-decay floor. exp factors inside a chunk are bounded by
# exp(chunk * |log w|); with chunk=16 and floor -5 the worst factor is e^80
# < f32 max. Semantically free: w < e^-5 retains 0.7% per step — state is
# gone either way (the fla/GLA kernels apply the same style of clamp).
WKV_LOG_CLAMP = -5.0


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rwkv_lora_rank
    H = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    dt = cfg.param_dtype
    return {
        "maa_base": jnp.zeros((5, d), dt),              # w,k,v,r,g mix biases
        "maa_w1": dense_init(ks[0], (d, 5 * r), dt),
        "maa_w2": dense_init(ks[1], (5, r, d), dt, scale=1.0 / r ** 0.5),
        "decay_base": jnp.full((d,), -2.0, dt),
        "decay_w1": dense_init(ks[2], (d, 2 * r), dt),
        "decay_w2": dense_init(ks[3], (2 * r, d), dt, scale=1.0 / r ** 0.5),
        "bonus": jnp.zeros((H, cfg.rwkv_head_dim), dt),  # u
        "wr": dense_init(ks[4], (d, d), dt),
        "wk": dense_init(ks[5], (d, d), dt),
        "wv": dense_init(ks[6], (d, d), dt),
        "wg": dense_init(ks[7], (d, d), dt),
        "wo": dense_init(ks[8], (d, d), dt),
        "gn_scale": jnp.ones((d,), dt),
        "gn_bias": jnp.zeros((d,), dt),
    }


def init_channel_mix(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "mu_k": jnp.zeros((d,), dt),
        "mu_r": jnp.zeros((d,), dt),
        "wk": dense_init(k1, (d, ff), dt),
        "wv": dense_init(k2, (ff, d), dt),
        "wr": dense_init(k3, (d, d), dt),
    }


def _wkv_chunked(r, k, v, w_log, u, chunk: int, unroll: bool = False):
    """Per-head chunked WKV. r/k/v: (T, hd); w_log: (T, hd) (= log w < 0);
    u: (hd,). Returns (y: (T, hd), S_final). f32 math. ``unroll`` replaces
    the chunk scan with a python loop (dry-run cost extraction)."""
    T, hd = r.shape
    assert T % chunk == 0
    nc = T // chunk
    rs = r.reshape(nc, chunk, hd)
    ks = k.reshape(nc, chunk, hd)
    vs = v.reshape(nc, chunk, hd)
    ws = w_log.reshape(nc, chunk, hd)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strictly lower

    def body(S, inp):
        rc, kc, vc, wc = inp                                # (Lc, hd)
        cum = jnp.cumsum(wc, axis=0)                        # inclusive
        cum_prev = cum - wc                                 # cum_{t-1}
        # intra: A[t,s] = sum_d r[t]k[s] exp(cum_prev[t]-cum[s]), s<t
        expo = cum_prev[:, None, :] - cum[None, :, :]       # (t,s,hd) <= 0
        expo = jnp.where(mask[:, :, None], expo, -jnp.inf)
        A = jnp.sum(rc[:, None, :] * kc[None, :, :] * jnp.exp(expo), axis=-1)
        diag = jnp.sum(rc * u[None, :] * kc, axis=-1)       # (Lc,)
        y = A @ vc + diag[:, None] * vc
        # inter: y += (r ⊙ exp(cum_prev)) @ S
        y = y + (rc * jnp.exp(cum_prev)) @ S
        # carry: S' = diag(exp(cum_T)) S + sum_s (k_s ⊙ exp(cum_T - cum_s)) v_s^T
        decay_T = jnp.exp(cum[-1])[:, None]                 # (hd,1)
        kk = kc * jnp.exp(cum[-1][None, :] - cum)           # (Lc, hd)
        S_new = decay_T * S + kk.T @ vc
        return S_new, y

    S0 = jnp.zeros((hd, hd), jnp.float32)
    if unroll:
        S, ys_list = S0, []
        for c in range(nc):
            S, yc = body(S, (rs[c], ks[c], vs[c], ws[c]))
            ys_list.append(yc)
        return jnp.stack(ys_list).reshape(T, hd), S
    S_final, ys = jax.lax.scan(body, S0, (rs, ks, vs, ws))
    return ys.reshape(T, hd), S_final


def _wkv_chunked_matmul(r, k, v, w_log, u, chunk: int, unroll: bool = False):
    """§Perf H2 — separable-decay MXU form of the chunked WKV.

    The exact form resolves exp(cum_{t-1} - cum_s) per channel inside the
    score sum, materializing a (Lc, Lc, hd) tensor per chunk — ~Lc x more
    HBM traffic than the matmuls need. Because the decay factor separates,
        A[t,s] = sum_d (r[t,d] e^{cum[t-1,d]}) (k[s,d] e^{-cum[s,d]}),
    the intra-chunk part is a single (Lc,hd)x(hd,Lc) GEMM after scaling
    r and k by per-chunk decay factors. e^{-cum} grows with chunk depth, so
    the chunk is short (16) and the per-step log-decay is floored at
    WKV_LOG_CLAMP (see above) — exponents stay within f32 range.
    """
    T, hd = r.shape
    assert T % chunk == 0
    nc = T // chunk
    rs = r.reshape(nc, chunk, hd)
    ks = k.reshape(nc, chunk, hd)
    vs = v.reshape(nc, chunk, hd)
    ws = w_log.reshape(nc, chunk, hd)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strictly lower

    def body(S, inp):
        rc, kc, vc, wc = inp                                # (Lc, hd)
        cum = jnp.cumsum(wc, axis=0)                        # inclusive, <= 0
        cum_prev = cum - wc
        r_t = rc * jnp.exp(cum_prev)                        # <= |r|
        k_t = kc * jnp.exp(-cum)                            # bounded e^{5Lc}
        A = jnp.where(mask, r_t @ k_t.T, 0.0)               # (Lc, Lc)
        diag = jnp.sum(rc * u[None, :] * kc, axis=-1)
        y = A @ vc + diag[:, None] * vc + r_t @ S
        decay_T = jnp.exp(cum[-1])[:, None]
        kk = kc * jnp.exp(cum[-1][None, :] - cum)
        S_new = decay_T * S + kk.T @ vc
        return S_new, y

    S0 = jnp.zeros((hd, hd), jnp.float32)
    if unroll:
        S, ys_list = S0, []
        for c in range(nc):
            S, yc = body(S, (rs[c], ks[c], vs[c], ws[c]))
            ys_list.append(yc)
        return jnp.stack(ys_list).reshape(T, hd), S
    S_final, ys = jax.lax.scan(body, S0, (rs, ks, vs, ws))
    return ys.reshape(T, hd), S_final


def time_mix(p, cfg: ModelConfig, x: Array, x_prev_last: Array | None = None):
    """x: (B, S, d). Token shift uses the previous position (zero/state at 0).
    Returns (out, (last_x, S_final)) — the carries used by decode."""
    B, S, d = x.shape
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    cdt = cfg.compute_dtype
    xf = x.astype(jnp.float32)
    prev0 = jnp.zeros((B, 1, d), jnp.float32) if x_prev_last is None \
        else x_prev_last[:, None, :].astype(jnp.float32)
    x_prev = jnp.concatenate([prev0, xf[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp_simple(p, xf, x_prev)

    r = (xr.astype(cdt) @ p["wr"].astype(cdt)).reshape(B, S, H, hd)
    k = (xk.astype(cdt) @ p["wk"].astype(cdt)).reshape(B, S, H, hd)
    v = (xv.astype(cdt) @ p["wv"].astype(cdt)).reshape(B, S, H, hd)
    g = xg.astype(cdt) @ p["wg"].astype(cdt)
    w_log = -jnp.exp(
        p["decay_base"].astype(jnp.float32)
        + jnp.tanh(xw @ p["decay_w1"].astype(jnp.float32))
        @ p["decay_w2"].astype(jnp.float32)
    ).reshape(B, S, H, hd)                                   # log w < 0
    w_log = jnp.maximum(w_log, WKV_LOG_CLAMP)
    u = p["bonus"].astype(jnp.float32)
    wkv_fn = _wkv_chunked_matmul if cfg.wkv_impl == "matmul" \
        else _wkv_chunked

    def per_bh(rb, kb, vb, wb, ub):
        return wkv_fn(
            rb.astype(jnp.float32), kb.astype(jnp.float32),
            vb.astype(jnp.float32), wb, ub, cfg.wkv_chunk,
            unroll=cfg.unroll_inner,
        )

    # vmap over batch (broadcast u) and heads
    y, S_final = jax.vmap(
        jax.vmap(per_bh, in_axes=(1, 1, 1, 1, 0), out_axes=(1, 0)),  # heads
        in_axes=(0, 0, 0, 0, None),
    )(r, k, v, w_log, u)                  # y: (B, S, H, hd); S: (B, H, hd, hd)
    y = y.reshape(B, S, d)
    # per-head GroupNorm then gate
    yh = y.reshape(B, S, H, hd)
    mean = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, d) * p["gn_scale"] + p["gn_bias"]
    out = (y.astype(cdt) * jax.nn.silu(g)) @ p["wo"].astype(cdt)
    return out, (xf[:, -1, :], S_final)


def _ddlerp_simple(p, x, x_prev):
    """ddlerp as in RWKV6: shared tanh bottleneck, per-stream low-rank out."""
    dx = x_prev - x
    base = p["maa_base"].astype(jnp.float32)                 # (5, d)
    w1 = p["maa_w1"].astype(jnp.float32)                     # (d, 5r)
    w2 = p["maa_w2"].astype(jnp.float32)                     # (5, r, d)
    r5 = w1.shape[1] // 5
    xx = x + dx * base[0][None, None]                        # shift seed
    z = jnp.tanh(xx @ w1).reshape(*x.shape[:-1], 5, r5)      # (B,S,5,r)
    mod = jnp.einsum("bsir,ird->bsid", z, w2)                # (B,S,5,d)
    mix = base[None, None] + mod
    return tuple(x + dx * mix[:, :, i] for i in range(5))


def channel_mix(p, cfg: ModelConfig, x: Array,
                x_prev_last: Array | None = None):
    B, S, d = x.shape
    cdt = cfg.compute_dtype
    xf = x.astype(jnp.float32)
    prev0 = jnp.zeros((B, 1, d), jnp.float32) if x_prev_last is None \
        else x_prev_last[:, None, :].astype(jnp.float32)
    x_prev = jnp.concatenate([prev0, xf[:, :-1]], axis=1)
    dx = x_prev - xf
    xk = (xf + dx * p["mu_k"]).astype(cdt)
    xr = (xf + dx * p["mu_r"]).astype(cdt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(cdt)) * (kk @ p["wv"].astype(cdt))
    return out, xf[:, -1, :]


# ---------------------------------------------------------------------------
# Decode (single step) — O(1) state: (last_x_tmix, last_x_cmix, S (H,hd,hd))
# ---------------------------------------------------------------------------

def time_mix_step(p, cfg: ModelConfig, x: Array, last_x: Array, S: Array):
    """x: (B, d); last_x: (B, d); S: (B, H, hd, hd). Returns (out, last, S')."""
    B, d = x.shape
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    cdt = cfg.compute_dtype
    xf = x.astype(jnp.float32)
    xw, xk, xv, xr, xg = (
        t[:, 0] for t in _ddlerp_simple(
            p, xf[:, None, :], last_x.astype(jnp.float32)[:, None, :]
        )
    )
    r = (xr.astype(cdt) @ p["wr"].astype(cdt)).reshape(B, H, hd)
    k = (xk.astype(cdt) @ p["wk"].astype(cdt)).reshape(B, H, hd)
    v = (xv.astype(cdt) @ p["wv"].astype(cdt)).reshape(B, H, hd)
    g = xg.astype(cdt) @ p["wg"].astype(cdt)
    w = jnp.exp(jnp.maximum(-jnp.exp(
        p["decay_base"].astype(jnp.float32)
        + jnp.tanh(xw @ p["decay_w1"].astype(jnp.float32))
        @ p["decay_w2"].astype(jnp.float32)
    ), WKV_LOG_CLAMP)).reshape(B, H, hd)
    u = p["bonus"].astype(jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]                 # (B,H,hd,hd)
    y = jnp.einsum("bhk,bhkv->bhv", rf, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    yh = y.reshape(B, H, hd)
    mean = jnp.mean(yh, -1, keepdims=True)
    var = jnp.var(yh, -1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    yd = yh.reshape(B, d) * p["gn_scale"] + p["gn_bias"]
    out = (yd.astype(cdt) * jax.nn.silu(g)) @ p["wo"].astype(cdt)
    return out, xf, S_new


def channel_mix_step(p, cfg: ModelConfig, x: Array, last_x: Array):
    cdt = cfg.compute_dtype
    xf = x.astype(jnp.float32)
    dx = last_x.astype(jnp.float32) - xf
    xk = (xf + dx * p["mu_k"]).astype(cdt)
    xr = (xf + dx * p["mu_r"]).astype(cdt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(cdt)) * (kk @ p["wv"].astype(cdt))
    return out, xf
