"""Unified model configuration covering all 10 assigned architectures.

Families:
  dense   — GQA transformer (qwen3-8b/14b, command-r-35b, phi3-medium-14b)
  moe     — GQA transformer + MoE FFN (olmoe-1b-7b, arctic-480b w/ dense residual)
  rwkv6   — attention-free Finch (time-mix WKV + channel-mix)
  griffin — RG-LRU + local-attention hybrid (recurrentgemma-9b, 2:1 pattern)
  encdec  — encoder-decoder backbone (seamless-m4t-large-v2; audio stub)
The vlm entry (qwen2-vl-72b) is family=dense + mrope + vision stub.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | griffin | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False              # qwen2-vl M-RoPE (t/h/w sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # per half-dim
    attn_logit_softcap: float = 0.0
    kv_repeat: int = 1               # KV-head replication for TP alignment
                                     # (vLLM-style; DESIGN.md §6)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None   # expert hidden (defaults to d_ff)
    moe_dense_residual: bool = False # arctic: dense SwiGLU in parallel
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "gspmd": sort-based dispatch, resharding left to the compiler
    # "a2a":   explicit two-hop all-to-all EP under shard_map (§Perf)
    moe_impl: str = "gspmd"

    # griffin (RG-LRU hybrid)
    pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")
    window_size: int = 2048          # local attention window
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 32
    wkv_chunk: int = 16
    # "matmul": separable-decay MXU form (2 small GEMMs/chunk, no (t,s,d)
    #   tensor; log-decay clamped at WKV_LOG_CLAMP for f32 range — §Perf H2)
    # "einsum": exact decay-resolved (t,s,d) form (oracle for tests)
    wkv_impl: str = "matmul"

    # encdec
    encoder_layers: int = 0          # >0 => encoder-decoder
    cross_attention: bool = False

    # frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"

    # numerics / training
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    optimizer: str = "adamw"         # adamw | adafactor (arctic)
    remat: str = "full"              # full | dots | none
    scan_layers: bool = True
    # Cost-extraction mode (dry-run only): replace inner lax.scan/map loops
    # (CE chunks, attention q-chunks, WKV chunks) with unrolled python loops
    # so XLA cost_analysis counts every iteration. Numerically identical.
    unroll_inner: bool = False
    # §Perf H1 — sequence-parallel layer pattern: keep the residual stream
    # d-sharded on 'model' between layers and materialize the replicated
    # activation ONCE per block half (reused by q/k/v or w1/w3), instead of
    # letting GSPMD re-gather per projection. 2 AG + 2 RS per layer.
    sp_collectives: bool = True
    # §Perf H-mem — FSDP: additionally shard params over the DP axes (ZeRO-3
    # style; GSPMD all-gathers per layer inside the scan). Required for the
    # >=35B configs to fit 16 GB/chip (DESIGN.md §6).
    fsdp: bool = False
    # §Perf H3 — parallelism strategy:
    #   "tp":   Megatron TP on 'model' + DP on ('pod','data')  (default)
    #   "fsdp": NO tensor parallelism; batch sharded over ALL mesh axes and
    #           params fully sharded + per-layer all-gathered. Right choice
    #           when tokens/device >> params/layer (e.g. <=14B dense at
    #           global-batch 256 x 4k): activation collectives vanish and
    #           the cell flips from collective-bound to compute-bound.
    parallelism: str = "tp"

    @property
    def dp_axes(self):
        return ("pod", "data", "model") if self.parallelism == "fsdp" \
            else ("pod", "data")

    # shapes this arch skips, with reasons (DESIGN.md §5)
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "moe" and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.family == "griffin" and self.lru_width is None:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def kv_heads_eff(self) -> int:
        return self.num_kv_heads * self.kv_repeat

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        per_layer = 0
        if self.family in ("dense", "moe"):
            per_layer = att + 2 * d  # norms
            if self.family == "dense":
                per_layer += dense_ffn
            else:
                per_layer += self.num_experts * 3 * d * self.moe_d_ff \
                    + d * self.num_experts
                if self.moe_dense_residual:
                    per_layer += dense_ffn
            total = emb + head + self.num_layers * per_layer
        elif self.family == "rwkv6":
            r = self.rwkv_lora_rank
            tmix = 4 * d * d + d * d  # r,k,v,g,w projections (w low-rank-ish)
            tmix += 5 * (d * r + r * d)  # ddlerp loras
            cmix = 2 * d * self.d_ff + 0
            per_layer = tmix + cmix + 2 * d
            total = emb + head + self.num_layers * per_layer
        elif self.family == "griffin":
            lw = self.lru_width
            rec = 2 * d * lw + lw * d + lw * self.conv_width + 2 * lw  # gates
            attn_l = att
            n_attn = sum(1 for i in range(self.num_layers)
                         if self._layer_kind(i) == "attn")
            n_rec = self.num_layers - n_attn
            total = emb + head + n_rec * (rec + dense_ffn + 2 * d) \
                + n_attn * (attn_l + dense_ffn + 2 * d)
        elif self.family == "encdec":
            dec = att + dense_ffn + 2 * d
            cross = att + d
            enc = att + dense_ffn + 2 * d
            total = emb + head + self.encoder_layers * enc \
                + self.num_layers * (dec + cross)
        else:
            raise ValueError(self.family)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6ND."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        inactive = (self.num_experts - self.experts_per_token) \
            * 3 * d * self.moe_d_ff * self.num_layers
        return int(self.param_count() - inactive)

    def _layer_kind(self, i: int) -> str:
        """griffin: layer i kind from the repeating pattern."""
        if self.family != "griffin" or not self.pattern:
            return "dense"
        return self.pattern[i % len(self.pattern)]
