"""Shared transformer layers: RMSNorm, RoPE/M-RoPE, SwiGLU, GQA attention
(train: flash / chunked online-softmax; serve: KV-cache decode step).

Parameters are plain dict pytrees; init functions take an rng key and return
arrays in ``param_dtype``. Compute is in ``compute_dtype`` (bf16 on TPU) with
f32 for norms/softmax statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.ops import flash_attention, chunked_attention_xla
from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> Array:
    """x: (B, S, H, hd). positions: (B, S) int32, or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 rotary frequencies are split into
    (temporal, height, width) sections; each section takes its angle from the
    corresponding position stream. Text tokens carry identical t/h/w
    positions, reducing M-RoPE to 1-D RoPE exactly.
    """
    B, S, H, hd = x.shape
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 3:
        assert mrope_sections is not None
        assert sum(mrope_sections) == hd // 2, (mrope_sections, hd)
        sec = jnp.concatenate([
            jnp.full((s,), i, jnp.int32)
            for i, s in enumerate(mrope_sections)
        ])                                            # (hd/2,) section id
        pos = positions.astype(jnp.float32)           # (3, B, S)
        angle = pos[sec, :, :].transpose(1, 2, 0) * inv[None, None, :]
    else:
        angle = positions.astype(jnp.float32)[..., None] * inv[None, None, :]
    cos = jnp.cos(angle)[:, :, None, :]               # (B, S, 1, hd/2)
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d, ff), dtype),
        "w3": dense_init(k2, (d, ff), dtype),
        "w2": dense_init(k3, (ff, d), dtype),
    }


def mlp(params, x: Array, cdt) -> Array:
    h = jax.nn.silu(x @ params["w1"].astype(cdt)) * (x @ params["w3"].astype(cdt))
    return h @ params["w2"].astype(cdt)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    hd = cfg.head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, (d, cfg.num_heads * hd), cfg.param_dtype),
        "wk": dense_init(kk, (d, cfg.kv_heads_eff * hd), cfg.param_dtype),
        "wv": dense_init(kv, (d, cfg.kv_heads_eff * hd), cfg.param_dtype),
        "wo": dense_init(ko, (cfg.num_heads * hd, d), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.param_dtype)
    return p


def _project_qkv(params, cfg: ModelConfig, x: Array, positions: Array):
    B, S, _ = x.shape
    hd = cfg.head_dim
    cdt = cfg.compute_dtype
    q = (x @ params["wq"].astype(cdt)).reshape(B, S, cfg.num_heads, hd)
    k = (x @ params["wk"].astype(cdt)).reshape(B, S, cfg.kv_heads_eff, hd)
    v = (x @ params["wv"].astype(cdt)).reshape(B, S, cfg.kv_heads_eff, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    sections = cfg.mrope_sections if cfg.mrope else None
    q = apply_rope(q, positions, cfg.rope_theta, sections)
    k = apply_rope(k, positions, cfg.rope_theta, sections)
    return q, k, v


def attention(params, cfg: ModelConfig, x: Array, positions: Array, *,
              causal: bool = True, window: int = 0,
              kv_override: Optional[Tuple[Array, Array]] = None,
              attn_impl: str = "xla") -> Array:
    """Full-sequence attention (train / prefill / encoder).

    kv_override: (k, v) already projected — used by cross-attention.
    window > 0: local attention |q - k| < window (griffin).
    """
    B, S, _ = x.shape
    if kv_override is None:
        q, k, v = _project_qkv(params, cfg, x, positions)
    else:
        # Cross-attention: no RoPE on q/k (positions are heterogeneous).
        cdt = cfg.compute_dtype
        hd = cfg.head_dim
        q = (x @ params["wq"].astype(cdt)).reshape(B, S, cfg.num_heads, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k, v = kv_override
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if attn_impl.startswith("pallas") and window == 0:
        o = flash_attention(qt, kt, vt, causal=causal, impl=attn_impl)
    else:
        # unroll mode uses larger q-chunks purely to bound the number of
        # unrolled iterations (total score bytes are chunk-invariant).
        o = chunked_attention_xla(qt, kt, vt, causal=causal, window=window,
                                  chunk_q=2048 if cfg.unroll_inner else 512,
                                  unroll=cfg.unroll_inner)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return o @ params["wo"].astype(cfg.compute_dtype)


def attention_decode(params, cfg: ModelConfig, x: Array, cache_k: Array,
                     cache_v: Array, pos: Array, *, window: int = 0):
    """One decode step. x: (B, 1, d); cache_k/v: (B, Smax, Hkv_eff, hd);
    pos: scalar int32 — current position (same for the whole batch).

    Returns (out, cache_k, cache_v) with the caches updated at ``pos``.
    """
    B = x.shape[0]
    hd = cfg.head_dim
    if cfg.mrope:
        positions = jnp.full((3, B, 1), pos, jnp.int32)  # text: t=h=w
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), pos, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), pos, axis=1
    )
    Smax = cache_k.shape[1]
    Hkv = cfg.kv_heads_eff
    rep = cfg.num_heads // Hkv
    qg = q.reshape(B, 1, Hkv, rep, hd).astype(jnp.float32)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    s = jnp.einsum("bqhrd,bshd->bhrqs", qg, kf) / jnp.sqrt(1.0 * hd)
    idx = jnp.arange(Smax)
    mask = idx[None, :] <= pos
    if window:
        mask = mask & (idx[None, :] > pos - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqs,bshd->bqhrd", p, vf)
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(cfg.compute_dtype)
    return o @ params["wo"].astype(cfg.compute_dtype), cache_k, cache_v
