"""Unified LM covering all assigned families: init / forward / loss /
prefill / decode, with scan-over-layers, remat, and logical sharding specs.

Param pytree layout (scanned stacks carry a leading L dim):
  {embed, blocks | groups+tail, final_norm, lm_head [, enc_blocks, enc_norm]}

Activation sharding: batch on ('pod','data'); attention heads / FFN hidden /
experts / vocab on 'model'; the saved residual stream between scanned layers
is additionally sharded on 'model' along d_model (sequence-parallel-style
memory saving — see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import griffin as griffin_lib
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    attention,
    attention_decode,
    dense_init,
    init_attention,
    init_mlp,
    mlp,
    rmsnorm,
)
from repro.sharding.util import DP, shard

Array = jax.Array


# ---------------------------------------------------------------------------
# Block init / apply (one layer)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), cfg.param_dtype),
                         "ln2": jnp.zeros((d,), cfg.param_dtype)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.param_dtype)
    elif kind == "moe":
        p["attn"] = init_attention(ks[0], cfg)
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    elif kind == "rwkv":
        p["tmix"] = rwkv_lib.init_time_mix(ks[0], cfg)
        p["cmix"] = rwkv_lib.init_channel_mix(ks[1], cfg)
    elif kind == "rec":
        p["rec"] = griffin_lib.init_recurrent_block(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.param_dtype)
    elif kind == "attn_local":
        p["attn"] = init_attention(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.param_dtype)
    elif kind == "cross":  # encoder-decoder decoder layer
        p["attn"] = init_attention(ks[0], cfg)
        p["ln_x"] = jnp.zeros((d,), cfg.param_dtype)
        p["xattn"] = init_attention(ks[1], cfg)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.param_dtype)
    else:
        raise ValueError(kind)
    return p


def _apply_block(params, cfg: ModelConfig, kind: str, x: Array,
                 positions: Array, *, causal: bool = True,
                 enc_out: Optional[Array] = None,
                 enc_positions: Optional[Array] = None,
                 attn_impl: str = "xla") -> Tuple[Array, Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps

    tp = cfg.parallelism == "tp"

    def gather_in(v):
        # §Perf H1: one explicit all-gather per block half; every projection
        # then consumes the same replicated tensor (no per-matmul re-gather).
        return shard(v, cfg.dp_axes, None, None) \
            if (cfg.sp_collectives and tp) else v

    def scatter_out(h):
        # reduce-scatter block output into the d-sharded residual (TP only)
        return shard(h, cfg.dp_axes, None, "model") \
            if (cfg.sp_collectives and tp) else h

    if kind in ("attn", "moe", "attn_local", "cross"):
        window = cfg.window_size if kind == "attn_local" else 0
        h = attention(params["attn"], cfg,
                      gather_in(rmsnorm(x, params["ln1"], eps)),
                      positions, causal=causal, window=window,
                      attn_impl=attn_impl)
        h = scatter_out(h)
        x = x + h
        if kind == "cross":
            # cross-attention: kv from encoder output (own projections).
            xa = params["xattn"]
            cdt = cfg.compute_dtype
            B, Se, _ = enc_out.shape
            hd = cfg.head_dim
            k = (enc_out @ xa["wk"].astype(cdt)).reshape(
                B, Se, cfg.kv_heads_eff, hd
            )
            v = (enc_out @ xa["wv"].astype(cdt)).reshape(
                B, Se, cfg.kv_heads_eff, hd
            )
            h = attention(xa, cfg, rmsnorm(x, params["ln_x"], eps),
                          positions, causal=False, kv_override=(k, v),
                          attn_impl=attn_impl)
            x = x + h
        ff_in = gather_in(rmsnorm(x, params["ln2"], eps))
        if kind == "moe":
            if cfg.moe_impl == "a2a":
                from repro.models.moe_a2a import moe_ffn_a2a
                h, aux = moe_ffn_a2a(params["moe"], cfg,
                                     rmsnorm(x, params["ln2"], eps))
            else:
                h, aux = moe_lib.moe_ffn(params["moe"], cfg, ff_in)
        else:
            h = mlp(params["mlp"], ff_in, cfg.compute_dtype)
        h = scatter_out(h)
        x = x + h
    elif kind == "rwkv":
        h, _state = rwkv_lib.time_mix(
            params["tmix"], cfg, gather_in(rmsnorm(x, params["ln1"], eps)))
        h = scatter_out(h)
        x = x + h
        h, _ = rwkv_lib.channel_mix(
            params["cmix"], cfg, gather_in(rmsnorm(x, params["ln2"], eps)))
        h = scatter_out(h)
        x = x + h
    elif kind == "rec":
        h, _ = griffin_lib.recurrent_block(
            params["rec"], cfg, gather_in(rmsnorm(x, params["ln1"], eps)))
        h = scatter_out(h)
        x = x + h
        h = mlp(params["mlp"], gather_in(rmsnorm(x, params["ln2"], eps)),
                cfg.compute_dtype)
        h = scatter_out(h)
        x = x + h
    else:
        raise ValueError(kind)
    return x, aux


def layer_kinds(cfg: ModelConfig, role: str = "decoder") -> Tuple[str, ...]:
    """Per-layer kind list for the given config."""
    if role == "encoder":
        return ("attn",) * cfg.encoder_layers
    if cfg.family == "dense":
        return ("attn",) * cfg.num_layers
    if cfg.family == "moe":
        return ("moe",) * cfg.num_layers
    if cfg.family == "rwkv6":
        return ("rwkv",) * cfg.num_layers
    if cfg.family == "griffin":
        pat = cfg.pattern or ("rec", "rec", "attn_local")
        return tuple(pat[i % len(pat)] for i in range(cfg.num_layers))
    if cfg.family == "encdec":
        return ("cross",) * cfg.num_layers
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def segment_structure(kinds: Tuple[str, ...]) -> Tuple[Tuple[str, int], ...]:
    """Maximal homogeneous runs of layer kinds: ((kind, count), ...).
    STATIC metadata — kept out of the param pytree (strings are not leaves)."""
    segs = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append((kinds[i], j - i))
        i = j
    return tuple(segs)


def _stack_init(key, cfg, kinds: Tuple[str, ...]):
    """Init a (possibly heterogeneous) stack as a list of stacked segment
    pytrees (leading L axis per segment), matching segment_structure(kinds)."""
    segs = segment_structure(kinds)
    out = []
    keys = jax.random.split(key, len(kinds))
    i = 0
    for kind, count in segs:
        seg_keys = jnp.stack(keys[i:i + count])
        out.append(jax.vmap(lambda k: _init_block(k, cfg, kind))(seg_keys))
        i += count
    return out


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_emb, k_blocks, k_enc, k_head = jax.random.split(key, 4)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": dense_init(k_emb, (cfg.vocab_size, d), cfg.param_dtype,
                            scale=1.0),
        "final_norm": jnp.zeros((d,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (d, cfg.vocab_size),
                                       cfg.param_dtype)
    params["blocks"] = _stack_init(k_blocks, cfg, layer_kinds(cfg))
    if cfg.encoder_layers:
        params["enc_blocks"] = _stack_init(
            k_enc, cfg, layer_kinds(cfg, "encoder")
        )
        params["enc_norm"] = jnp.zeros((d,), cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill / encoder)
# ---------------------------------------------------------------------------

def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _run_stack(segments, seg_meta, cfg: ModelConfig, x: Array,
               positions: Array, *, causal: bool, enc_out=None,
               enc_positions=None, attn_impl: str = "xla"):
    """Scan each homogeneous segment over its stacked layers."""
    aux_total = jnp.zeros((), jnp.float32)
    policy = _remat_policy(cfg)
    for (kind, count), stacked in zip(seg_meta, segments):
        bnd_model = "model" if cfg.parallelism == "tp" else None

        def one_layer(carry, layer_params, _kind=kind):
            xc, aux = carry
            xc = shard(xc, cfg.dp_axes, None, bnd_model)
            xo, a = _apply_block(
                layer_params, cfg, _kind, xc, positions, causal=causal,
                enc_out=enc_out, enc_positions=enc_positions,
                attn_impl=attn_impl,
            )
            xo = shard(xo, cfg.dp_axes, None, bnd_model)
            return (xo, aux + a), None

        body = one_layer
        if policy is not None:
            body = jax.checkpoint(one_layer, policy=policy,
                                  prevent_cse=False, static_argnums=())
        if cfg.scan_layers and count > 1:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
        else:
            for li in range(count):
                lp = jax.tree.map(lambda a: a[li], stacked)
                (x, aux_total), _ = body((x, aux_total), lp)
    return x, aux_total


def embed_tokens(params, cfg: ModelConfig, tokens: Array) -> Array:
    e = params["embed"].astype(cfg.compute_dtype)
    return jnp.take(e, tokens, axis=0)


def forward(params, cfg: ModelConfig, *, tokens: Optional[Array] = None,
            embeds: Optional[Array] = None,
            positions: Optional[Array] = None,
            enc_embeds: Optional[Array] = None,
            attn_impl: str = "xla") -> Tuple[Array, Array]:
    """Returns (final hidden states (B,S,d), aux_loss). Decoder-causal.

    encdec: enc_embeds (stub audio frames) run through the encoder; the
    decoder cross-attends to the encoder output.
    """
    if embeds is None:
        embeds = embed_tokens(params, cfg, tokens)
    B, S, d = embeds.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = shard(embeds, cfg.dp_axes, None,
              "model" if cfg.parallelism == "tp" else None)

    enc_out = None
    enc_positions = None
    if cfg.encoder_layers:
        assert enc_embeds is not None
        Be, Se, _ = enc_embeds.shape
        enc_positions = jnp.broadcast_to(
            jnp.arange(Se, dtype=jnp.int32), (Be, Se)
        )
        enc_x = shard(enc_embeds.astype(cfg.compute_dtype), cfg.dp_axes,
                      None, "model" if cfg.parallelism == "tp" else None)
        enc_x, _ = _run_stack(
            params["enc_blocks"],
            segment_structure(layer_kinds(cfg, "encoder")),
            cfg, enc_x, enc_positions, causal=False, attn_impl=attn_impl,
        )
        enc_out = rmsnorm(enc_x, params["enc_norm"], cfg.norm_eps)

    x, aux = _run_stack(
        params["blocks"], segment_structure(layer_kinds(cfg)),
        cfg, x, positions, causal=True,
        enc_out=enc_out, enc_positions=enc_positions, attn_impl=attn_impl,
    )
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# Loss: chunked, vocab-sharded cross-entropy (+ router aux + z-loss)
# ---------------------------------------------------------------------------

def chunked_cross_entropy(h: Array, lm_head: Array, labels: Array,
                          chunk: int = 512, z_coef: float = 1e-4,
                          unroll: bool = False, dp_axes=DP,
                          vocab_axis="model"):
    """h: (B,S,d) final hiddens; lm_head: (d,V) vocab-sharded; labels (B,S).

    The (chunk, V) logits are formed per chunk in f32 and never stored
    (jax.checkpoint recomputes them in backward) — peak logits memory is
    B*chunk*V/shards instead of B*S*V/shards. The gold logit is read via a
    one-hot contraction, NOT take_along_axis: on a vocab-sharded logits
    tensor the gather would force GSPMD to all-gather the full vocab dim,
    while the one-hot product reduces locally and psums a scalar per token.
    """
    B, S, d = h.shape
    V = lm_head.shape[1]
    nchunks = S // chunk if S % chunk == 0 else 1
    if S % chunk != 0:
        chunk = S
    hs = h.reshape(B, nchunks, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, nchunks, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, policy=None, prevent_cse=False)
    def one(carry, hl):
        hc, lc = hl
        logits = (hc.astype(jnp.float32) @ lm_head.astype(jnp.float32))
        logits = shard(logits, dp_axes, None, vocab_axis)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, V, dtype=jnp.float32)
        onehot = shard(onehot, dp_axes, None, vocab_axis)
        gold = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - gold).sum()
        zl = (lse ** 2).sum()
        return (carry[0] + nll, carry[1] + zl), None

    if unroll:
        nll = jnp.zeros(())
        zl = jnp.zeros(())
        for c in range(nchunks):
            (nll, zl), _ = one((nll, zl), (hs[c], ls[c]))
    else:
        (nll, zl), _ = jax.lax.scan(
            one, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    ntok = B * S
    return nll / ntok + z_coef * zl / ntok


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array],
            attn_impl: str = "xla") -> Tuple[Array, Dict[str, Array]]:
    h, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"),
        attn_impl=attn_impl,
    )
    lm_head = params["lm_head"] if "lm_head" in params \
        else params["embed"].T
    ce = chunked_cross_entropy(
        h, lm_head, batch["labels"],
        chunk=2048 if cfg.unroll_inner else 512,
        unroll=cfg.unroll_inner, dp_axes=cfg.dp_axes,
        vocab_axis="model" if cfg.parallelism == "tp" else None)
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}
