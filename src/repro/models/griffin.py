"""Griffin / RecurrentGemma (arXiv:2402.19427) — RG-LRU + local attention.

Recurrent block: gated branch (GeLU) x (conv1d width-4 -> RG-LRU) -> out proj.
RG-LRU:  r_t = sigmoid(W_a x_t); i_t = sigmoid(W_i x_t)
         a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
The sequence form uses jax.lax.associative_scan (parallel prefix over the
affine maps h -> a h + b) — O(log S) depth on TPU; decode is a single affine
step, O(1) state (+ width-4 conv tail, + 2048-token local-attn window), which
is what makes the long_500k cell runnable for this family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array
LRU_C = 8.0


def init_recurrent_block(key, cfg: ModelConfig):
    d, lw = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "w_gate": dense_init(ks[0], (d, lw), dt),
        "w_x": dense_init(ks[1], (d, lw), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, lw), dt, scale=0.5),
        "conv_b": jnp.zeros((lw,), dt),
        "lru_lambda": jnp.ones((lw,), dt) * 0.7,   # softplus -> a ~ decay
        "w_a": dense_init(ks[3], (lw, lw), dt),
        "w_i": dense_init(ks[4], (lw, lw), dt),
        "w_out": dense_init(ks[5], (lw, d), dt),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Array | None = None):
    """Depthwise causal conv, width W. x: (B,S,lw); w: (W,lw).
    tail: (B, W-1, lw) previous context (decode) or None (zeros)."""
    B, S, lw = x.shape
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, W - 1, lw), x.dtype)
    # caches store the tail in f32; keep the conv in compute dtype
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, S+W-1, lw)
    out = sum(
        xp[:, i:i + S, :] * w[i][None, None, :] for i in range(W)
    ) + b[None, None, :]
    return out, xp[:, -(W - 1):, :]


def rg_lru(p, x: Array, h0: Array | None = None):
    """x: (B,S,lw) conv output. Returns (y, h_last). f32 scan math."""
    B, S, lw = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lru_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a0 = jnp.ones((B, 1, lw), jnp.float32)
        aa = jnp.concatenate([a0, a], axis=1)
        bb = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated], axis=1)
    else:
        aa, bb = a, gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (aa, bb), axis=1)
    h = Bc if h0 is None else Bc[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def recurrent_block(p, cfg: ModelConfig, x: Array,
                    state: Tuple[Array, Array] | None = None):
    """x: (B,S,d). state = (h_lru (B,lw), conv_tail (B,W-1,lw)) or None.
    Returns (out, new_state)."""
    cdt = cfg.compute_dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(cdt))
    u = x @ p["w_x"].astype(cdt)
    h0, tail = state if state is not None else (None, None)
    u, new_tail = _causal_conv(u, p["conv_w"].astype(cdt),
                               p["conv_b"].astype(cdt), tail)
    y, h_last = rg_lru(p, u, h0)
    out = (gate * y) @ p["w_out"].astype(cdt)
    # states are carried in f32 across steps (cache dtype), output in cdt
    return out, (h_last.astype(jnp.float32), new_tail.astype(jnp.float32))


def recurrent_block_step(p, cfg: ModelConfig, x: Array,
                         state: Tuple[Array, Array]):
    """One-token decode. x: (B, d); state as above with conv tail (B,W-1,lw)."""
    out, new_state = recurrent_block(p, cfg, x[:, None, :], state)
    return out[:, 0], new_state
