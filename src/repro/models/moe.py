"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is SORT-BASED (gather/scatter), not one-hot-einsum: tokens are
ordered by destination expert with a stable argsort, assigned a rank within
their expert queue, and dropped beyond capacity C = T*k/E * capacity_factor.
Expert compute is then a dense (E, C, d) batched matmul over gathered rows.

Why not the GShard one-hot einsum: (a) the (T, E, C) dispatch tensor is
O(T^2)-ish at 4k x 256 shapes, and (b) XLA's cost model counts the one-hot
contraction as real FLOPs, poisoning the roofline analysis this framework
reports. Gathers/scatters are data movement; the counted FLOPs are exactly
the active-expert matmuls (6*N_active*D accounting stays honest).

Sharding: tokens on ('pod','data'), experts on 'model' (EP). The baseline
path leaves resharding to GSPMD via sharding constraints; the explicit
all-to-all shard_map EP path is the §Perf hillclimb variant (see
repro/models/moe_a2a.py).

Covers olmoe-1b-7b (64e top-8) and arctic-480b (128e top-2 + dense residual).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp
from repro.sharding.util import shard

Array = jax.Array


def init_moe(key, cfg: ModelConfig):
    d, ffm, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, k1, k2, k3, kd = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, E), jnp.float32),  # router kept f32
        "we1": dense_init(k1, (E, d, ffm), cfg.param_dtype),
        "we3": dense_init(k2, (E, d, ffm), cfg.param_dtype),
        "we2": dense_init(k3, (E, ffm, d), cfg.param_dtype),
    }
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(kd, d, cfg.d_ff, cfg.param_dtype)
    return p


def route_topk(logits: Array, k: int) -> Tuple[Array, Array, Array]:
    """(T, E) router logits -> (weights (T,k), experts (T,k), aux loss)."""
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * <f_e, p_e>.
    me = jnp.mean(probs, axis=0)
    fe = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    fe = fe / jnp.maximum(jnp.sum(fe), 1.0)
    aux = E * jnp.sum(me * fe)
    return topw, topi, aux


def moe_ffn(params, cfg: ModelConfig, x: Array, *,
            capacity_override: Optional[int] = None) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss). Sort-based capacity dispatch."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)
    cdt = cfg.compute_dtype

    logits = xt.astype(jnp.float32) @ params["router"]          # (T, E)
    topw, topi, aux = route_topk(logits, k)

    C = capacity_override or int(max(1, round(T * k / E * cfg.capacity_factor)))

    # ---- sort by expert, rank within expert, drop beyond capacity ----
    e_flat = topi.reshape(-1)                                   # (T*k,)
    w_flat = topw.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)                    # token-priority
    e_s, w_s, t_s = e_flat[order], w_flat[order], t_flat[order]
    counts = jnp.bincount(e_s, length=E)
    starts = jnp.cumsum(counts) - counts                        # exclusive
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[e_s]
    keep = rank < C
    # Over-capacity entries are routed to the out-of-range slot E*C and
    # silently dropped by mode="drop" (never clobber a kept slot).
    slot = jnp.where(keep, e_s * C + rank, E * C)               # (T*k,)

    # (E*C,) gather grid; sentinel row T => zero input, scatter no-op target.
    grid_tok = jnp.full((E * C,), T, jnp.int32).at[slot].set(t_s, mode="drop")
    grid_w = jnp.zeros((E * C,), jnp.float32).at[slot].set(w_s, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = xt_pad[grid_tok].reshape(E, C, d)               # gather
    expert_in = shard(expert_in, "model", None, None)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, params["we1"].astype(cdt))
    ) * jnp.einsum("ecd,edf->ecf", expert_in, params["we3"].astype(cdt))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["we2"].astype(cdt))
    expert_out = expert_out.reshape(E * C, d) * grid_w[:, None].astype(cdt)

    out = jnp.zeros((T + 1, d), cdt).at[grid_tok].add(expert_out)[:T]

    if cfg.moe_dense_residual:
        out = out + mlp(params["dense"], xt, cdt)
    return out.reshape(B, S, d), aux.astype(jnp.float32)


def moe_ffn_dense_ref(params, cfg: ModelConfig, x: Array) -> Array:
    """No-capacity dense reference (every token gets its exact top-k mix);
    used by tests to validate the dispatch path with a large capacity."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    cdt = cfg.compute_dtype
    logits = xt.astype(jnp.float32) @ params["router"]
    topw, topi, _ = route_topk(logits, k)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["we1"].astype(cdt))) \
        * jnp.einsum("td,edf->tef", xt, params["we3"].astype(cdt))
    every = jnp.einsum("tef,efd->ted", h, params["we2"].astype(cdt))  # (T,E,d)
    w_full = jnp.zeros((xt.shape[0], E), jnp.float32)
    w_full = jax.vmap(lambda w, i, row: row.at[i].add(w))(
        topw, topi, w_full
    )
    out = jnp.einsum("te,ted->td", w_full.astype(cdt), every)
    if cfg.moe_dense_residual:
        out = out + mlp(params["dense"], xt, cdt)
    return out.reshape(B, S, d)
