"""Optimizers: AdamW and Adafactor, as pure pytree transforms.

No optax dependency — state pytrees are plain dicts so checkpointing and
ZeRO-1 sharding (repro.sharding.specs.zero1_spec) stay trivial. Params are
f32 (compute casts to bf16 at use); grads arrive f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _cosine_lr(lr, step, warmup, total):
    # (step+1)/warmup: never a dead zero-lr first step
    warm = jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return lr * warm * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog)))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000

    def init(self, params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def schedule(self, step):
        return _cosine_lr(self.lr, step, self.warmup_steps, self.total_steps)

    def update(self, grads, state, params, step):
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32) if hasattr(step, "astype") \
            else float(step + 1)
        gs, treedef = jax.tree.flatten(grads)
        ms = treedef.flatten_up_to(state["m"])
        vs = treedef.flatten_up_to(state["v"])
        ps = treedef.flatten_up_to(params)
        new_p, new_m, new_v = [], [], []
        for g, m, v, p in zip(gs, ms, vs, ps):
            gf = g.astype(jnp.float32)
            m1 = self.b1 * m + (1 - self.b1) * gf
            v1 = self.b2 * v + (1 - self.b2) * gf * gf
            mh = m1 / (1 - self.b1 ** t)
            vh = v1 / (1 - self.b2 ** t)
            upd = mh / (jnp.sqrt(vh) + self.eps)
            p1 = p.astype(jnp.float32) * (1 - lr * self.weight_decay) - lr * upd
            new_p.append(p1.astype(p.dtype))
            new_m.append(m1)
            new_v.append(v1)
        return (jax.tree.unflatten(treedef, new_p),
                {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v)})


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second moment (Shazeer & Stern 2018), no first moment —
    the memory plan for arctic-480b (DESIGN.md §6): O(rows+cols) state per
    matrix instead of O(rows*cols)."""

    lr: float = 1e-3
    decay: float = 0.8          # beta2_t = 1 - t^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000

    def init(self, params):
        def per_leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"f": jax.tree.map(per_leaf, params)}

    def schedule(self, step):
        return self.lr * jnp.minimum(
            (step + 1.0) / max(self.warmup_steps, 1), 1.0)

    def update(self, grads, state, params, step):
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32) if hasattr(step, "astype") \
            else float(step + 1)
        beta2 = 1.0 - t ** (-self.decay)
        gs, treedef = jax.tree.flatten(grads)
        fs = treedef.flatten_up_to(state["f"])
        ps = treedef.flatten_up_to(params)
        new_p, new_f = [], []
        for g, f, p in zip(gs, fs, ps):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if p.ndim >= 2:
                vr = beta2 * f["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * f["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                v_est = (vr[..., :, None] * vc[..., None, :]) \
                    / denom[..., None]
                u = gf / jnp.sqrt(v_est + self.eps)
                f1 = {"vr": vr, "vc": vc}
            else:
                v = beta2 * f["v"] + (1 - beta2) * g2
                u = gf / jnp.sqrt(v + self.eps)
                f1 = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_f.append(f1)
        return (jax.tree.unflatten(treedef, new_p),
                {"f": jax.tree.unflatten(treedef, new_f)})


def make_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(name)
