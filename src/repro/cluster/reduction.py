"""The per-iteration reduction object and the tree it flows up.

What one worker ships per iteration (paper Alg. 2 line 6, plus the
stopping rule's same-pass reductions — DESIGN.md §8):

  * three n-vectors    d = D_i^T(y' - lam'), w = D_i^T(y' - y),
                       v = D_i^T lam'
  * five scalars       r_sq, dx_sq, y_sq, obj (Boyd residual/tolerance
                       inputs + telemetry) and the covered row count

— NOTHING m-sized. That is the entire point of transpose reduction: a
consensus/data-parallel scheme would move O(m_i) per worker per round.

Tree reduce: workers form a ``fanout``-ary heap over the membership
order; each node merges its children's contributions into its own and
ships ONE partial up, so the coordinator receives a single message per
iteration and no link carries more than one contribution — the shape
that scales past the coordinator's ingress at large N. The topology
carries an ``epoch``: membership changes bump it, and every in-flight
contribution is tagged so partials from a dead topology are discarded
instead of double-counted.

Compression composes per HOP: each worker quantizes the partial it
transmits (its own + dequantized children) with
:mod:`repro.cluster.compress`; error feedback on the d-component is
per-sender, so each hop's rounding bias re-enters that hop's next
transmission and vanishes over iterations (w/v are stopping-rule-only
and quantized stateless).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster import compress

SCALARS = ("r_sq", "dx_sq", "y_sq", "obj")


@dataclasses.dataclass
class Contribution:
    """One (partial) reduction: a worker's own, or a merged subtree's."""

    iteration: int
    workers: Tuple[int, ...]            # who is folded in (subtree)
    rows: int                           # logical rows covered
    d: np.ndarray                       # (n,) f32
    w: np.ndarray
    v: np.ndarray
    scalars: Dict[str, float]

    def merge(self, other: "Contribution") -> "Contribution":
        assert self.iteration == other.iteration, \
            f"merging iterations {self.iteration} != {other.iteration}"
        return Contribution(
            iteration=self.iteration,
            workers=tuple(sorted(self.workers + other.workers)),
            rows=self.rows + other.rows,
            d=self.d + other.d, w=self.w + other.w, v=self.v + other.v,
            scalars={k: self.scalars[k] + other.scalars[k]
                     for k in SCALARS})

    @classmethod
    def zero(cls, iteration: int, n: int) -> "Contribution":
        z = np.zeros((n,), np.float32)
        return cls(iteration=iteration, workers=(), rows=0,
                   d=z, w=z.copy(), v=z.copy(),
                   scalars={k: 0.0 for k in SCALARS})


def encode(c: Contribution, compressed: bool,
           ef_err: Optional[np.ndarray] = None
           ) -> Tuple[dict, Optional[np.ndarray]]:
    """Wire payload for one hop. ``compressed`` quantizes all three
    n-vectors to int8 (+ per-group scales); ``ef_err`` is the sender's
    error-feedback residual for d (returned updated — the caller owns
    it across iterations). Returns (payload, new_ef_err)."""
    n = int(c.d.shape[0])
    head = {"iteration": c.iteration, "workers": c.workers,
            "rows": c.rows, "n": n, "scalars": c.scalars,
            "compressed": compressed}
    # the three vectors travel PACKED as one array each way: per-array
    # pickle framing (~150 B) would otherwise rival the payload at
    # small n and hide the n-vs-m story the byte counters exist to tell
    if not compressed:
        head["dwv"] = np.stack(
            [np.asarray(c.d, np.float32), np.asarray(c.w, np.float32),
             np.asarray(c.v, np.float32)])
        return head, ef_err
    if ef_err is None:
        ef_err = np.zeros((n,), np.float32)
    qd, sd, new_err = (np.asarray(a) for a in
                       compress.ef_compress(c.d, ef_err))
    qw, sw = (np.asarray(a) for a in compress.quantize_int8(c.w))
    qv, sv = (np.asarray(a) for a in compress.quantize_int8(c.v))
    head["q"] = np.stack([qd, qw, qv])
    head["s"] = np.stack([sd, sw, sv])
    return head, new_err


def decode(payload: dict) -> Contribution:
    """Inverse of :func:`encode`, with strict shape validation: a frame
    that unpickles but carries a malformed contribution (chaos-corrupted
    or truncated) must surface as ``ValueError`` here — which receivers
    treat like a dead link — never as a silently wrong reduction."""
    try:
        n = int(payload["n"])
        iteration = int(payload["iteration"])
        rows = int(payload["rows"])
        workers = tuple(int(w) for w in payload["workers"])
        scalars = {k: float(payload["scalars"][k]) for k in SCALARS}
        if payload["compressed"]:
            q, s = payload["q"], payload["s"]
            if q.shape[0] != 3 or s.shape[0] != 3:
                raise ValueError(f"bad q/s stack {q.shape}/{s.shape}")
            d, w, v = (np.asarray(compress.dequantize_int8(q[i], s[i], n))
                       for i in range(3))
        else:
            dwv = np.asarray(payload["dwv"], np.float32)
            if dwv.shape != (3, n):
                raise ValueError(f"bad dwv shape {dwv.shape} for n={n}")
            d, w, v = dwv
        if d.shape != (n,) or rows < 0 or iteration < 0:
            raise ValueError("inconsistent contribution fields")
    except ValueError:
        raise
    except Exception as e:
        raise ValueError(f"malformed contribution payload: {e}") from e
    return Contribution(iteration=iteration, workers=workers,
                        rows=rows, d=d, w=w, v=v, scalars=scalars)


@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """``fanout``-ary heap over the (sorted) live worker ids.

    position(wid) follows membership order; parent(pos) = (pos-1)//f.
    The root's parent is the coordinator. Deterministic from the member
    list, so coordinator and workers never need to exchange the full
    tree — each worker is told only its parent address and child count.
    """

    order: Tuple[int, ...]
    fanout: int = 2
    epoch: int = 0

    @classmethod
    def build(cls, worker_ids: Sequence[int], fanout: int = 2,
              epoch: int = 0) -> "TreeTopology":
        assert fanout >= 1
        return cls(order=tuple(sorted(worker_ids)), fanout=fanout,
                   epoch=epoch)

    @property
    def root(self) -> int:
        return self.order[0]

    def parent(self, wid: int) -> Optional[int]:
        pos = self.order.index(wid)
        if pos == 0:
            return None                  # root reports to the coordinator
        return self.order[(pos - 1) // self.fanout]

    def children(self, wid: int) -> List[int]:
        pos = self.order.index(wid)
        lo = self.fanout * pos + 1
        return [self.order[i]
                for i in range(lo, min(lo + self.fanout, len(self.order)))]

    def depth(self) -> int:
        """Hops from the deepest leaf to the coordinator (>= 1)."""
        d, pos = 1, len(self.order) - 1
        while pos > 0:
            pos = (pos - 1) // self.fanout
            d += 1
        return d
