"""The cluster coordinator — the paper's "central server", productionized.

Drives a fault-tolerant unwrapped-ADMM solve over worker PROCESSES
(DESIGN.md §11). Per iteration the coordinator does exactly what Alg. 2
assigns the central node: solve the cached-Gram system for x from the
summed n-vector reduction d, broadcast x, wait for the next reduction.
Everything m-sized stays at the workers; the coordinator's working set
is O(n^2) (the factor) + O(n) per iteration + the x-history it keeps
for recovery.

Fault tolerance (strict mode): worker death is detected by link EOF
(one socket read after a SIGKILL) or heartbeat age. Recovery marks the
worker dead, spreads its orphaned blocks over the least-loaded
survivors (store fingerprints verify content at the new owner), ships
the x-history so the new owner REPLAYS the fused body to reconstruct
the orphans' iterates exactly, bumps the topology epoch, and re-issues
the in-flight iteration — survivors answer the retry from their cached
per-block contributions, so a retry costs one pass over the orphaned
blocks only. The solve then continues to the same answer as an
undisturbed run.

Bounded staleness (``staleness S > 0``): star topology; the coordinator
proceeds once a quorum of workers has contributed at the current
iteration AND no live worker lags more than S iterations; missing
workers are represented by their latest cached reduction, and a late
arrival REPLACES its stale cache entry — coordinator-side error
feedback: the stale estimate's error is corrected the moment the true
reduction lands, rather than lost. Inexact per-iteration reductions of
this kind are exactly what consensus-ADMM theory tolerates (Chang et
al. 2014), and the transpose reduction is partition-insensitive (Wu et
al. 2024), which is what makes elastic membership sound here.

Checkpoint/resume: every ``checkpoint_every`` iterations the
coordinator gathers (y, lam) slices from the workers, assembles the
full iterate, and persists (x, y, lam, d, iter) through
``repro.checkpoint.manager.CheckpointManager``; ``resume=True``
restores the newest step and continues. The gathered state also
becomes the recovery base, truncating the replayed x-history.
"""
from __future__ import annotations

import dataclasses
import queue
import shutil
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cluster import compress
from repro.cluster.chaos import ChaosSchedule, FaultInjector
from repro.cluster.membership import DeadCluster, Membership, WorkerInfo
from repro.cluster.reduction import Contribution, TreeTopology, decode
from repro.cluster.transport import (
    ByteCounter,
    ConnectionClosed,
    Listener,
)
from repro.cluster.worker import make_loss, worker_entry
from repro.obs import Observability
from repro.obs.metrics import (
    merged_histogram,
    snapshot_counters,
    snapshot_histograms,
    summarize_histogram,
)

REDUCTION_TAGS = ("contrib",)            # what counts as reduction wire
BROADCAST_TAGS = ("iter",)


class ClusterError(RuntimeError):
    pass


@dataclasses.dataclass
class DegradePolicy:
    """Graceful degradation instead of an indefinite hang (DESIGN.md §13).

    ``iter_deadline_s`` bounds how long one iteration may wait for its
    reduction. On expiry the coordinator first RETRIES (strict mode:
    reset the accumulator and re-broadcast — survivors answer from their
    cached contributions, so a lost/dropped message costs one cheap
    round trip; staleness mode: relax the quorum to ``min_quorum`` and
    the bound to ``max_staleness`` for that round). After
    ``deadline_retries`` fruitless extensions — or when deaths shrink
    the live set below ``min_quorum`` of the spawned workers — the solve
    STOPS and returns the best-so-far x with ``status="degraded"``
    rather than hanging forever. Without a policy the previous behavior
    (wait indefinitely, raise on total death) is unchanged."""

    iter_deadline_s: float = 60.0
    deadline_retries: int = 2
    min_quorum: float = 0.25
    max_staleness: int = 8

    def __post_init__(self):
        if not 0.0 < self.min_quorum <= 1.0:
            raise ValueError(
                f"min_quorum must be in (0, 1], got {self.min_quorum}")
        if self.iter_deadline_s <= 0:
            raise ValueError("iter_deadline_s must be positive")
        if self.deadline_retries < 0 or self.max_staleness < 0:
            raise ValueError("retries/staleness must be >= 0")


@dataclasses.dataclass
class ClusterConfig:
    """Runtime shape. ``staleness == 0`` is the strict mode: tree reduce,
    every block in every iteration, retries on failure. ``staleness =
    S > 0`` switches to star + quorum with the bound S."""

    n_workers: int = 2
    compress: bool = False
    fanout: int = 2
    staleness: int = 0
    quorum: float = 1.0
    heartbeat_interval_s: float = 0.5
    heartbeat_timeout_s: float = 15.0
    register_timeout_s: float = 180.0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume: bool = False
    backend: str = "auto"
    limit_threads: bool = True
    jax_platforms: Optional[str] = None
    obs_dir: Optional[str] = None        # observability run directory:
                                         # trace.json / metrics.json /
                                         # telemetry.jsonl (DESIGN.md §12)
    worker_overrides: Dict[int, dict] = dataclasses.field(
        default_factory=dict)
    port: int = 0                        # fixed listen port (0 = OS pick);
                                         # a relaunched coordinator reuses
                                         # the old port so workers find it
    spawn: bool = True                   # False: adopt re-registering
                                         # workers instead of spawning
                                         # (the coordinator-relaunch path)
    degrade: Optional[DegradePolicy] = None
    chaos: Optional[object] = None       # ChaosSchedule or its spec string
    reconnect: Optional[dict] = None     # worker self-heal knobs shipped
                                         # in every worker config, e.g.
                                         # {"retries": 8, "backoff_s": 0.3}

    def __post_init__(self):
        if self.staleness > 0 and self.checkpoint_every > 0:
            # a checkpoint needs every block at ONE iteration; quorum
            # mode holds workers at mixed iterations by design, so the
            # gather would skip every round — refuse loudly instead of
            # silently never writing a checkpoint the user relies on
            raise ValueError(
                "checkpointing requires the strict synchronous mode "
                "(staleness=0): bounded-staleness iterates are never "
                "at a single consistent iteration")
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1], got {self.quorum}")
        if isinstance(self.chaos, str):
            self.chaos = ChaosSchedule.parse(self.chaos)
        if not self.spawn and self.n_workers < 1:
            raise ValueError("spawn=False still needs n_workers >= 1 "
                             "expected re-registrations")


@dataclasses.dataclass
class ClusterResult:
    x: np.ndarray
    iters: int
    converged: bool
    history: Optional[dict]              # objective/primal_res/dual_res lists
    telemetry: dict
    status: str = "ok"                   # converged | max_iters | degraded


class ClusterCoordinator:
    def __init__(self, store_path: str, loss: dict, tau: float = 1.0,
                 rho: float = 0.0, eps_rel: float = 1e-3,
                 eps_abs: float = 1e-6,
                 config: Optional[ClusterConfig] = None):
        from repro.data.store import ShardedMatrixStore

        self.cfg = config or ClusterConfig()
        self.store_path = store_path
        self.store = ShardedMatrixStore.open(store_path)
        self.loss_spec = dict(loss)
        self.loss = make_loss(self.loss_spec)
        # reductions travel as FLAT f32 vectors; multi-column iterates
        # (ycols=K) ravel to n*K on the wire (repro.exec.cluster)
        self._red_n = self.store.n * getattr(self.loss, "ycols", 1)
        self.tau, self.rho = float(tau), float(rho)
        self.eps_rel, self.eps_abs = float(eps_rel), float(eps_abs)
        self.members = Membership()
        # the coordinator's wire accounting lives in the obs registry
        # (ByteCounter is registry-backed), so metrics.json and the
        # legacy telemetry counters come from one source of truth
        self.obs = Observability(dir=self.cfg.obs_dir,
                                 process_name="coordinator")
        self.counter = ByteCounter(registry=self.obs.registry)
        self.listener = Listener(port=self.cfg.port)
        self._events: "queue.Queue" = queue.Queue()
        self._epoch = 0
        self._topology: Optional[TreeTopology] = None
        self._started = False
        self._stats = None
        # recovery base: iterates at _base_iter (None = zeros) + x since
        self._base_iter = 0
        self._base_y: Optional[np.ndarray] = None
        self._base_lam: Optional[np.ndarray] = None
        self._x_hist: List[np.ndarray] = []   # [i] -> x of iter _base+i+1
        self._latest: Dict[int, Contribution] = {}   # staleness cache
        self._iters_run = 0
        self._retries = 0
        self._shutdown_result: Optional[dict] = None
        # elasticity / chaos / degradation state (DESIGN.md §13)
        self._procs: Dict[int, object] = {}        # every spawned Process
        self._pending_joins: List[Tuple[int, dict]] = []
        self._join_t0: Dict[int, float] = {}       # wid -> register time
        self._joins = 0
        self._accept_stop = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self._recovery_log: List[dict] = []        # closed events
        self._open_recovery: List[dict] = []       # awaiting next collect
        self._degraded_rounds = 0
        self._status = "ok"
        self._crashed = False
        sched: Optional[ChaosSchedule] = self.cfg.chaos
        self._chaos_spec = sched.to_spec() if sched is not None else None
        self._chaos_joins = list(sched.for_kind("join")) if sched else []
        inj_events = sched.for_target("coord") if sched else ()
        self._coord_injector = (FaultInjector(inj_events)
                                if inj_events else None)

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.shutdown()

    def _worker_config(self, wid: int) -> dict:
        cfg = {"store_path": self.store_path, "loss": self.loss_spec,
               "tau": self.tau, "backend": self.cfg.backend,
               "compress": self.cfg.compress,
               "staleness": self.cfg.staleness > 0,
               "heartbeat_interval": self.cfg.heartbeat_interval_s,
               "limit_threads": self.cfg.limit_threads,
               "jax_platforms": self.cfg.jax_platforms,
               "obs": bool(self.cfg.obs_dir),
               "chaos": self._chaos_spec,
               "reconnect": self.cfg.reconnect}
        cfg.update(self.cfg.worker_overrides.get(wid, {}))
        return cfg

    def spawn_worker(self, wid: Optional[int] = None) -> int:
        """Launch one worker process against this coordinator's port —
        used at startup, by scheduled chaos ``join`` events, and by
        anything else that wants to grow the cluster mid-solve. The
        worker registers itself; the register lands in the event queue
        and (mid-solve) becomes a pending join."""
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        if wid is None:
            taken = set(self._procs) | set(self.members.workers)
            wid = max(taken, default=-1) + 1
        host, port = self.listener.address
        p = ctx.Process(target=worker_entry,
                        args=(wid, host, port, self._worker_config(wid)),
                        daemon=True)
        p.start()
        self._procs[wid] = p
        return wid

    def start(self):
        """Spawn workers (or, with ``spawn=False``, wait for the old
        ones to re-register), collect registrations, assign blocks."""
        if self._started:
            return
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()
        if self.cfg.spawn:
            for wid in range(self.cfg.n_workers):
                self.spawn_worker(wid)
        try:
            self._await_registrations()
        except BaseException:
            # a failed start must not leak spawned processes into a
            # long-lived host (daemon=True only reaps at interpreter
            # exit) — __exit__ never runs when __enter__ raises
            for p in self._procs.values():
                if p.is_alive():
                    p.terminate()
            self._accept_stop.set()
            self.listener.close()
            raise
        plan = self.members.initial_assignment(self.store.nblocks)
        for wid, blocks in plan.items():
            self._send_assign(wid, blocks, upto_iter=self._base_iter)
        self._broadcast_topology()
        self._started = True

    def _accept_loop(self):
        """Persistent accept thread: reads each new connection's first
        frame (the registration) and posts it into the event queue. This
        is what makes joins possible MID-solve — registration is no
        longer a startup-only phase."""
        while not self._accept_stop.is_set():
            try:
                conn = self.listener.accept(timeout=0.5,
                                            counter=self.counter)
            except OSError:
                return                   # listener closed: shutdown/crash
            if conn is None:
                continue
            try:
                msg = conn.recv(timeout=30.0)
            except ConnectionClosed:
                conn.close()
                continue
            if msg is None or msg.get("type") != "register":
                conn.close()
                continue
            msg["_conn"] = conn
            self._events.put((int(msg["wid"]), msg))

    def _admit(self, wid: int, msg, strict: bool = True) -> bool:
        """Turn a register message into a live member + receiver thread.
        ``strict`` raises on a store-fingerprint mismatch (startup);
        mid-solve joins reject the bad joiner instead of killing a
        healthy solve."""
        conn = msg["_conn"]
        if msg["store_fingerprint"] != self.store.fingerprint:
            if strict:
                raise ClusterError(
                    f"worker {wid} opened a store with fingerprint "
                    f"{msg['store_fingerprint'][:12]}… != coordinator's "
                    f"{self.store.fingerprint[:12]}…")
            conn.close()
            return False
        old = self.members.workers.get(wid)
        if old is not None and old.alive:
            # a rejoining wid the failure detector has not retired yet:
            # retire the stale incarnation first (its blocks respread)
            self._mark_and_recover([wid], None, None)
        info = WorkerInfo(wid=wid, conn=conn,
                          peer_addr=tuple(msg["peer_addr"]),
                          process=self._procs.get(wid))
        if self._coord_injector is not None:
            conn.chaos = self._coord_injector
        self.members.add(info)
        threading.Thread(target=self._rx, args=(wid, conn),
                         daemon=True).start()
        return True

    def _await_registrations(self):
        expected = self.cfg.n_workers
        deadline = time.monotonic() + self.cfg.register_timeout_s
        while len(self.members.workers) < expected:
            dead_early = [w for w, p in self._procs.items()
                          if not p.is_alive()
                          and w not in self.members.workers]
            if dead_early:
                raise ClusterError(
                    f"workers {dead_early} exited before registering "
                    "(exitcodes "
                    f"{[self._procs[w].exitcode for w in dead_early]}); if "
                    "launching from a script, guard the entry point "
                    "with `if __name__ == '__main__':` — the spawn "
                    "start method re-imports __main__")
            if time.monotonic() > deadline:
                raise ClusterError(
                    f"only {len(self.members.workers)} of "
                    f"{expected} workers registered in "
                    f"{self.cfg.register_timeout_s:.0f}s")
            try:
                wid, msg = self._events.get(timeout=1.0)
            except queue.Empty:
                continue
            if msg is None or msg.get("type") != "register":
                continue                 # stale obituary pre-membership
            self._admit(int(msg["wid"]), msg, strict=True)

    def shutdown(self) -> dict:
        """Stop workers, fold their byte counters in, reap processes.
        Returns the aggregate counter snapshot. Idempotent."""
        if self._shutdown_result is not None:
            return self._shutdown_result
        worker_counters = ByteCounter()
        alive = self.members.alive()
        for w in alive:
            try:
                w.conn.send("stop")
            except ConnectionClosed:
                w.alive = False
        waiting = {w.wid for w in alive if w.alive}
        deadline = time.monotonic() + 10.0
        while waiting and time.monotonic() < deadline:
            try:
                wid, msg = self._events.get(timeout=0.5)
            except queue.Empty:
                continue
            if msg is None:
                waiting.discard(wid)
            elif msg.get("type") == "bye":
                worker_counters.merge(msg["counters"])
                w = self.members.workers.get(wid)
                if w is not None and msg.get("metrics") is not None:
                    w.metrics = msg["metrics"]
                if self.obs.enabled:
                    # fold the worker's registry (relabelled so series
                    # stay per-worker) and its trace events, so the run
                    # directory renders the whole cluster as ONE
                    # metrics.json + one Perfetto timeline
                    if msg.get("metrics") is not None:
                        self.obs.registry.merge(
                            msg["metrics"],
                            extra_labels={"worker": str(wid)})
                    if msg.get("trace"):
                        self.obs.tracer.add_events(
                            msg["trace"],
                            process_name=f"worker-{wid}",
                            pid=msg.get("pid"))
                waiting.discard(wid)
        self._accept_stop.set()
        for w in self.members.workers.values():
            if w.conn is not None:
                w.conn.close()
        for p in self._procs.values():
            if p is None:
                continue
            p.join(timeout=5.0)
            if p.is_alive():
                p.terminate()            # SIGTERM first...
                p.join(timeout=2.0)
            if p.is_alive():
                # ...but a SIGSTOPped worker holds SIGTERM pending
                # forever; SIGKILL is the only reaper that works on a
                # stopped process
                p.kill()
                p.join(timeout=2.0)
        self.listener.close()
        self._started = False
        self._shutdown_result = {"coordinator": self.counter.snapshot(),
                                 "workers": worker_counters.snapshot()}
        self.obs.finish()
        return self._shutdown_result

    def crash(self):
        """Abandon the cluster WITHOUT the shutdown handshake — the
        test harness's stand-in for a coordinator process dying. Every
        link drops (workers with ``reconnect`` configured start dialing
        the port back); worker processes are left running and tracked so
        a relaunched coordinator on the same port can adopt them (pass
        the handles via ``adopt_processes``)."""
        self._crashed = True
        self._accept_stop.set()
        self.listener.close()
        for w in self.members.workers.values():
            if w.conn is not None:
                try:
                    w.conn.close()
                except OSError:
                    pass
        self._started = False
        self._shutdown_result = {"coordinator": self.counter.snapshot(),
                                 "workers": {}}

    def adopt_processes(self, procs: Dict[int, object]):
        """Give a relaunched coordinator the previous incarnation's
        process handles so its shutdown can reap them."""
        for wid, p in procs.items():
            self._procs.setdefault(wid, p)

    # -- plumbing -----------------------------------------------------------
    def _rx(self, wid: int, conn):
        try:
            while True:
                self._events.put((wid, conn.recv()))
        except ConnectionClosed:
            self._events.put((wid, None))

    def _send(self, wid: int, msg_type: str, **payload) -> bool:
        w = self.members.get(wid)
        try:
            w.conn.send(msg_type, **payload)
            return True
        except ConnectionClosed:
            self._events.put((wid, None))
            return False

    def _send_assign(self, wid: int, blocks: List[int], upto_iter: int,
                     force: bool = False):
        """Ship ownership of ``blocks``: recovery base slices (if any)
        plus the x-history needed to replay up to ``upto_iter``.
        ``force`` overwrites iterates the worker already holds (the
        resume path)."""
        base_state = None
        if self._base_y is not None:
            base_state = {}
            for bid in blocks:
                sl = self.store.block_slice(bid)
                base_state[bid] = (self._base_y[sl].copy(),
                                   self._base_lam[sl].copy())
        hist = self._x_hist[: max(0, upto_iter - self._base_iter)]
        self._send(wid, "assign", blocks=list(blocks),
                   base_iter=self._base_iter, base_state=base_state,
                   force=force,
                   x_history=(np.stack(hist) if hist else
                              np.zeros((0, self.store.n), np.float32)))

    def _broadcast_topology(self):
        wids = self.members.alive_ids()
        if self.cfg.staleness > 0:
            self._topology = None        # star: everyone reports directly
            for wid in wids:
                self._send(wid, "topology", epoch=self._epoch, parent=None,
                           nchildren=0)
            return
        topo = TreeTopology.build(wids, fanout=self.cfg.fanout,
                                  epoch=self._epoch)
        self._topology = topo
        for wid in wids:
            parent = topo.parent(wid)
            self._send(wid, "topology", epoch=self._epoch,
                       parent=(self.members.get(parent).peer_addr
                               if parent is not None else None),
                       nchildren=len(topo.children(wid)))

    def _broadcast_iter(self, k: int, x: np.ndarray):
        for wid in self.members.alive_ids():
            self._send(wid, "iter", k=k, x=np.asarray(x, np.float32),
                       epoch=self._epoch)

    # -- failure handling ---------------------------------------------------
    def _mark_and_recover(self, dead_wids, current_iter: Optional[int],
                          x_k: Optional[np.ndarray]):
        # duplicate death events are routine (EOF from the receiver
        # thread AND a failed send both post one): only newly-dead wids
        # trigger recovery, or every duplicate would cost an epoch bump
        # and an iteration retry
        newly = [wid for wid in dead_wids
                 if (w := self.members.workers.get(wid)) is not None
                 and w.alive]
        if not newly:
            return
        orphans = set()
        for wid in newly:
            w = self.members.workers[wid]
            if w.conn is not None:
                # sever the link: a live-but-retired worker (blown
                # deadline, zombie incarnation) sees its sends fail and
                # — with reconnect configured — comes back as a join
                try:
                    w.conn.close()
                except OSError:
                    pass
            orphans |= self.members.mark_dead(wid)
        self._open_recovery.append({
            "kind": "death", "wids": list(newly),
            "iter": current_iter, "blocks_moved": len(orphans),
            "t0": time.monotonic()})
        plan = self.members.reassignment_plan(sorted(orphans))
        # replay target: the state BEFORE the in-flight iteration — the
        # retry (strict) or the next broadcast (staleness) advances the
        # orphans onward from there
        upto = (current_iter - 1) if current_iter is not None else (
            self._base_iter + len(self._x_hist))
        for wid, blocks in plan.items():
            self._send_assign(wid, blocks, upto_iter=upto)
        if self.cfg.staleness > 0:
            for wid in newly:
                self._latest.pop(wid, None)
            return                       # star: epoch stays, late msgs fold
        self._epoch += 1
        self._broadcast_topology()
        if current_iter is not None:
            self._retries += 1
            self._broadcast_iter(current_iter, x_k)

    # -- elastic membership -------------------------------------------------
    def _spawn_due_joins(self, k: int):
        """Fire scheduled chaos ``join`` events whose iteration is due:
        spawn the worker process now; its registration arrives whenever
        process + jax startup completes and is applied at a later
        iteration boundary by :meth:`_apply_joins`."""
        due = [e for e in self._chaos_joins if e.iteration <= k]
        for e in due:
            self._chaos_joins.remove(e)
            wid = int(e.target.lstrip("w")) if e.target.startswith("w") \
                else None
            self.spawn_worker(wid)

    def _apply_joins(self):
        """Fold pending registrations into the membership at an
        iteration boundary: admit, level block load off the most-loaded
        survivors (``Membership.rebalance_plan``), ship the base state +
        x-history so joiners replay to the last COMPLETED iteration, and
        rebuild the topology under a new epoch — the same machinery the
        death path uses, pointed the other way."""
        if not self._pending_joins:
            return
        joins, self._pending_joins = self._pending_joins, []
        admitted = []
        for wid, msg in joins:
            if self._admit(wid, msg, strict=False):
                admitted.append(wid)
        if not admitted:
            return
        upto = self._base_iter + len(self._x_hist)   # last completed iter
        gains, losses = self.members.rebalance_plan()
        moved = 0
        for wid in set(gains) | set(losses):
            g = set(gains.get(wid, ()))
            l = set(losses.get(wid, ()))
            net_loss = sorted(l - g)
            if net_loss:
                self._send(wid, "unassign", blocks=net_loss)
            net_gain = sorted(g - l)
            if net_gain:
                self._send_assign(wid, net_gain, upto_iter=upto)
                moved += len(net_gain)
        if self.cfg.staleness > 0:
            # donors' cached reductions still cover their OLD blocks;
            # merging them alongside the joiner's fresh ones would
            # double-count the moved rows — everyone touched must
            # contribute fresh before being counted again
            for wid in set(gains) | set(losses):
                self._latest.pop(wid, None)
        self._joins += len(admitted)
        self._epoch += 1
        self._broadcast_topology()
        now = time.monotonic()
        for wid in admitted:
            t0 = self._join_t0.pop(wid, now)
            self._open_recovery.append({
                "kind": "join", "wid": wid, "iter": upto,
                "blocks_moved": moved, "t0": t0,
                "register_to_assign_s": round(now - t0, 3)})

    def _close_recovery(self, k: int):
        """A collect for iteration k completed with full coverage — any
        open death/join recovery is now proven healed; stamp durations
        into the log (the benchmark's time-to-recover / join-to-
        contributing metrics)."""
        if not self._open_recovery:
            return
        now = time.monotonic()
        for e in self._open_recovery:
            e["recovered_at_iter"] = k
            e["recover_s"] = round(now - e.pop("t0"), 3)
            self._recovery_log.append(e)
        self._open_recovery = []

    def _poll_failures(self) -> List[int]:
        """Heartbeat-age check. MUST run on every wait-loop pass, not
        only when the event queue idles: live workers heartbeat every
        interval, so a busy queue would otherwise starve the check and
        a HUNG (not dead) worker — open link, no EOF — would never be
        declared dead."""
        return self.members.stale(self.cfg.heartbeat_timeout_s)

    def _handle_common(self, wid: int, msg) -> Optional[Tuple[int, dict]]:
        """Events any wait-loop must absorb; returns the message back
        when the caller should interpret it."""
        if msg is None:
            return (wid, None)           # death, caller recovers
        t = msg.get("type")
        if t == "heartbeat":
            self.members.beat(wid)
            w = self.members.workers.get(wid)
            if w is not None and msg.get("metrics") is not None:
                w.metrics = msg["metrics"]
            return None
        if t == "error":
            raise ClusterError(
                f"worker {wid} failed:\n{msg['traceback']}")
        if t == "register":
            # a mid-solve join (fresh worker or a self-healed one
            # re-registering): queue it — membership only changes at
            # iteration boundaries, where the epoch bump is safe
            self._pending_joins.append((wid, msg))
            self._join_t0.setdefault(wid, time.monotonic())
            return None
        if t in ("assigned", "unassigned", "bye"):
            return None
        return (wid, msg)

    # -- setup reduction: sufficient stats ----------------------------------
    def stats(self):
        """Merged :class:`SufficientStats` over all blocks — the setup
        all-reduce of Alg. 2 lines 2-3 (and the WHOLE solve for
        quadratic-data-term fits, paper §4). The merged fingerprint must
        equal the store's, proving every block was folded exactly once
        across whatever membership survived."""
        from repro.service.stats import SufficientStats
        if self._stats is not None:
            return self._stats
        if not self._started:
            self.start()
        pending: Dict[int, List[int]] = {}
        for w in self.members.alive():
            blocks = sorted(w.blocks)
            pending[w.wid] = blocks
            self._send(w.wid, "stats", blocks=blocks)
        merged = SufficientStats.zero(self.store.n)
        folded: set = set()
        while len(folded) < self.store.nblocks:
            dead = self._poll_failures()
            if dead:
                self._stats_recover(dead, pending, folded)
            try:
                wid, msg = self._events.get(
                    timeout=self.cfg.heartbeat_interval_s)
            except queue.Empty:
                continue
            ev = self._handle_common(wid, msg)
            if ev is None:
                continue
            wid, msg = ev
            if msg is None:
                self._stats_recover([wid], pending, folded)
                continue
            if msg.get("type") != "stats":
                continue
            blocks = set(msg["blocks"])
            if blocks & folded:
                continue                 # re-request already covered
            merged = merged.merge(SufficientStats.from_payload(msg))
            folded |= blocks
            # drop only the ANSWERED blocks: a re-requested orphan may
            # still be outstanding at this worker, and forgetting it
            # would strand the block if this worker dies too
            left = [b for b in pending.get(wid, []) if b not in folded]
            if left:
                pending[wid] = left
            else:
                pending.pop(wid, None)
        if merged.fingerprint != self.store.fingerprint:
            raise ClusterError(
                "merged stats fingerprint != store fingerprint: some "
                "block was folded zero or twice across the membership")
        self._stats = merged
        return merged

    def _stats_recover(self, dead, pending, folded):
        self._mark_and_recover(dead, None, None)
        for wid in dead:
            lost = [b for b in pending.pop(wid, []) if b not in folded]
            for bid in lost:
                owner = self.members.owner_of(bid)
                pending.setdefault(owner, []).append(bid)
                self._send(owner, "stats", blocks=[bid])

    # -- the solve ----------------------------------------------------------
    def solve(self, max_iters: int = 500, record: bool = True,
              x0: Optional[np.ndarray] = None,
              reg=None) -> ClusterResult:
        """Run the solve through the shared executor driver
        (DESIGN.md §14): the coordinator contributes the three cluster
        primitives via :class:`repro.exec.ClusterExecutor`; the stopping
        rule, warm start, checkpoint cadence and history all live in
        ``repro.exec.base.solve_with_executor`` — the same code path the
        local, streaming and shard_map topologies run."""
        from repro.exec import ClusterExecutor, solve_with_executor

        if self._iters_run:
            # worker iterates persist across calls but d/x/history here
            # restart from zero — a second solve would silently diverge
            # from any single-process run. One coordinator, one solve.
            raise ClusterError(
                "this coordinator already ran a solve; create a new "
                "ClusterCoordinator (or use checkpoint_dir + resume "
                "to continue a solve across runs)")
        if not self._started:
            self.start()
        ex = ClusterExecutor(self)
        t0 = time.monotonic()
        res = solve_with_executor(
            ex, loss=self.loss, tau=self.tau, rho=self.rho,
            eps_rel=self.eps_rel, eps_abs=self.eps_abs,
            max_iters=max_iters, x0=x0, record=record, reg=reg,
            checkpoint_dir=self.cfg.checkpoint_dir,
            checkpoint_every=self.cfg.checkpoint_every,
            resume=self.cfg.resume, obs=self.obs)
        k = int(res.iters)
        history = None
        if record and res.history is not None:
            history = {
                "objective": [float(v) for v in res.history.objective],
                "primal_res": [float(v) for v in res.history.primal_res],
                "dual_res": [float(v) for v in res.history.dual_res]}
        return ClusterResult(x=np.asarray(res.x, np.float32), iters=k,
                             converged=ex.converged, history=history,
                             telemetry=self._telemetry(
                                 k - ex.resume_iter,
                                 time.monotonic() - t0),
                             status=self._status)

    def _below_min_quorum(self) -> bool:
        pol = self.cfg.degrade
        if pol is None:
            return False
        floor = max(1, int(np.ceil(pol.min_quorum * self.cfg.n_workers)))
        return len(self.members.alive()) < floor

    # -- collection: strict (tree) ------------------------------------------
    def _collect_strict(self, k: int, x_k: np.ndarray
                        ) -> Optional[Contribution]:
        """Wait for full coverage of iteration k at the current epoch;
        recover + retry on any death. In tree mode that is ONE message
        (the root's merged partial) per attempt. With a
        :class:`DegradePolicy`, a blown per-iteration deadline first
        RETRIES (reset + re-broadcast: recovers dropped/corrupted
        messages for one cheap cached-answer round trip) and then gives
        up — returning None, which the solve loop reports as
        ``degraded`` — instead of waiting forever."""
        pol = self.cfg.degrade
        deadline = (time.monotonic() + pol.iter_deadline_s
                    if pol is not None else None)
        rebroadcasts = 0
        acc = Contribution.zero(k, self._red_n)
        seen: set = set()
        while True:
            if deadline is not None and time.monotonic() > deadline:
                if rebroadcasts >= pol.deadline_retries:
                    return None
                rebroadcasts += 1
                self._retries += 1
                self._recovery_log.append({
                    "kind": "deadline_retry", "iter": k,
                    "attempt": rebroadcasts})
                acc = Contribution.zero(k, self._red_n)
                seen = set()
                deadline = time.monotonic() + pol.iter_deadline_s
                self._broadcast_iter(k, x_k)
            try:
                dead = self._poll_failures()
                if dead:
                    acc = Contribution.zero(k, self._red_n)
                    seen = set()
                    self._mark_and_recover(dead, k, x_k)
                if self._below_min_quorum():
                    return None
                try:
                    wid, msg = self._events.get(
                        timeout=self.cfg.heartbeat_interval_s)
                except queue.Empty:
                    continue
                ev = self._handle_common(wid, msg)
                if ev is None:
                    continue
                wid, msg = ev
                if msg is None:
                    acc = Contribution.zero(k, self._red_n)
                    seen = set()
                    self._mark_and_recover([wid], k, x_k)
                    continue
            except DeadCluster:
                if pol is not None:
                    return None          # degraded beats an exception
                raise
            if msg.get("type") != "contrib":
                continue
            if msg["epoch"] != self._epoch:
                continue                 # partial of a dead topology
            try:
                c = decode(msg["payload"])
            except ValueError:
                continue                 # malformed: the retry recovers it
            if c.iteration != k or set(c.workers) & seen:
                continue
            self.members.beat(wid)
            acc = acc.merge(c)
            seen |= set(c.workers)
            if acc.rows >= self.store.m:
                assert acc.rows == self.store.m, \
                    f"row overcount: {acc.rows} > {self.store.m}"
                return acc

    # -- collection: bounded staleness (star) -------------------------------
    def _collect_stale(self, k: int) -> Optional[Contribution]:
        """Proceed once >= quorum of live workers contributed at k and
        nobody lags more than ``staleness``; absent workers are
        represented by their newest cached reduction (replaced — not
        lost — when the late message lands). With a
        :class:`DegradePolicy`, a blown deadline RELAXES the round to
        (min_quorum, max_staleness) — counting only workers that have
        contributed at all — and a second blown deadline returns None
        (degraded)."""
        S, q = self.cfg.staleness, self.cfg.quorum
        pol = self.cfg.degrade
        deadline = (time.monotonic() + pol.iter_deadline_s
                    if pol is not None else None)
        relaxed = False
        while True:
            alive = self.members.alive_ids()
            haves = [w for w in alive if self._latest.get(w) is not None]
            fresh = sum(1 for w in haves
                        if self._latest[w].iteration == k)
            oldest = min((self._latest[w].iteration for w in haves),
                         default=0)
            if relaxed:
                # degraded round: merge whoever has EVER contributed,
                # provided a min_quorum of them is fresh and none of
                # them is older than the widened bound
                satisfied = (haves
                             and fresh >= max(1, int(np.ceil(
                                 pol.min_quorum * len(alive))))
                             and oldest >= k - pol.max_staleness)
                merge_over = haves
            else:
                satisfied = (len(haves) == len(alive)
                             and fresh >= max(1, int(np.ceil(
                                 q * len(alive))))
                             and oldest >= k - S)
                merge_over = alive
            if satisfied:
                if relaxed:
                    self._degraded_rounds += 1
                acc = Contribution.zero(k, self._red_n)
                for w in merge_over:
                    # stale entries merge AS IF current — the (bounded)
                    # inexactness the mode accepts by construction
                    acc = acc.merge(dataclasses.replace(
                        self._latest[w], iteration=k))
                return acc
            if deadline is not None and time.monotonic() > deadline:
                if relaxed:
                    return None
                relaxed = True
                self._recovery_log.append({
                    "kind": "quorum_relax", "iter": k,
                    "min_quorum": pol.min_quorum,
                    "max_staleness": pol.max_staleness})
                deadline = time.monotonic() + pol.iter_deadline_s
                continue
            try:
                dead = self._poll_failures()
                if dead:
                    self._mark_and_recover(dead, k, None)
                if self._below_min_quorum():
                    return None
            except DeadCluster:
                if pol is not None:
                    return None
                raise
            try:
                wid, msg = self._events.get(
                    timeout=self.cfg.heartbeat_interval_s)
            except queue.Empty:
                continue
            ev = self._handle_common(wid, msg)
            if ev is None:
                continue
            wid, msg = ev
            if msg is None:
                try:
                    self._mark_and_recover([wid], k, None)
                except DeadCluster:
                    if pol is not None:
                        return None
                    raise
                continue
            if msg.get("type") != "contrib":
                continue
            try:
                c = decode(msg["payload"])
            except ValueError:
                continue
            w = c.workers[0]
            prev = self._latest.get(w)
            if prev is None or c.iteration > prev.iteration:
                self._latest[w] = c
                self.members.get(w).last_iteration = c.iteration
                self.members.beat(w)

    # -- checkpoint / resume ------------------------------------------------
    def _gather_iterates(self, k: int
                         ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Assemble full (y, lam) from worker slices; None if membership
        changed mid-gather (caller skips this checkpoint round)."""
        for wid in self.members.alive_ids():
            if not self._send(wid, "checkpoint"):
                return None
        ycols = getattr(self.loss, "ycols", 1)
        shape = ((self.store.m,) if ycols == 1
                 else (self.store.m, ycols))
        y = np.zeros(shape, np.float32)
        lam = np.zeros(shape, np.float32)
        covered: set = set()
        deadline = time.monotonic() + self.cfg.heartbeat_timeout_s
        while covered != set(range(self.store.nblocks)):
            if time.monotonic() > deadline:
                return None
            try:
                wid, msg = self._events.get(timeout=0.5)
            except queue.Empty:
                continue
            ev = self._handle_common(wid, msg)
            if ev is None:
                continue
            wid, msg = ev
            if msg is None:
                self._mark_and_recover([wid], None, None)
                return None
            if msg.get("type") != "ckpt":
                continue
            for bid, (y_b, lam_b, b_iter) in msg["blocks"].items():
                if b_iter != k:
                    return None          # raced a retry; skip this round
                sl = self.store.block_slice(int(bid))
                y[sl], lam[sl] = y_b, lam_b
                covered.add(int(bid))
        return y, lam

    # -- telemetry ----------------------------------------------------------
    def _pad_objective(self) -> float:
        # one pad-row objective contract for the streaming AND cluster
        # drivers (engine.streaming.store_pad_objective)
        from repro.engine.streaming import store_pad_objective
        return store_pad_objective(self.store, self.loss)

    def _per_worker_telemetry(self) -> dict:
        """Per-worker timing breakdown from the newest registry snapshot
        each worker shipped (heartbeat or bye): iteration counts and
        wall time, block-step latency percentiles, replay/retry work."""
        out: Dict[str, dict] = {}
        for w in self.members.workers.values():
            snap = w.metrics
            if snap is None:
                continue
            iter_h = merged_histogram(
                snapshot_histograms(snap, "worker.iter_s"))
            steps = merged_histogram(
                snapshot_histograms(snap, "worker.block_step_s"))
            out[str(w.wid)] = {
                "alive": w.alive,
                "iters": int(snapshot_counters(snap, "worker.iters")),
                "iter_wall_s": round(iter_h.sum, 6),
                "block_step_ms": summarize_histogram(
                    steps.to_snapshot(), scale=1e3),
                "replayed_steps": int(
                    snapshot_counters(snap, "worker.replayed_steps")),
                "retry_cached_answers": int(snapshot_counters(
                    snap, "worker.retry_cached_answers")),
            }
        return out

    def _telemetry(self, iters: int, wall_s: float) -> dict:
        n = self.store.n
        coord = self.counter.snapshot()
        reduction_rx = sum(coord["received_bytes"].get(t, 0)
                           for t in REDUCTION_TAGS)
        bcast_tx = sum(coord["sent_bytes"].get(t, 0)
                       for t in BROADCAST_TAGS)
        deaths_rec = [e for e in self._recovery_log
                      if e["kind"] == "death"]
        joins_rec = [e for e in self._recovery_log if e["kind"] == "join"]
        return {
            "workers_spawned": self.cfg.n_workers,
            "workers_alive": len(self.members.alive()),
            "deaths": list(self.members.deaths),
            "blocks_reassigned": self.members.reassignments,
            "iteration_retries": self._retries,
            "status": self._status,
            "joins": self._joins,
            "blocks_rebalanced": self.members.rebalances,
            "degraded_rounds": self._degraded_rounds,
            "chaos_spec": self._chaos_spec,
            "chaos_seed": (self.cfg.chaos.seed
                           if self.cfg.chaos is not None else None),
            "recovery": {
                "events": list(self._recovery_log),
                "time_to_recover_s": (
                    round(max(e["recover_s"] for e in deaths_rec), 3)
                    if deaths_rec else None),
                "iterations_retried": self._retries,
                "join_to_contributing_s": (
                    round(max(e["recover_s"] for e in joins_rec), 3)
                    if joins_rec else None),
            },
            "iters": iters,
            "wall_s": round(wall_s, 3),
            "epoch": self._epoch,
            "tree_depth": (self._topology.depth()
                           if self._topology else 1),
            "coordinator_reduction_rx_bytes": reduction_rx,
            "coordinator_broadcast_tx_bytes": bcast_tx,
            "reduction_rx_bytes_per_iter": (
                round(reduction_rx / iters, 1) if iters else 0.0),
            "payload_bytes_per_nvec": compress.wire_bytes(
                n, self.cfg.compress),
            "payload_bytes_per_nvec_uncompressed": compress.wire_bytes(
                n, False),
            "counters": coord,
            "per_worker": self._per_worker_telemetry(),
        }


# ---------------------------------------------------------------------------
# convenience drivers (launch/fit.py, benchmarks, tests)
# ---------------------------------------------------------------------------

def _ensure_store(D, aux, store_dir: Optional[str], n_workers: int,
                  block_rows: Optional[int] = None) -> Tuple[str, bool]:
    """Stage host arrays (or pass through an existing store dir).
    Returns (path, created): ``created`` stores are the convenience
    drivers' to delete after shutdown — a dataset-sized temp directory
    must not outlive the solve."""
    from repro.data.store import ShardedMatrixStore
    if isinstance(D, str):
        return D, False
    created = store_dir is None
    if created:
        store_dir = tempfile.mkdtemp(prefix="cluster_store_")
    D = np.asarray(D)
    if D.ndim == 3:
        D = D.reshape(-1, D.shape[-1])
    if block_rows is None:
        # >= 2 blocks per worker so a death has something to spread
        block_rows = max(1, -(-D.shape[0] // (2 * max(n_workers, 1))))
    store = ShardedMatrixStore.from_arrays(
        D, None if aux is None else np.asarray(aux).reshape(-1),
        block_rows=block_rows)
    store.save(store_dir)
    return store_dir, created


def cluster_solve(D, aux, loss: dict, tau: float, rho: float = 0.0,
                  max_iters: int = 300, store_dir: Optional[str] = None,
                  config: Optional[ClusterConfig] = None,
                  block_rows: Optional[int] = None,
                  eps_rel: float = 1e-3, eps_abs: float = 1e-6,
                  record: bool = True, x0=None, reg=None) -> ClusterResult:
    """One-call multi-process solve: stage the store, run the cluster,
    tear it down. ``D`` may be host arrays or a saved store path."""
    config = config or ClusterConfig()
    path, created = _ensure_store(D, aux, store_dir, config.n_workers,
                                  block_rows)
    try:
        with ClusterCoordinator(path, loss, tau=tau, rho=rho,
                                eps_rel=eps_rel, eps_abs=eps_abs,
                                config=config) as coord:
            res = coord.solve(max_iters=max_iters, record=record,
                              x0=x0, reg=reg)
            res.telemetry["shutdown_counters"] = coord.shutdown()
            # bye messages carry each worker's FINAL registry snapshot;
            # refresh the breakdown solve() built from (periodic, hence
            # lagging) heartbeats
            res.telemetry["per_worker"] = coord._per_worker_telemetry()
        return res
    finally:
        if created:
            shutil.rmtree(path, ignore_errors=True)


def cluster_stats(D, aux, store_dir: Optional[str] = None,
                  config: Optional[ClusterConfig] = None,
                  block_rows: Optional[int] = None):
    """Distributed sufficient-stats ingest (the paper-§4 regression
    path: lasso/ridge solves never iterate over the cluster — one
    stats reduction, then the coordinator solves locally)."""
    config = config or ClusterConfig()
    path, created = _ensure_store(D, aux, store_dir, config.n_workers,
                                  block_rows)
    try:
        with ClusterCoordinator(path, {"name": "least_squares"},
                                config=config) as coord:
            st = coord.stats()
            telemetry = coord.shutdown()
        return st, telemetry
    finally:
        if created:
            shutil.rmtree(path, ignore_errors=True)
