"""Deterministic fault injection for the cluster runtime (DESIGN.md §13).

The paper's deployment story is thousands of commodity cores, where
workers joining, dying, hanging, and flaking mid-solve is the steady
state.  This module generates a *seeded, reproducible* schedule of such
faults and injects them at exact (iteration, target) points, so every
recovery path in the coordinator/worker runtime can be exercised by a
test that fails the same way twice.

Three layers:

  * ``FaultEvent`` — one scheduled fault: ``kind @ iteration : target``
    with an optional numeric parameter (milliseconds for delay/slow).
  * ``ChaosSchedule`` — an immutable, sorted collection of events with a
    compact string form (``kill@13:w2,join@20:w4,delay@5:w1:50``) that
    round-trips through ``parse``/``to_spec`` and a seeded ``generate``
    (same seed → byte-identical schedule).  The schedule is shipped to
    workers as its spec string; each side slices out its own target.
  * ``FaultInjector`` — consumes one target's slice.  Disabled injectors
    follow the ``obs`` no-op pattern: a single attribute check and an
    empty tuple, nothing else, so production paths pay nothing.

Fault taxonomy (see DESIGN.md §13 for the recovery each one exercises):

  wire    delay / drop / dup / corrupt / reset — applied inside
          ``Connection.send`` for data-plane frames (contrib, iter).
  process kill (SIGKILL) / stop (SIGSTOP, a hang that still owns the
          socket) / slow (sleep before the block step) — applied by the
          worker when it receives the scheduled iteration's broadcast.
  cluster join — consumed by the coordinator: spawn a fresh worker
          process at the scheduled iteration boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

WIRE_KINDS = ("delay", "drop", "dup", "corrupt", "reset")
PROCESS_KINDS = ("kill", "stop", "slow")
CLUSTER_KINDS = ("join",)
KINDS = WIRE_KINDS + PROCESS_KINDS + CLUSTER_KINDS

# wire faults only touch data-plane frames; control traffic (register,
# heartbeats, topology, shutdown) stays clean so a "dropped contribution"
# cannot masquerade as a dead worker at the transport level
DATA_PLANE = ("contrib", "iter")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled fault: ``kind`` fires at ``iteration`` on ``target``
    (``"w<wid>"`` or ``"coord"``).  ``param`` is milliseconds for
    delay/slow and ignored elsewhere."""
    iteration: int
    target: str
    kind: str
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.iteration < 0:
            raise ValueError("fault iteration must be >= 0")

    def to_token(self) -> str:
        tok = f"{self.kind}@{self.iteration}:{self.target}"
        if self.param:
            tok += f":{self.param:g}"
        return tok

    @classmethod
    def from_token(cls, token: str) -> "FaultEvent":
        try:
            kind, rest = token.strip().split("@", 1)
            parts = rest.split(":")
            iteration, target = int(parts[0]), parts[1]
            param = float(parts[2]) if len(parts) > 2 else 0.0
        except (ValueError, IndexError) as e:
            raise ValueError(f"bad fault token {token!r} "
                             "(want kind@iter:target[:param])") from e
        return cls(iteration=iteration, target=target, kind=kind,
                   param=param)


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A sorted, immutable fault schedule with a recorded seed."""
    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(sorted(self.events)))

    # -- serialization ------------------------------------------------
    def to_spec(self) -> str:
        return ",".join(e.to_token() for e in self.events)

    @classmethod
    def parse(cls, spec: str, seed: Optional[int] = None) -> "ChaosSchedule":
        tokens = [t for t in str(spec).split(",") if t.strip()]
        return cls(events=tuple(FaultEvent.from_token(t) for t in tokens),
                   seed=seed)

    # -- seeded generation --------------------------------------------
    @classmethod
    def generate(cls, seed: int, n_workers: int, iters: int, *,
                 kills: int = 1, stops: int = 1, joins: int = 1,
                 delays: int = 2, drops: int = 1, dups: int = 0,
                 corrupts: int = 0, resets: int = 0,
                 delay_ms: Tuple[float, float] = (10.0, 120.0),
                 ) -> "ChaosSchedule":
        """Deterministic schedule: same arguments → identical events.

        Kill/stop victims are distinct workers so the schedule cannot
        fault a process twice; at least one original worker survives.
        Joins spawn fresh wids above ``n_workers``.  Wire faults land on
        any original worker.  Iterations are placed in the middle of the
        solve so detection + recovery complete inside it.
        """
        if kills + stops >= n_workers:
            raise ValueError("kill+stop victims must leave a survivor")
        if iters < 8:
            raise ValueError("need >= 8 iterations to schedule recovery")
        rng = np.random.default_rng(seed)
        victims = [int(w) for w in rng.permutation(n_workers)]
        lo, hi = 2, max(3, iters - 5)
        events: List[FaultEvent] = []

        def it():
            return int(rng.integers(lo, hi))

        for _ in range(kills):
            events.append(FaultEvent(it(), f"w{victims.pop(0)}", "kill"))
        for _ in range(stops):
            events.append(FaultEvent(it(), f"w{victims.pop(0)}", "stop"))
        for j in range(joins):
            # join early enough that process spawn + registration lands
            # inside the solve even on a loaded single-core host
            events.append(FaultEvent(int(rng.integers(1, max(2, iters // 4))),
                                     f"w{n_workers + j}", "join"))
        for kind, count in (("delay", delays), ("drop", drops),
                            ("dup", dups), ("corrupt", corrupts),
                            ("reset", resets)):
            for _ in range(count):
                w = int(rng.integers(0, n_workers))
                # quantize so the schedule round-trips exactly through
                # to_spec()/parse() (the %g token keeps 6 significant
                # digits; whole milliseconds are plenty for a delay)
                param = (float(round(rng.uniform(*delay_ms), 1))
                         if kind == "delay" else 0.0)
                events.append(FaultEvent(it(), f"w{w}", kind, param))
        return cls(events=tuple(events), seed=int(seed))

    # -- slicing ------------------------------------------------------
    def for_target(self, target: str) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.target == target)

    def for_kind(self, *kinds: str) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind in kinds)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out


class FaultInjector:
    """Consumes one target's slice of a schedule.

    Mirrors the ``obs`` no-op pattern: when disabled every hook is a
    single attribute check returning an empty tuple, so the runtime can
    call the hooks unconditionally.  Each event fires exactly once
    (process faults fire at the first iteration >= their schedule point,
    wire faults only at the exact iteration, so a fault aimed at a
    window the target never saw does not detonate arbitrarily later).

    ``data_plane`` names the frame types wire faults may touch.  The
    cluster default is :data:`DATA_PLANE` (contrib/iter); the fit
    service front end passes its own tags (fit/fit_result) so the SAME
    injector and schedule grammar drive service-connection chaos while
    control frames stay clean in both runtimes.
    """

    __slots__ = ("enabled", "_events", "_fired", "_iteration",
                 "_data_plane")

    def __init__(self, events: Iterable[FaultEvent] = (),
                 enabled: Optional[bool] = None,
                 data_plane: Sequence[str] = DATA_PLANE):
        self._events = tuple(sorted(events))
        self.enabled = (bool(self._events) if enabled is None
                        else bool(enabled))
        self._fired: set = set()
        self._iteration = -1
        self._data_plane = tuple(data_plane)

    def set_iteration(self, k: int) -> None:
        self._iteration = int(k)

    def process_actions(self, k: int) -> Tuple[Tuple[str, float], ...]:
        """(kind, param_ms) process faults due at iteration ``k``."""
        if not self.enabled:
            return ()
        self._iteration = int(k)
        out = []
        for i, e in enumerate(self._events):
            if (i not in self._fired and e.kind in PROCESS_KINDS
                    and e.iteration <= k):
                self._fired.add(i)
                out.append((e.kind, e.param))
        return tuple(out)

    def on_send(self, msg_type: str) -> Tuple[Tuple[str, float], ...]:
        """(kind, param_ms) wire faults for a frame being sent now."""
        if not self.enabled:
            return ()
        if msg_type not in self._data_plane:
            return ()
        out = []
        for i, e in enumerate(self._events):
            if (i not in self._fired and e.kind in WIRE_KINDS
                    and e.iteration == self._iteration):
                self._fired.add(i)
                out.append((e.kind, e.param))
        return tuple(out)

    def corrupt(self, frame: bytes) -> bytes:
        """Deterministically mangle a frame body.  The first bytes are
        the pickle protocol header — flipping them guarantees the
        receiver's decode fails (detected corruption) rather than
        silently altering array payload."""
        b = bytearray(frame)
        for off in (0, 1, len(b) // 2):
            if off < len(b):
                b[off] ^= 0xFF
        return bytes(b)

    def pending(self) -> Tuple[FaultEvent, ...]:
        return tuple(e for i, e in enumerate(self._events)
                     if i not in self._fired)


#: shared disabled injector — the no-op fast path
NOOP = FaultInjector(events=(), enabled=False)


def make_injector(spec: Optional[str], target: str,
                  data_plane: Sequence[str] = DATA_PLANE) -> FaultInjector:
    """Build a target's injector from a schedule spec string (the form
    shipped inside worker configs); ``None``/empty → the NOOP singleton."""
    if not spec:
        return NOOP
    sched = spec if isinstance(spec, ChaosSchedule) else ChaosSchedule.parse(spec)
    events = sched.for_target(target)
    return (FaultInjector(events, data_plane=data_plane) if events
            else NOOP)
