"""Socket transport for the cluster runtime — framing, counters, failure.

Stdlib-only (``socket`` + ``pickle``; no MPI, no grpc): messages are
length-prefixed pickles of ``{"type": str, ...}`` dicts whose values are
plain Python and numpy arrays. The framing is deliberately boring — the
interesting contract is the ACCOUNTING: every connection counts frame
bytes per message type, because "what crosses the wire per iteration"
is the paper's headline quantity and BENCH_cluster.json records it
(n-vector reductions vs the O(m) a consensus scheme would move).

Failure model: a peer death surfaces as EOF/ECONNRESET on ``recv``
(raised as :class:`ConnectionClosed`) — the coordinator's per-worker
receiver threads translate that into a death event, which is how a
SIGKILLed worker is detected within one read rather than one heartbeat
timeout.

Trust model: pickle over a socket means the transport must only ever be
pointed at the coordinator's own spawned workers (localhost by default).
This is a cluster runtime for a solver you launched, not a public
endpoint — do not expose the listener beyond hosts you control.
"""
from __future__ import annotations

import pickle
import random
import socket
import struct
import threading
import time
from typing import Optional, Tuple

from repro.obs.context import current_context
from repro.obs.metrics import MetricsRegistry

_LEN = struct.Struct(">Q")

# A corrupted (or hostile) 8-byte header must not drive _recv_exact into
# an arbitrary multi-GB allocation: any decoded frame length above this
# cap is treated as a desynchronized/corrupt stream and the connection
# dies. Real frames are n-vector contributions and x broadcasts — MBs at
# the very largest — so 1 GiB is generous by orders of magnitude.
MAX_FRAME_BYTES = 1 << 30

# Once the first header byte has arrived the peer is mid-send and the
# rest of the frame is read under this completion deadline rather than
# fully blocking: a peer SIGSTOPped mid-send (socket open, stream
# frozen) must not pin the receiver thread forever.
FRAME_DEADLINE_S = 120.0

# registry series the counter writes: transport.{tx,rx}_{bytes,msgs}
# labelled by message type — the wire-accounting schema every other
# registry consumer (telemetry deltas, obs_report) reads back.
_SECTIONS = (("sent_bytes", "transport.tx_bytes"),
             ("sent_msgs", "transport.tx_msgs"),
             ("received_bytes", "transport.rx_bytes"),
             ("received_msgs", "transport.rx_msgs"))


class ConnectionClosed(Exception):
    """Peer went away (EOF / reset) — the transport-level death signal."""


#: sentinel distinguishing "no per-accept override" from an explicit None
_UNSET = object()


class ByteCounter:
    """Per-message-type frame byte/count totals, backed by a
    :class:`~repro.obs.metrics.MetricsRegistry` (DESIGN.md §12): the
    transport's accounting is ordinary labelled counters, so a worker's
    wire bytes ship, merge, and report through the same snapshot schema
    as every other metric. The legacy dict shape of :meth:`snapshot` /
    :meth:`merge` (sent_bytes/sent_msgs/received_*) is preserved — it is
    the cluster telemetry and BENCH_cluster.json surface."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry or MetricsRegistry()

    def add(self, direction: str, tag: str, nbytes: int):
        d = "tx" if direction == "tx" else "rx"
        self.registry.inc(f"transport.{d}_bytes", nbytes, type=tag)
        self.registry.inc(f"transport.{d}_msgs", 1, type=tag)

    def snapshot(self) -> dict:
        return {key: {t: int(v)
                      for t, v in self.registry.labeled(name, "type").items()}
                for key, name in _SECTIONS}

    def merge(self, other: dict):
        """Fold another counter's :meth:`snapshot` into this one (the
        coordinator aggregates worker-reported counters at shutdown)."""
        for key, name in _SECTIONS:
            for tag, v in other.get(key, {}).items():
                self.registry.inc(name, v, type=tag)

    def total(self, direction: str = "tx") -> int:
        d = "tx" if direction == "tx" else "rx"
        return int(sum(
            self.registry.labeled(f"transport.{d}_bytes", "type").values()))


class Connection:
    """One framed duplex channel. ``send`` is locked (multiple threads —
    main loop + heartbeat — share the coordinator link); ``recv`` must
    stay single-threaded per connection (one receiver thread each)."""

    def __init__(self, sock: socket.socket,
                 counter: Optional[ByteCounter] = None,
                 chaos=None,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 frame_deadline_s: float = FRAME_DEADLINE_S):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass                     # non-TCP socket (tests, AF_UNIX)
        self._sock = sock
        self._send_lock = threading.Lock()
        self.counter = counter or ByteCounter()
        self.closed = False
        # optional FaultInjector (repro.cluster.chaos) consulted on send;
        # None (the default) costs one attribute check per frame
        self.chaos = chaos
        self.max_frame_bytes = max_frame_bytes
        self.frame_deadline_s = frame_deadline_s

    @property
    def peer(self) -> Tuple[str, int]:
        return self._sock.getpeername()

    def send(self, msg_type: str, **payload):
        # Trace propagation (DESIGN.md §16): when a request-scoped
        # TraceContext is active in this thread, stamp it into the frame
        # as the optional "_ctx" field. Frames are plain dicts, so peers
        # that predate the field ignore the extra key, and frames
        # without it decode exactly as before — compatible both ways.
        ctx = current_context()
        if ctx is not None and "_ctx" not in payload:
            payload["_ctx"] = ctx.to_wire()
        frame = pickle.dumps({"type": msg_type, **payload},
                             protocol=pickle.HIGHEST_PROTOCOL)
        copies = 1
        if self.chaos is not None:
            for kind, param in self.chaos.on_send(msg_type):
                if kind == "drop":
                    self.counter.add("tx", msg_type,
                                     _LEN.size + len(frame))
                    return           # vanished on the wire
                if kind == "delay":
                    time.sleep(param / 1e3)
                elif kind == "dup":
                    copies = 2
                elif kind == "corrupt":
                    frame = self.chaos.corrupt(frame)
                elif kind == "reset":
                    self.close()
                    raise ConnectionClosed("chaos: connection reset")
        header = _LEN.pack(len(frame))
        try:
            with self._send_lock:
                for _ in range(copies):
                    self._sock.sendall(header + frame)
        except OSError as e:
            self.closed = True
            raise ConnectionClosed(str(e)) from e
        self.counter.add("tx", msg_type, copies * (len(header) + len(frame)))

    def _recv_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            try:
                chunk = self._sock.recv(n - len(buf))
            except socket.timeout:
                raise
            except OSError as e:
                self.closed = True
                raise ConnectionClosed(str(e)) from e
            if not chunk:
                self.closed = True
                raise ConnectionClosed("EOF")
            buf.extend(chunk)
        return bytes(buf)

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        """Next message, or None on IDLE timeout. Raises
        ConnectionClosed on peer death. Only a timeout with ZERO bytes
        read returns None: once the first header byte has arrived the
        peer is alive and mid-send, so the rest of the frame is read
        under ``frame_deadline_s`` — a mid-header timeout must never
        drop buffered bytes and desynchronize the length-prefixed
        stream, but a peer frozen mid-send (SIGSTOP) must not pin this
        thread forever either. A blown deadline, an absurd decoded
        length, or an undecodable frame all kill the connection: once
        any of those happens the stream cannot be trusted again."""
        try:
            # settimeout itself can race a close() from another thread
            # (recovery severing a retired worker's link): that is a
            # dead connection, not a crash in the receiver thread
            self._sock.settimeout(timeout)
            first = self._recv_exact(1)
        except socket.timeout:
            return None
        except OSError as e:
            self.closed = True
            raise ConnectionClosed(str(e)) from e
        try:
            # finish the frame under a completion deadline
            self._sock.settimeout(self.frame_deadline_s)
            header = first + self._recv_exact(_LEN.size - 1)
            length = _LEN.unpack(header)[0]
            if length > self.max_frame_bytes:
                self.close()
                raise ConnectionClosed(
                    f"frame length {length} exceeds cap "
                    f"{self.max_frame_bytes} (corrupt stream)")
            frame = self._recv_exact(length)
        except socket.timeout:
            self.close()
            raise ConnectionClosed(
                f"frame stalled mid-receive for {self.frame_deadline_s}s "
                "(peer hung mid-send)") from None
        except ConnectionClosed:
            raise
        except OSError as e:                  # settimeout raced a close()
            self.closed = True
            raise ConnectionClosed(str(e)) from e
        try:
            msg = pickle.loads(frame)
            if not isinstance(msg, dict):
                raise ValueError("frame is not a message dict")
        except ConnectionClosed:
            raise
        except Exception as e:
            self.close()
            raise ConnectionClosed(f"undecodable frame: {e}") from e
        self.counter.add("rx", msg.get("type", "?"),
                         _LEN.size + len(frame))
        return msg

    def close(self):
        self.closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class Listener:
    """Bound server socket (port 0 -> OS-assigned; workers report theirs
    back to the coordinator at registration).

    ``chaos`` / ``max_frame_bytes`` / ``frame_deadline_s`` set here become
    the defaults every accepted :class:`Connection` inherits — the fit
    service front end accepts from untrusted-ish clients and needs a much
    smaller frame cap and a short frame-completion deadline (a slow-loris
    client that sends half a header and stalls must be severed, not
    allowed to pin a handler thread for the cluster default of 120 s)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 chaos=None,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 frame_deadline_s: float = FRAME_DEADLINE_S):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.address: Tuple[str, int] = self._sock.getsockname()
        self.chaos = chaos
        self.max_frame_bytes = max_frame_bytes
        self.frame_deadline_s = frame_deadline_s

    def accept(self, timeout: Optional[float] = None,
               counter: Optional[ByteCounter] = None, *,
               chaos=_UNSET,
               max_frame_bytes: Optional[int] = None,
               frame_deadline_s: Optional[float] = None
               ) -> Optional[Connection]:
        """Accept one connection; keyword overrides beat the listener
        defaults per accepted connection (``chaos=None`` explicitly
        disables injection for this connection even when the listener
        carries an injector)."""
        self._sock.settimeout(timeout)
        try:
            sock, _ = self._sock.accept()
        except socket.timeout:
            return None
        return Connection(
            sock, counter=counter,
            chaos=self.chaos if chaos is _UNSET else chaos,
            max_frame_bytes=(self.max_frame_bytes if max_frame_bytes is None
                             else max_frame_bytes),
            frame_deadline_s=(self.frame_deadline_s if frame_deadline_s is None
                             else frame_deadline_s))

    def close(self):
        self._sock.close()


def connect(address: Tuple[str, int], timeout: float = 10.0,
            counter: Optional[ByteCounter] = None, *,
            retries: int = 0, backoff_s: float = 0.5,
            backoff_max_s: float = 10.0, jitter: float = 0.25,
            chaos=None) -> Connection:
    """Dial ``address``, retrying with exponential backoff + jitter.

    ``retries`` extra attempts follow a failed dial, sleeping
    ``min(backoff_s * 2**attempt, backoff_max_s) * (1 + U[0,jitter])``
    between them — the jitter keeps a herd of workers re-registering
    against a relaunched coordinator from dialing in lockstep. The
    default ``retries=0`` preserves the old single-attempt behavior.
    Failure raises :class:`ConnectionClosed` (the caller-facing "peer
    unreachable" signal) rather than a raw ``OSError``."""
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            sock = socket.create_connection(address, timeout=timeout)
            sock.settimeout(None)
            return Connection(sock, counter=counter, chaos=chaos)
        except OSError as e:
            last = e
            if attempt == retries:
                break
            delay = min(backoff_s * (2.0 ** attempt), backoff_max_s)
            time.sleep(delay * (1.0 + jitter * random.random()))
    raise ConnectionClosed(
        f"connect to {address} failed after {retries + 1} attempt(s): "
        f"{last}") from last
