"""The cluster worker process — owns row blocks, ships n-vector reductions.

One worker = one OS process (spawned by the coordinator, or launched by
hand pointing at the coordinator's address). It:

  * opens the shared :class:`~repro.data.store.ShardedMatrixStore`
    READ-ONLY (mmap) and verifies every assigned block's content against
    the store's write-time fingerprints before touching it;
  * keeps the m_i-sized iterates (y, lam) of its blocks in HOST numpy
    buffers and runs the per-iteration body through the SAME jitted
    fused step the streaming engine uses (``engine.streaming
    .block_step_fns`` -> ``IterationEngine.iterate``) — one device-
    resident block at a time, so worker device memory is bounded by one
    block;
  * per iteration ships ONE :class:`~repro.cluster.reduction
    .Contribution` (three n-vectors + scalars) up the reduce tree —
    merging its children's partials first — optionally int8-compressed
    with per-sender error feedback;
  * heartbeats the coordinator and dies loudly (any exception is
    reported upstream as an ``error`` message before exit).

Recovery contract: a worker's iterates are a deterministic function of
(block content, x_1..x_k), so the coordinator never backs them up — an
``assign`` mid-solve carries a base state (possibly empty) plus the
x-history since, and the new owner REPLAYS the fused body over just
those blocks to reconstruct (y, lam) exactly. Per-block iteration
counters make retried broadcasts idempotent: a block already at
iteration k answers from its cached contribution instead of applying
the prox twice.

Fault injection: the legacy per-worker knobs ``die_at_iter`` (SIGKILL on
that iteration's broadcast) and ``slow_ms`` (per-iteration delay) remain,
and a ``chaos`` spec string (see :mod:`repro.cluster.chaos`) schedules
seeded kill/stop/slow process faults plus wire faults on the data plane.

Self-healing: when the coordinator link drops and ``reconnect`` is
configured, the worker does NOT exit — it discards all block state
(everything is reconstructible from the store + the coordinator's base
state and x-history), dials the coordinator with exponential backoff +
jitter, re-registers, re-verifies its assigned blocks, and rejoins the
solve. This is both halves of DESIGN.md §13's recovery loop: a worker
the coordinator force-retired (blown deadline, dropped contribution)
comes back as a mid-solve JOIN, and a relaunched coordinator finds its
old workers dialing the same port.
"""
from __future__ import annotations

import os
import queue
import signal
import threading
import time
import traceback
from typing import Dict, Optional

from repro.cluster.chaos import NOOP, make_injector
from repro.cluster.transport import (
    ByteCounter,
    Connection,
    ConnectionClosed,
    Listener,
    connect,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

_HEARTBEAT_TYPES = ("heartbeat",)


def make_loss(spec: dict):
    """ProxLoss from a picklable spec — the coordinator cannot ship the
    ProxLoss itself (closures don't pickle), so both ends build it from
    ``{"name": ..., **params}`` through the one registry-backed factory
    in :mod:`repro.core.prox` (every registered loss is cluster-capable
    with zero per-topology code)."""
    from repro.core.prox import loss_from_spec
    return loss_from_spec(spec)


def _setup_env(config: dict):
    """Thread/platform knobs BEFORE first jax backend init. Many worker
    processes timeshare the host's cores; unbounded per-process XLA/BLAS
    pools thrash, so workers default to single-threaded compute (the
    coordinator overrides via config on big hosts)."""
    if config.get("jax_platforms"):
        os.environ["JAX_PLATFORMS"] = config["jax_platforms"]
    if config.get("limit_threads", True):
        os.environ.setdefault("OMP_NUM_THREADS", "1")
        os.environ.setdefault("OPENBLAS_NUM_THREADS", "1")
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_cpu_multi_thread_eigen" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_cpu_multi_thread_eigen=false"
            ).strip()


class WorkerRuntime:
    """Single-threaded state machine over one inbox; receiver threads
    (coordinator link + one per peer connection) only enqueue."""

    def __init__(self, wid: int, coord_addr, config: dict):
        import jax  # noqa: F401  (backend init happens under _setup_env)

        from repro.data.store import ShardedMatrixStore

        self.wid = wid
        self.config = config
        # one registry backs everything the worker measures: wire bytes
        # (via ByteCounter), block-step / iteration latency histograms,
        # replay and retry counters. Heartbeats ship its snapshot; the
        # coordinator folds it per-worker (DESIGN.md §12).
        self.metrics = MetricsRegistry()
        self.counter = ByteCounter(registry=self.metrics)
        self.tracer = Tracer(enabled=bool(config.get("obs")),
                             process_name=f"worker-{wid}")
        self.store = ShardedMatrixStore.open(config["store_path"])
        self.loss = make_loss(config["loss"])
        self.tau = float(config.get("tau", 1.0))
        self.compress = bool(config.get("compress", False))
        self.staleness = bool(config.get("staleness", False))
        self._ef_err = None               # error-feedback residual for d

        from repro.engine import IterationEngine
        from repro.engine.streaming import block_step_fns

        self.engine = IterationEngine(
            loss=self.loss, tau=self.tau,
            backend=config.get("backend", "auto"))
        self._step, _, _ = block_step_fns(
            self.engine, self.store.has_aux, True,
            sparse=self.store.sparse)
        self._step_lean, _, _ = block_step_fns(
            self.engine, self.store.has_aux, False,
            sparse=self.store.sparse)

        # per-block state: padded host iterates + iteration counter +
        # cached last contribution (idempotent retries)
        self.blocks: Dict[int, dict] = {}

        self.inbox: "queue.Queue" = queue.Queue()
        self.peers = Listener()           # children connect here
        self.coord_addr = tuple(coord_addr)
        # seeded fault injection (no-op singleton when unconfigured)
        self.chaos = make_injector(config.get("chaos"), f"w{wid}")
        self._conn_chaos = self.chaos if self.chaos.enabled else None
        # reconnect knobs: {} disables (lose the coordinator -> exit);
        # retries/backoff_s/backoff_max_s feed transport.connect
        self.reconnect = dict(config.get("reconnect") or {})
        self._gen = 0                     # coordinator-link generation
        self._registrations = 0
        self._parent_conns: Dict[tuple, Connection] = {}
        self.topology = {"epoch": -1, "parent": None, "nchildren": 0}
        self._task = None                 # in-flight tree reduce
        self._peer_buf = []               # children ahead of our own iter
        self._stop = threading.Event()
        self.coord: Connection = None
        self._attach(retries=int(self.reconnect.get("retries", 3)))

    # -- coordinator link --------------------------------------------------
    def _attach(self, retries: int):
        """Dial the coordinator (with backoff), register, and start this
        link's receiver + heartbeat threads. Each attach bumps the link
        generation so a stale thread's death notice cannot tear down a
        newer link."""
        self._gen += 1
        gen = self._gen
        self.coord = connect(
            self.coord_addr, counter=self.counter, chaos=self._conn_chaos,
            retries=retries,
            backoff_s=float(self.reconnect.get("backoff_s", 0.5)),
            backoff_max_s=float(self.reconnect.get("backoff_max_s", 5.0)))
        self.coord.send("register", wid=self.wid,
                        peer_addr=self.peers.address,
                        store_fingerprint=self.store.fingerprint,
                        pid=os.getpid(),
                        rejoin=self._registrations > 0)
        self._registrations += 1
        threading.Thread(target=self._coord_rx,
                         args=(self.coord, gen), daemon=True).start()
        threading.Thread(target=self._heartbeat,
                         args=(self.coord,), daemon=True).start()

    def _reset_state(self):
        """Drop everything tied to the lost coordinator: block iterates,
        in-flight reduce, buffered peer partials, parent links. All of it
        is reconstructible from (store, base state, x-history) at the
        next assignment — keeping any of it risks folding a dead epoch's
        state into the new coordinator's solve."""
        self.blocks.clear()
        self._task = None
        self._peer_buf = []
        self._ef_err = None
        for conn in self._parent_conns.values():
            try:
                conn.close()
            except OSError:
                pass
        self._parent_conns = {}
        self.topology = {"epoch": -1, "parent": None, "nchildren": 0}
        self.metrics.inc("worker.reconnects")

    # -- threads -----------------------------------------------------------
    def _coord_rx(self, conn: Connection, gen: int):
        try:
            while not self._stop.is_set():
                msg = conn.recv()
                self.inbox.put(("cmd", msg))
        except ConnectionClosed:
            self.inbox.put(("cmd_closed", gen))

    def _peer_rx(self, conn: Connection):
        try:
            while not self._stop.is_set():
                msg = conn.recv()
                if msg.get("type") == "contrib":
                    self.inbox.put(("peer", msg))
        except ConnectionClosed:
            pass

    def _peer_accept(self):
        while not self._stop.is_set():
            conn = self.peers.accept(timeout=0.5, counter=self.counter)
            if conn is not None:
                threading.Thread(target=self._peer_rx, args=(conn,),
                                 daemon=True).start()

    def _heartbeat(self, conn: Connection):
        interval = float(self.config.get("heartbeat_interval", 0.5))
        while not self._stop.is_set():
            try:
                conn.send("heartbeat", wid=self.wid,
                          t=time.monotonic(),
                          metrics=self.metrics.snapshot())
            except ConnectionClosed:
                return                    # link died; a reattach starts
                                          # its own heartbeat thread
            self._stop.wait(interval)

    # -- block state -------------------------------------------------------
    def _init_block(self, bid: int, base_iter: int, base=None,
                    verified: bool = False):
        import numpy as np
        if not verified and not self.store.verify_block(bid):
            raise RuntimeError(
                f"worker {self.wid}: store block {bid} content does not "
                f"match its write-time fingerprint — refusing assignment")
        br = self.store.block_rows
        ycols = getattr(self.loss, "ycols", 1)
        shape = (br,) if ycols == 1 else (br, ycols)
        y = np.zeros(shape, np.float32)
        lam = np.zeros(shape, np.float32)
        if base is not None:
            y_l, lam_l = base
            y[: len(y_l)] = y_l
            lam[: len(lam_l)] = lam_l
        self.blocks[bid] = {"y": y, "lam": lam, "iter": int(base_iter),
                            "contrib": None}

    def _apply_block(self, bid: int, x_dev, k: int, want_dual: bool):
        """Advance one block's iterates by one fused step; cache its
        contribution for iteration k."""
        import jax
        import numpy as np

        from repro.cluster.reduction import Contribution
        from repro.engine.streaming import _zero_sweep

        st = self.blocks[bid]
        t0 = time.perf_counter()
        with self.tracer.span("block_step", block=bid, k=k):
            D_b, a_b = self.store.block(bid, padded=True)
            step = self._step if want_dual else self._step_lean
            acc = _zero_sweep(self.store.n, jax.numpy.float32,
                              getattr(self.loss, "ycols", 1))
            y_new, lam_new, acc = step(
                jax.device_put(np.ascontiguousarray(D_b)),
                jax.device_put(a_b) if a_b is not None else None,
                jax.device_put(st["y"]), jax.device_put(st["lam"]),
                x_dev, acc)
            st["y"] = np.asarray(y_new)
            st["lam"] = np.asarray(lam_new)
            st["iter"] = k
        self.metrics.observe("worker.block_step_s",
                             time.perf_counter() - t0)
        if want_dual:
            sl = self.store.block_slice(bid)
            # wire format: reductions travel FLAT — (n, K) ravels to
            # (n*K,) so tree merge + int8 compression stay shape-blind
            st["contrib"] = Contribution(
                iteration=k, workers=(self.wid,),
                rows=sl.stop - sl.start,
                d=np.asarray(acc.d).ravel(), w=np.asarray(acc.w).ravel(),
                v=np.asarray(acc.v).ravel(),
                scalars={"r_sq": float(acc.r_sq),
                         "dx_sq": float(acc.dx_sq),
                         "y_sq": float(acc.y_sq),
                         "obj": float(acc.obj)})

    def _replay(self, bids, x_history):
        """Reconstruct (y, lam) for newly assigned blocks: the lean body
        over just these blocks, once per historical x."""
        import jax
        import numpy as np
        with self.tracer.span("replay", blocks=len(bids),
                              steps=len(x_history)):
            for x in np.asarray(x_history, np.float32):
                x_dev = jax.device_put(x)
                for bid in bids:
                    self._apply_block(bid, x_dev,
                                      self.blocks[bid]["iter"] + 1,
                                      want_dual=False)
                self.metrics.inc("worker.replayed_steps", len(bids))

    # -- message handlers ---------------------------------------------------
    def _on_assign(self, msg):
        base_iter = int(msg.get("base_iter", 0))
        base_state = msg.get("base_state") or {}
        force = bool(msg.get("force", False))   # resume: overwrite state
        incoming = [bid for bid in msg["blocks"]
                    if force or bid not in self.blocks]
        # one batched content check so a bad assignment reports EVERY
        # mismatched block (join path: the joiner mmap-opened the store
        # cold and must prove it holds the same rows)
        bad = self.store.verify_blocks(incoming)
        if bad:
            raise RuntimeError(
                f"worker {self.wid}: store blocks {bad} do not match "
                "their write-time fingerprints — refusing assignment")
        fresh = []
        for bid in incoming:
            self._init_block(bid, base_iter, base_state.get(bid),
                             verified=True)
            fresh.append(bid)
        hist = msg.get("x_history")
        if hist is not None and len(hist) and fresh:
            self._replay(fresh, hist)
        self.coord.send("assigned", wid=self.wid, blocks=list(self.blocks),
                        at_iter={b: self.blocks[b]["iter"]
                                 for b in self.blocks})

    def _on_stats(self, msg):
        import numpy as np

        from repro.service.stats import SufficientStats
        bids = msg.get("blocks")
        if bids is None:
            bids = sorted(self.blocks)
        stats = SufficientStats.zero(self.store.n)
        for bid in bids:
            D_b, a_b = self.store.block(bid, padded=False)
            stats = stats.update(
                D_b if self.store.sparse else np.asarray(D_b),
                np.asarray(a_b) if a_b is not None else None,
                block_fingerprint=self.store.fingerprints[bid])
        self.coord.send("stats", wid=self.wid, blocks=list(bids),
                        **stats.to_payload())

    def _on_topology(self, msg):
        self.topology = {"epoch": int(msg["epoch"]),
                         "parent": (tuple(msg["parent"])
                                    if msg["parent"] else None),
                         "nchildren": int(msg["nchildren"])}
        if self._task and self._task["epoch"] < self.topology["epoch"]:
            self._task = None             # partials of a dead topology

    def _on_iter(self, msg):
        import jax
        import numpy as np

        from repro.cluster.reduction import Contribution

        k = int(msg["k"])
        if (not self.staleness
                and int(msg["epoch"]) != self.topology["epoch"]):
            # a broadcast from a topology that died before we got to it;
            # the coordinator has already re-issued this iteration under
            # the new epoch (FIFO per link makes this purely defensive)
            return
        die_at = self.config.get("die_at_iter")
        if die_at is not None and k >= int(die_at):
            os.kill(os.getpid(), 9)       # fault injection: SIGKILL
        slow = float(self.config.get("slow_ms", 0.0))
        if slow:
            time.sleep(slow / 1e3)
        for kind, param in self.chaos.process_actions(k):
            if kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)
            elif kind == "stop":
                # a hang, not a death: the process keeps its sockets but
                # stops heartbeating — only the coordinator's staleness
                # detector can retire it (and only SIGKILL can reap it)
                os.kill(os.getpid(), signal.SIGSTOP)
            elif kind == "slow":
                time.sleep(param / 1e3)
        t_iter = time.perf_counter()
        x_dev = jax.device_put(np.asarray(msg["x"], np.float32))
        own = Contribution.zero(
            k, self.store.n * getattr(self.loss, "ycols", 1))
        with self.tracer.span("worker_iter", k=k):
            for bid in sorted(self.blocks):
                st = self.blocks[bid]
                if st["iter"] < k:
                    self._apply_block(bid, x_dev, k, want_dual=True)
                else:
                    # retried broadcast: answered from the cached
                    # contribution, no prox re-applied
                    self.metrics.inc("worker.retry_cached_answers")
                c = st["contrib"]
                assert c is not None and c.iteration == k, \
                    f"block {bid} at iter {st['iter']}, contrib for {k}?"
                own = own.merge(c)
        self.metrics.inc("worker.iters")
        self.metrics.observe("worker.iter_s", time.perf_counter() - t_iter)
        own = Contribution(iteration=k, workers=(self.wid,),
                           rows=own.rows, d=own.d, w=own.w, v=own.v,
                           scalars=own.scalars)
        self._task = {"k": k, "epoch": int(msg["epoch"]),
                      "partial": own, "from": {self.wid},
                      "need": self.topology["nchildren"]}
        # children may have delivered before our own broadcast arrived
        buf, self._peer_buf = self._peer_buf, []
        for pending in buf:
            self._on_peer(pending)
        self._maybe_transmit()

    def _on_peer(self, msg):
        from repro.cluster.reduction import decode
        t = self._task
        ep, it = msg["epoch"], msg["payload"]["iteration"]
        if t is None or ep > t["epoch"] or (ep == t["epoch"]
                                            and it > t["k"]):
            # AHEAD of us (fast child beat our own iter broadcast):
            # buffer — dropping it would deadlock the parent's wait.
            # Each child sends once per (k, epoch), so the live window
            # is bounded by the child count; the cap only sheds entries
            # from topologies that died before we processed them.
            if ep >= self.topology["epoch"]:
                self._peer_buf.append(msg)
                cap = 2 * max(1, self.topology["nchildren"]) + 8
                del self._peer_buf[:-cap]
            return
        if ep < t["epoch"] or it < t["k"]:
            return                        # partial of a dead topology
        try:
            c = decode(msg["payload"])
        except ValueError:
            return                        # malformed partial: dropped;
                                          # the deadline retry recovers it
        if set(c.workers) & t["from"]:
            # a duplicated (chaos) or retried child partial that already
            # folded into this task — merging it again would double-count
            return
        t["from"] |= set(c.workers)
        t["partial"] = t["partial"].merge(c)
        t["need"] -= 1
        self._maybe_transmit()

    def _maybe_transmit(self):
        from repro.cluster.reduction import encode
        t = self._task
        if t is None or t["need"] > 0:
            return
        payload, self._ef_err = encode(t["partial"], self.compress,
                                       self._ef_err)
        parent = self.topology["parent"]
        self._task = None
        if parent is None:
            self.coord.send("contrib", wid=self.wid, epoch=t["epoch"],
                            payload=payload)
            return
        try:
            conn = self._parent_conns.get(parent)
            if conn is None or conn.closed:
                conn = connect(parent, counter=self.counter,
                               chaos=self._conn_chaos,
                               retries=2, backoff_s=0.1)
                self._parent_conns[parent] = conn
            conn.send("contrib", wid=self.wid, epoch=t["epoch"],
                      payload=payload)
        except (ConnectionClosed, OSError):
            # parent died: the coordinator's failure detector will
            # rebuild the topology and re-issue this iteration; our
            # cached per-block contributions make the retry cheap.
            self._parent_conns.pop(parent, None)

    def _on_unassign(self, msg):
        """Mid-solve rebalance: blocks move to a joiner. Drop their
        state (the new owner replays it) — keeping it would answer a
        retried broadcast for a block we no longer own."""
        dropped = [bid for bid in msg["blocks"]
                   if self.blocks.pop(bid, None) is not None]
        self.metrics.inc("worker.blocks_unassigned", len(dropped))
        self.coord.send("unassigned", wid=self.wid, blocks=dropped)

    def _on_checkpoint(self, msg):
        state = {}
        for bid, st in self.blocks.items():
            sl = self.store.block_slice(bid)
            valid = sl.stop - sl.start
            state[bid] = (st["y"][:valid].copy(), st["lam"][:valid].copy(),
                          st["iter"])
        self.coord.send("ckpt", wid=self.wid, blocks=state)

    # -- main loop ----------------------------------------------------------
    def run(self):
        threading.Thread(target=self._peer_accept, daemon=True).start()
        while True:
            reason = self._serve()
            if reason == "stop" or not self.reconnect:
                break
            # coordinator link lost and self-healing configured: shed
            # state and re-register (covers both a worker the failure
            # detector retired and a relaunched coordinator)
            self._reset_state()
            try:
                self._attach(retries=int(self.reconnect.get("retries", 8)))
            except ConnectionClosed:
                break                     # coordinator truly gone
        self._stop.set()
        try:
            self.coord.close()
        except OSError:
            pass

    def _serve(self) -> str:
        """Pump the inbox until the solve stops ("stop") or the current
        coordinator link dies ("lost")."""
        handlers = {"assign": self._on_assign, "stats": self._on_stats,
                    "topology": self._on_topology, "iter": self._on_iter,
                    "unassign": self._on_unassign,
                    "checkpoint": self._on_checkpoint}
        while True:
            kind, msg = self.inbox.get()
            if kind == "cmd_closed":
                if msg == self._gen:
                    return "lost"
                continue                  # a previous link's obituary
            if kind == "peer":
                self._on_peer(msg)
                continue
            mtype = msg.get("type")
            if mtype == "stop":
                # every link (coordinator, peer server, parent hops)
                # shares self.counter, so one snapshot covers them all;
                # metrics + trace events ride along so the coordinator
                # can fold a final per-worker registry and render the
                # cluster solve as one timeline
                try:
                    self.coord.send("bye", wid=self.wid,
                                    counters=self.counter.snapshot(),
                                    metrics=self.metrics.snapshot(),
                                    trace=self.tracer.events(),
                                    pid=os.getpid())
                except ConnectionClosed:
                    pass
                return "stop"
            if mtype in _HEARTBEAT_TYPES:
                continue
            if mtype == "iter" and self.staleness:
                # bounded-staleness drain: a slow worker computes against
                # the NEWEST broadcast x rather than queueing up history
                msg = self._drain_to_newest(msg)
            handler = handlers.get(mtype)
            if handler is None:
                continue                  # unknown command: ignore
            try:
                handler(msg)
            except ConnectionClosed:
                # the coordinator link died mid-handler (chaos reset, or
                # a send into a closed socket): same as cmd_closed
                return "lost"

    def _drain_to_newest(self, msg):
        while True:
            try:
                kind, nxt = self.inbox.get_nowait()
            except queue.Empty:
                return msg
            if kind == "peer":
                self._on_peer(nxt)
            elif kind == "cmd" and nxt.get("type") == "iter":
                msg = nxt                 # supersedes the queued one
            elif kind == "cmd" and nxt.get("type") in _HEARTBEAT_TYPES:
                continue
            else:
                self.inbox.put((kind, nxt))   # non-iter cmd: keep order
                return msg


def worker_entry(wid: int, coord_host: str, coord_port: int, config: dict):
    """multiprocessing spawn target. Sets thread/platform env BEFORE the
    jax backend initializes, then hands off to the runtime; any failure
    is reported to the coordinator as an ``error`` message."""
    _setup_env(config)
    rt = None
    try:
        rt = WorkerRuntime(wid, (coord_host, coord_port), config)
        rt.run()
    except Exception:
        tb = traceback.format_exc()
        try:
            if rt is not None:
                rt.coord.send("error", wid=wid, traceback=tb)
            else:
                conn = connect((coord_host, coord_port))
                conn.send("error", wid=wid, traceback=tb)
        except Exception:
            pass
        raise
