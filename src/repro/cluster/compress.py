"""int8 error-feedback compression — ONE implementation for every wire.

Both "networks" in this repo ship the same object per iteration: an
n-length transpose reduction (the paper's O(n)-per-node communication
claim). This module owns its compression so the two transports cannot
drift apart:

  * ``core/distributed.py`` — the shard_map all-gather psum (single
    process, many devices) quantizes each shard's d-contribution here;
  * ``repro/cluster`` — the multi-process runtime quantizes every tree
    hop of the cross-process reduce with the same blocks/scales.

Scheme: blockwise symmetric int8. The vector is cut into ``block``-sized
groups, each scaled by its own max-abs / 127 — one f32 scale per group,
so the wire payload is 1 byte/coordinate + 4/block bytes of scales (a
~3.9x reduction at block=256) instead of 4 bytes/coordinate. Error
feedback (``ef_compress``) keeps the quantization residual at the
SENDER and adds it to the next iteration's vector, so the systematic
bias of repeated rounding vanishes over iterations — ADMM sees a
perturbed-but-unbiased RHS (the inexact-consensus tolerance the cluster
runtime leans on; DESIGN.md §11).

Everything here is pure ``jax.numpy`` and jit/shard_map traceable; host
callers (the cluster transport) pass numpy arrays and get jax arrays
back, converting at the socket boundary.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

DEFAULT_BLOCK = 256


def quantize_int8(v: Array, block: int = DEFAULT_BLOCK
                  ) -> Tuple[Array, Array]:
    """Blockwise symmetric int8 quantization: (q int8 (nb, block),
    scale f32 (nb, 1)). The tail group is zero-padded (dequantize
    truncates it back). The group size adapts down to n — without that,
    an n=32 vector would be padded out to a 256-byte group and the
    "compressed" payload would EXCEED the 4n raw bytes."""
    n = v.shape[0]
    block = min(block, max(n, 1))
    nb = -(-n // block)
    pad = nb * block - n
    vp = jnp.pad(v, (0, pad)).reshape(nb, block)
    scale = jnp.max(jnp.abs(vp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(vp / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array, n: int) -> Array:
    """Inverse of :func:`quantize_int8` (up to rounding): f32 (n,)."""
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def ef_compress(v: Array, err: Array, block: int = DEFAULT_BLOCK
                ) -> Tuple[Array, Array, Array]:
    """Error-feedback quantization step: ``(q, scale, new_err)``.

    Quantizes ``v + err`` and returns the residual the SENDER must carry
    into its next transmission. The receiver reconstructs with
    :func:`dequantize_int8`; summing reconstructions over iterations is
    unbiased because each sender's residual re-enters its own stream.
    """
    corrected = v + err
    q, scale = quantize_int8(corrected, block=block)
    new_err = corrected - dequantize_int8(q, scale, corrected.shape[0])
    return q, scale, new_err


def wire_bytes(n: int, compressed: bool, block: int = DEFAULT_BLOCK) -> int:
    """Payload bytes of one n-vector on the wire (excluding framing):
    the quantity BENCH_cluster.json records per hop per iteration."""
    if not compressed:
        return 4 * n
    block = min(block, max(n, 1))
    nb = -(-n // block)
    return nb * block + 4 * nb          # int8 payload + f32 scales
