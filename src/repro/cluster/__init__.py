"""repro.cluster — multi-process coordinator/worker runtime (DESIGN.md §11).

The paper's deployment shape (5 Tb over 7000+ cores) is separate
PROCESSES shipping n-length transpose reductions to a solver node —
not a single-process shard_map. This package closes that gap:

  * :mod:`compress`    — int8 error-feedback wire compression, shared
                         with ``core/distributed.py``'s psum;
  * :mod:`transport`   — length-prefixed socket framing + byte counters;
  * :mod:`reduction`   — per-iteration contribution container and the
                         tree-reduce topology;
  * :mod:`membership`  — worker registry, heartbeats, block ownership,
                         reassignment and rebalance plans;
  * :mod:`chaos`       — seeded, deterministic fault injection (wire /
                         process / membership faults) for DESIGN.md §13;
  * :mod:`worker`      — the worker process: owns store row blocks, runs
                         the fused iteration body, ships reductions;
  * :mod:`coordinator` — the solver node: global x-update, broadcast,
                         fault recovery, bounded-staleness aggregation.

``compress`` is imported eagerly (``core/distributed`` depends on it);
the runtime modules load lazily so importing :mod:`repro.core` never
pays for the cluster machinery.
"""
from repro.cluster import compress  # noqa: F401  (eager: core.distributed)

_LAZY = {
    "ChaosSchedule": "repro.cluster.chaos",
    "ClusterConfig": "repro.cluster.coordinator",
    "ClusterCoordinator": "repro.cluster.coordinator",
    "ClusterResult": "repro.cluster.coordinator",
    "DegradePolicy": "repro.cluster.coordinator",
    "FaultInjector": "repro.cluster.chaos",
    "cluster_solve": "repro.cluster.coordinator",
    "cluster_stats": "repro.cluster.coordinator",
}

__all__ = ["compress"] + sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name])
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
