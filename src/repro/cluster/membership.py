"""Worker registry: liveness, block ownership, reassignment plans.

Liveness has two signals, and the faster one wins:

  * EOF on the worker's coordinator link (SIGKILL, crash — detected
    within one socket read by the receiver thread);
  * heartbeat age > ``timeout`` (hung process, network partition — the
    worker's heartbeat thread stamps every ``interval`` seconds).

Block ownership is the unit of both work and recovery: a worker owns a
set of store block indices; when it dies its blocks are orphaned and
:meth:`Membership.reassignment_plan` spreads them over the least-loaded
survivors. The STORE is the ground truth for what a block is — owners
re-open it read-only (mmap) and verify content against the write-time
fingerprints, so a reassignment can never silently feed a different
block's rows into the solve; the orphans' ITERATES are reconstructed by
the new owner from the coordinator's x-history (see worker.replay), not
copied from the dead process.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set


@dataclasses.dataclass
class WorkerInfo:
    wid: int
    conn: object = None                  # transport.Connection
    peer_addr: Optional[tuple] = None    # (host, port) of its peer server
    blocks: Set[int] = dataclasses.field(default_factory=set)
    last_heartbeat: float = dataclasses.field(default_factory=time.monotonic)
    alive: bool = True
    last_iteration: int = 0              # newest contribution seen from it
    process: object = None               # multiprocessing.Process handle
    metrics: Optional[dict] = None       # newest registry snapshot
                                         # (heartbeat / bye payload)


class DeadCluster(RuntimeError):
    """No live workers remain — the solve cannot make progress."""


class Membership:
    def __init__(self):
        self.workers: Dict[int, WorkerInfo] = {}
        self.deaths: List[int] = []          # wids, in death order
        self.reassignments: int = 0          # blocks moved post-death
        self.rebalances: int = 0             # blocks moved post-join

    # -- registry ----------------------------------------------------------
    def add(self, info: WorkerInfo):
        self.workers[info.wid] = info

    def alive(self) -> List[WorkerInfo]:
        return [w for w in self.workers.values() if w.alive]

    def alive_ids(self) -> List[int]:
        return sorted(w.wid for w in self.alive())

    def get(self, wid: int) -> WorkerInfo:
        return self.workers[wid]

    def owner_of(self, block: int) -> Optional[int]:
        for w in self.alive():
            if block in w.blocks:
                return w.wid
        return None

    # -- liveness ----------------------------------------------------------
    def beat(self, wid: int):
        w = self.workers.get(wid)
        if w is not None:
            w.last_heartbeat = time.monotonic()

    def stale(self, timeout: float) -> List[int]:
        now = time.monotonic()
        return [w.wid for w in self.alive()
                if now - w.last_heartbeat > timeout]

    def mark_dead(self, wid: int) -> Set[int]:
        """Retire a worker; returns its orphaned blocks."""
        w = self.workers.get(wid)
        if w is None or not w.alive:
            return set()
        w.alive = False
        self.deaths.append(wid)
        orphans, w.blocks = set(w.blocks), set()
        return orphans

    # -- block ownership ---------------------------------------------------
    def initial_assignment(self, nblocks: int) -> Dict[int, List[int]]:
        """Contiguous row-order split over registration order — each
        worker's blocks are adjacent, matching the paper's "node i holds
        rows m_i" layout (and mmap read locality)."""
        wids = self.alive_ids()
        if not wids:
            raise DeadCluster("no workers registered")
        per = -(-nblocks // len(wids))
        plan: Dict[int, List[int]] = {}
        for i, wid in enumerate(wids):
            blocks = list(range(i * per, min((i + 1) * per, nblocks)))
            plan[wid] = blocks
            self.workers[wid].blocks = set(blocks)
        return plan

    def reassignment_plan(self, orphans: Sequence[int]
                          ) -> Dict[int, List[int]]:
        """Spread orphaned blocks over the least-loaded survivors."""
        live = self.alive()
        if not live:
            raise DeadCluster(
                f"all workers dead; {len(orphans)} blocks orphaned")
        plan: Dict[int, List[int]] = {}
        for b in sorted(orphans):
            w = min(live, key=lambda w: len(w.blocks))
            w.blocks.add(b)
            plan.setdefault(w.wid, []).append(b)
            self.reassignments += 1
        return plan

    def rebalance_plan(self) -> "tuple[Dict[int, List[int]], Dict[int, List[int]]]":
        """Level block load across the live set — the dual of
        :meth:`reassignment_plan`, run when a worker JOINS mid-solve:
        blocks migrate one at a time from the most-loaded survivor to
        the least-loaded worker (the empty joiner) until every pair of
        loads is within one block. Returns ``(gains, losses)`` keyed by
        wid. Deterministic: ties break toward the smaller wid and the
        highest block index moves first. Exactness is the
        partition-insensitivity argument (PAPERS.md, Wu et al. 2024) —
        the solve's answer does not depend on which worker holds which
        rows, so ownership can move between iterations freely; the new
        owner reconstructs iterates by x-history replay."""
        live = self.alive()
        if not live:
            raise DeadCluster("no live workers to rebalance over")
        gains: Dict[int, List[int]] = {}
        losses: Dict[int, List[int]] = {}
        while True:
            donor = max(live, key=lambda w: (len(w.blocks), -w.wid))
            recip = min(live, key=lambda w: (len(w.blocks), w.wid))
            if len(donor.blocks) - len(recip.blocks) <= 1:
                break
            b = max(donor.blocks)
            donor.blocks.discard(b)
            recip.blocks.add(b)
            gains.setdefault(recip.wid, []).append(b)
            losses.setdefault(donor.wid, []).append(b)
            self.rebalances += 1
        return gains, losses

    def coverage(self) -> Set[int]:
        out: Set[int] = set()
        for w in self.alive():
            out |= w.blocks
        return out
